"""Pytest setup for the python/ layer (L1 Pallas kernels + L2 model + AOT).

The suite needs the JAX/Pallas toolchain (and hypothesis for the property
tests). On machines without those installed — e.g. a Rust-only CI runner —
collection is skipped with a notice instead of erroring, so `pytest python/`
is always safe to run.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def _have(mod: str) -> bool:
    return importlib.util.find_spec(mod) is not None


collect_ignore = []
collect_ignore_glob = []
_notices = []

if not _have("jax"):
    collect_ignore_glob = ["tests/*.py"]
    _notices.append(
        "python/: skipping the whole suite — jax is not installed "
        "(pip install -r python/requirements.txt)"
    )
else:
    import jax

    # The Rust tables are f64; without x64 jax silently downcasts.
    jax.config.update("jax_enable_x64", True)

    if not _have("hypothesis"):
        collect_ignore = ["tests/test_kernels.py", "tests/test_model.py"]
        _notices.append(
            "python/: skipping property tests — hypothesis is not installed "
            "(pip install -r python/requirements.txt)"
        )

for _n in _notices:
    # visible when conftest is imported outside pytest (pytest captures this)
    print(_n, file=sys.stderr)


def pytest_report_header(config):
    # visible in the pytest header (pytest swallows collection-time stderr)
    return _notices
