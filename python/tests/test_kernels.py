"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; every kernel must match ``ref``
to float tolerance on random tables, including the junction-tree edge
cases (zero rows from evidence, 0/0 separator entries).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, table_ops

jax.config.update("jax_enable_x64", True)

DIMS = st.sampled_from([1, 2, 3, 5, 16, 17, 64, 100, 256])
DTYPES = st.sampled_from([np.float32, np.float64])


def rand_table(rng, m, k, dtype, zero_rows=0.0):
    x = rng.uniform(0.0, 1.0, size=(m, k)).astype(dtype)
    if zero_rows > 0:
        mask = rng.uniform(size=m) < zero_rows
        x[mask] = 0.0
    return x


def tol(dtype):
    return 1e-5 if dtype == np.float32 else 1e-12


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, dtype=DTYPES, seed=st.integers(0, 2**32 - 1))
def test_marginalize_matches_ref(m, k, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rand_table(rng, m, k, dtype)
    got = table_ops.marginalize(jnp.asarray(x))
    want = ref.marginalize(jnp.asarray(x))
    np.testing.assert_allclose(got, want, rtol=tol(dtype), atol=tol(dtype))


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, dtype=DTYPES, seed=st.integers(0, 2**32 - 1))
def test_absorb_matches_ref(m, k, dtype, seed):
    rng = np.random.default_rng(seed)
    clique = rand_table(rng, m, k, dtype)
    new = rng.uniform(0.0, 1.0, size=m).astype(dtype)
    old = rand_table(rng, m, 1, dtype, zero_rows=0.3)[:, 0]  # some zeros
    new = np.where(old == 0.0, 0.0, new).astype(dtype)  # 0/0 pattern
    got = table_ops.absorb(jnp.asarray(clique), jnp.asarray(new), jnp.asarray(old))
    want = ref.absorb(jnp.asarray(clique), jnp.asarray(new), jnp.asarray(old))
    np.testing.assert_allclose(got, want, rtol=tol(dtype), atol=tol(dtype))


@settings(max_examples=25, deadline=None)
@given(m=DIMS, dtype=DTYPES, seed=st.integers(0, 2**32 - 1))
def test_sep_update_matches_ref(m, dtype, seed):
    rng = np.random.default_rng(seed)
    new = rng.uniform(0.0, 1.0, size=m).astype(dtype)
    old = rng.uniform(0.0, 1.0, size=m).astype(dtype)
    got_r, got_n, got_m = table_ops.sep_update(jnp.asarray(new), jnp.asarray(old))
    want_r, want_n, want_m = ref.sep_update(jnp.asarray(new), jnp.asarray(old))
    np.testing.assert_allclose(got_r, want_r, rtol=tol(dtype), atol=tol(dtype))
    np.testing.assert_allclose(got_n, want_n, rtol=tol(dtype), atol=tol(dtype))
    np.testing.assert_allclose(got_m, want_m, rtol=tol(dtype), atol=tol(dtype))


@settings(max_examples=15, deadline=None)
@given(m=st.sampled_from([2, 4, 16, 64]), k=st.sampled_from([1, 8, 64]), seed=st.integers(0, 2**32 - 1))
def test_mxu_marginalize_agrees_with_vpu_variant(m, k, seed):
    rng = np.random.default_rng(seed)
    x = rand_table(rng, m, k, np.float64)
    a = table_ops.marginalize(jnp.asarray(x))
    b = table_ops.marginalize_mxu(jnp.asarray(x))
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


def test_marginalize_zero_table():
    x = jnp.zeros((8, 4), dtype=jnp.float64)
    np.testing.assert_array_equal(table_ops.marginalize(x), np.zeros(8))


def test_absorb_zero_over_zero_is_zero():
    clique = jnp.ones((4, 4), dtype=jnp.float64)
    new = jnp.zeros(4, dtype=jnp.float64)
    old = jnp.zeros(4, dtype=jnp.float64)
    out = table_ops.absorb(clique, new, old)
    np.testing.assert_array_equal(out, np.zeros((4, 4)))


def test_sep_update_zero_mass_reports_zero():
    new = jnp.zeros(4, dtype=jnp.float64)
    old = jnp.ones(4, dtype=jnp.float64)
    ratio, norm, mass = table_ops.sep_update(new, old)
    assert float(mass) == 0.0
    np.testing.assert_array_equal(norm, np.zeros(4))
    np.testing.assert_array_equal(ratio, np.zeros(4))


def test_tile_sweep_changes_nothing():
    rng = np.random.default_rng(7)
    x = rand_table(rng, 300, 17, np.float64)
    want = ref.marginalize(jnp.asarray(x))
    for tile_m in [1, 7, 64, 256, 300, 512]:
        got = table_ops.marginalize(jnp.asarray(x), tile_m=tile_m)
        np.testing.assert_allclose(got, want, rtol=1e-12)


def test_vmem_footprint_estimate_is_sane():
    # default tile on the largest bucket must fit a 16 MiB VMEM budget
    bytes_needed = table_ops.vmem_footprint_bytes(table_ops.TILE_M, 1024, dtype_bytes=4)
    assert bytes_needed < 16 * 1024 * 1024, f"{bytes_needed} bytes exceeds VMEM"
    # and the estimate grows linearly in K
    assert table_ops.vmem_footprint_bytes(64, 512) == pytest.approx(
        2 * table_ops.vmem_footprint_bytes(64, 256), rel=0.02
    )
