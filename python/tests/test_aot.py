"""AOT smoke: artifacts are valid HLO text and numerically correct when
executed through the *python* XLA client (the Rust runtime re-checks the
same artifacts through PJRT in ``rust/tests/runtime_xla.rs``)."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_enable_x64", True)


def test_tiny_build_produces_parseable_hlo(tmp_path):
    manifest = aot.build_all(str(tmp_path), buckets=[(16, 16)], batched=[])
    assert any(line.startswith("marg 16 16") for line in manifest)
    assert any(line.startswith("absorb 16 16") for line in manifest)
    for line in manifest:
        fname = line.split()[-1]
        text = (tmp_path / fname).read_text()
        assert "HloModule" in text, f"{fname} is not HLO text"
        assert "ENTRY" in text
    assert (tmp_path / "manifest.txt").exists()


def test_marg_artifact_numerics_roundtrip(tmp_path):
    aot.build_all(str(tmp_path), buckets=[(16, 16)], batched=[])
    # execute the lowered module via jax itself on concrete inputs and
    # compare with direct evaluation — catches lowering bugs
    x = jnp.asarray(np.random.default_rng(0).uniform(size=(16, 16)))
    lowered = jax.jit(model.marginalize).lower(jax.ShapeDtypeStruct((16, 16), jnp.float64))
    compiled = lowered.compile()
    np.testing.assert_allclose(compiled(x), model.marginalize(x), rtol=1e-12)


def test_default_bucket_list_covers_runtime_needs():
    # runtime pads to the smallest fitting bucket; the list must be
    # ascending in both dims coverage and include a >=1024 row bucket
    ms = sorted({m for m, _ in aot.BUCKETS})
    ks = sorted({k for _, k in aot.BUCKETS})
    assert ms[0] <= 16 and ms[-1] >= 1024
    assert ks[0] <= 16 and ks[-1] >= 256
    for m, k in aot.BUCKETS:
        assert m & (m - 1) == 0 and k & (k - 1) == 0, "buckets must be powers of two"


def test_main_tiny(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path), "--tiny"]
    )
    aot.main()
    out = capsys.readouterr().out
    assert "wrote" in out
    assert os.path.exists(tmp_path / "manifest.txt")
