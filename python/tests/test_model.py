"""L2 correctness: model compositions vs the oracle, batching, shapes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

DIMS = st.sampled_from([1, 2, 4, 16, 33, 64])


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, seed=st.integers(0, 2**32 - 1))
def test_message_pass_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    child = jnp.asarray(rng.uniform(size=(m, k)))
    parent = jnp.asarray(rng.uniform(size=(m, k)))
    sep_old = jnp.asarray(rng.uniform(0.1, 1.0, size=m))
    got_p, got_s, got_m = model.message_pass(child, parent, sep_old)
    want_p, want_s, want_m = ref.message_pass(child, parent, sep_old)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-12)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-12)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([1, 2, 8]), m=DIMS, k=DIMS, seed=st.integers(0, 2**32 - 1))
def test_batched_ops_equal_per_case_loop(b, m, k, seed):
    rng = np.random.default_rng(seed)
    cliques = jnp.asarray(rng.uniform(size=(b, m, k)))
    new = jnp.asarray(rng.uniform(size=(b, m)))
    old = jnp.asarray(rng.uniform(0.1, 1.0, size=(b, m)))
    bm = model.marginalize_batch(cliques)
    ba = model.absorb_batch(cliques, new, old)
    for i in range(b):
        np.testing.assert_allclose(bm[i], ref.marginalize(cliques[i]), rtol=1e-12)
        np.testing.assert_allclose(ba[i], ref.absorb(cliques[i], new[i], old[i]), rtol=1e-12)


def test_normalize():
    x = jnp.asarray([1.0, 3.0])
    np.testing.assert_allclose(model.normalize(x), [0.25, 0.75])
    z = jnp.zeros(3)
    np.testing.assert_array_equal(model.normalize(z), np.zeros(3))


def test_message_pass_conserves_conditionals():
    # after absorbing, the parent's separator marginal equals the message
    rng = np.random.default_rng(3)
    child = jnp.asarray(rng.uniform(size=(8, 4)))
    parent = jnp.asarray(rng.uniform(size=(8, 16)))
    sep_old = jnp.asarray(ref.marginalize(parent))  # calibrated separator
    parent_out, sep_out, mass = model.message_pass(child, parent, sep_old)
    # sep_out is the normalized child marginal
    np.testing.assert_allclose(
        sep_out, ref.marginalize(child) / float(mass), rtol=1e-12
    )
    # parent's new separator marginal == sep_out (Hugin fixed point)
    np.testing.assert_allclose(ref.marginalize(parent_out), sep_out, rtol=1e-9)


def test_chain_calibrate_runs_and_accumulates_mass():
    rng = np.random.default_rng(9)
    cliques = [jnp.asarray(rng.uniform(size=(8, 8))) for _ in range(4)]
    seps = [jnp.ones(8, dtype=jnp.float64) for _ in range(3)]
    final, log_mass = model.chain_calibrate(cliques, seps)
    assert final.shape == (8, 8)
    assert np.isfinite(float(log_mass))
    # lowering the whole chain into one jitted module must also work
    jitted = jax.jit(lambda cs, ss: model.chain_calibrate(cs, ss))
    f2, lm2 = jitted(cliques, seps)
    np.testing.assert_allclose(f2, final, rtol=1e-12)
    np.testing.assert_allclose(lm2, log_mass, rtol=1e-12)
