"""L1 — Pallas kernels for the dominant potential-table operations.

The paper parallelizes three table operations on CPU threads via index
mappings (gather/scatter). The TPU re-think (DESIGN.md §Hardware-Adaptation)
reshapes each clique table into a 2-D *separator-major* view ``(M, K)``:
``M`` enumerates separator configurations, ``K`` the remaining clique
digits. Then

* **marginalization** is a row reduction ``(M, K) -> (M,)`` on the VPU
  (with an alternative one-hot **MXU matmul** formulation for wide tables),
* **extension + reduction** ("absorb") is a broadcast multiply of the
  per-row ratio ``new/old``,

and the HBM <-> VMEM schedule that the paper expressed with threadblocks is
expressed here with ``BlockSpec`` tiles over ``M``.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowering produces plain HLO that
both pytest and the Rust runtime execute. Tile shapes are still chosen for
a real TPU VMEM budget (see ``vmem_footprint_bytes``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile heights (rows of the sep-major view per grid step). Chosen
# so a (TILE_M, K<=1024) f32/f64 block stays well under a 16 MiB VMEM
# budget alongside the output tile and double-buffering headroom.
TILE_M = 256


def _row_sum_kernel(x_ref, o_ref):
    """One grid step: reduce a (tile_m, K) block to (tile_m,) row sums."""
    o_ref[...] = jnp.sum(x_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def marginalize(clique, tile_m: int = TILE_M):
    """Row-sum marginalization ``(M, K) -> (M,)`` as a tiled Pallas kernel.

    ``M`` must be a multiple of ``tile_m`` or smaller than it (the grid
    covers ``ceil(M / tile_m)`` row tiles; ragged edges are handled by
    Pallas block clamping).
    """
    m, k = clique.shape
    tile = min(tile_m, m)
    grid = (pl.cdiv(m, tile),)
    return pl.pallas_call(
        _row_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), clique.dtype),
        interpret=True,
    )(clique)


def _absorb_kernel(x_ref, new_ref, old_ref, o_ref):
    """One grid step: multiply a (tile_m, K) block by the per-row ratio.

    The reduction ratio ``new/old`` uses the junction-tree convention
    0/0 = 0 (evidence-killed entries stay dead).
    """
    new = new_ref[...]
    old = old_ref[...]
    ratio = jnp.where(old != 0.0, new / jnp.where(old != 0.0, old, 1.0), 0.0)
    o_ref[...] = x_ref[...] * ratio[:, None]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def absorb(clique, sep_new, sep_old, tile_m: int = TILE_M):
    """Fused extension+reduction: ``out[m,k] = clique[m,k] * new[m]/old[m]``.

    This is the paper's separator-update absorbed into the receiving
    clique, with the division folded in (one pass over the table instead
    of two).
    """
    m, k = clique.shape
    tile = min(tile_m, m)
    grid = (pl.cdiv(m, tile),)
    return pl.pallas_call(
        _absorb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), clique.dtype),
        interpret=True,
    )(clique, sep_new, sep_old)


def _matmul_marg_kernel(x_ref, sel_ref, o_ref):
    """MXU formulation: ``o = sel @ x`` with ``sel`` a (tile_m, M) one-hot
    selector — marginalization as a systolic-array matmul.

    On real TPU hardware this variant wins when ``K`` is large enough to
    amortize the selector traffic (the selector is fused from an iota
    comparison, so it never materializes in HBM).
    """
    o_ref[...] = jnp.dot(sel_ref[...], x_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_m",))
def marginalize_mxu(clique, tile_m: int = TILE_M):
    """Marginalization routed through the MXU (see `_matmul_marg_kernel`).

    Semantically identical to :func:`marginalize`; exists so the §Perf
    estimate can compare VPU-reduce vs MXU-matmul schedules.
    """
    m, k = clique.shape
    tile = min(tile_m, m)
    grid = (pl.cdiv(m, tile),)
    # one-hot row selector: sel[i, j] = 1 iff j == global_row(i)
    sel = jnp.eye(m, dtype=clique.dtype)
    out = pl.pallas_call(
        _matmul_marg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), clique.dtype),
        interpret=True,
    )(clique, sel)
    return jnp.sum(out, axis=1)


def _sep_update_kernel(new_ref, old_ref, ratio_ref, norm_ref):
    """Normalize a separator message and emit the update ratio.

    Outputs: ratio = normalized_new / old (0/0 = 0), norm = normalized_new.
    The mass (pre-normalization sum) is returned by the caller from a
    plain reduction — scalars are cheap at the JAX level.
    """
    new = new_ref[...]
    old = old_ref[...]
    total = jnp.sum(new)
    scale = jnp.where(total > 0.0, 1.0 / jnp.where(total > 0.0, total, 1.0), 0.0)
    normalized = new * scale
    ratio_ref[...] = jnp.where(old != 0.0, normalized / jnp.where(old != 0.0, old, 1.0), 0.0)
    norm_ref[...] = normalized


@jax.jit
def sep_update(sep_new, sep_old):
    """Separator finish: returns ``(ratio, normalized_new, mass)``.

    Single-tile kernel (separators are small relative to cliques); the
    mass is computed outside the kernel so callers can fold ``ln(mass)``
    into their evidence-likelihood accumulator.
    """
    (m,) = sep_new.shape
    ratio, norm = pl.pallas_call(
        _sep_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m,), sep_new.dtype),
            jax.ShapeDtypeStruct((m,), sep_new.dtype),
        ),
        interpret=True,
    )(sep_new, sep_old)
    mass = jnp.sum(sep_new)
    return ratio, norm, mass


def vmem_footprint_bytes(tile_m: int, k: int, dtype_bytes: int = 4, buffers: int = 2) -> int:
    """Estimated VMEM bytes for one :func:`absorb` grid step.

    ``buffers=2`` accounts for double-buffered input + output tiles; the
    two (tile_m,) separator vectors are negligible but included. Used by
    DESIGN.md §Perf to justify tile choices against a 16 MiB budget.
    """
    tile_bytes = tile_m * k * dtype_bytes
    sep_bytes = 2 * tile_m * dtype_bytes
    return buffers * (2 * tile_bytes + sep_bytes)
