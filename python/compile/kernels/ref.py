"""Pure-jnp reference oracle for the Pallas table-op kernels.

Every kernel in :mod:`compile.kernels.table_ops` must match these
definitions exactly (pytest sweeps shapes/dtypes with hypothesis). These
are also the semantics the Rust native backend implements, so the chain
``rust native == HLO artifact == pallas kernel == ref`` is closed by the
combination of this suite and ``rust/tests/runtime_xla.rs``.
"""

from __future__ import annotations

import jax.numpy as jnp


def marginalize(clique):
    """Row sums of the sep-major view: ``(M, K) -> (M,)``."""
    return jnp.sum(clique, axis=1)


def _safe_ratio(new, old):
    """Junction-tree division: ``new/old`` with 0/0 = 0."""
    return jnp.where(old != 0.0, new / jnp.where(old != 0.0, old, 1.0), 0.0)


def absorb(clique, sep_new, sep_old):
    """``out[m, k] = clique[m, k] * new[m] / old[m]`` (0/0 = 0)."""
    return clique * _safe_ratio(sep_new, sep_old)[:, None]


def sep_update(sep_new, sep_old):
    """Returns ``(ratio, normalized_new, mass)``; mass may be 0."""
    mass = jnp.sum(sep_new)
    scale = jnp.where(mass > 0.0, 1.0 / jnp.where(mass > 0.0, mass, 1.0), 0.0)
    normalized = sep_new * scale
    return _safe_ratio(normalized, sep_old), normalized, mass


def message_pass(child, parent, sep_old):
    """One junction-tree message in the 2-D view (both tables sep-major).

    Returns ``(parent_out, sep_out, mass)`` — the composition the L2
    model lowers per edge.
    """
    msg = marginalize(child)
    ratio, norm, mass = sep_update(msg, sep_old)
    del ratio
    return absorb(parent, norm, sep_old), norm, mass
