"""AOT: lower the L2 entry points to HLO **text** artifacts per shape
bucket, for the Rust PJRT runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the published
``xla`` crate's XLA (xla_extension 0.5.1) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Buckets: clique tables are padded by the Rust runtime to the smallest
``(M, K)`` bucket that fits (sep-major 2-D view; padding rows/cols are
zero, which both ops treat as absent mass). One compiled executable per
(op, bucket) pair; the manifest lists them all.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import os

import jax

# The Rust tables are f64; without x64 jax silently downcasts the lowered
# modules to f32 and PJRT rejects the runtime's buffers.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (M, K) buckets for the sep-major clique view. Powers of two, spanning
# tiny separators up to ~1M-entry cliques (1024 * 1024).
BUCKETS = [(16, 16), (64, 64), (256, 256), (1024, 256), (1024, 1024)]

# Case-batched variants (batch, M, K) — emitted for the batched-dispatch
# extension benchmarked on the Python side.
BATCHED_BUCKETS = [(8, 256, 256)]

DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_marginalize(m: int, k: int) -> str:
    spec = jax.ShapeDtypeStruct((m, k), DTYPE)
    return to_hlo_text(jax.jit(model.marginalize).lower(spec))


def lower_absorb(m: int, k: int) -> str:
    clique = jax.ShapeDtypeStruct((m, k), DTYPE)
    sep = jax.ShapeDtypeStruct((m,), DTYPE)
    return to_hlo_text(jax.jit(model.absorb).lower(clique, sep, sep))


def lower_message(m: int, k: int) -> str:
    """Fused child->parent message for same-bucket child/parent tables."""
    table = jax.ShapeDtypeStruct((m, k), DTYPE)
    sep = jax.ShapeDtypeStruct((m,), DTYPE)
    return to_hlo_text(jax.jit(model.message_pass).lower(table, table, sep))


def lower_marginalize_batch(b: int, m: int, k: int) -> str:
    spec = jax.ShapeDtypeStruct((b, m, k), DTYPE)
    return to_hlo_text(jax.jit(model.marginalize_batch).lower(spec))


def lower_absorb_batch(b: int, m: int, k: int) -> str:
    clique = jax.ShapeDtypeStruct((b, m, k), DTYPE)
    sep = jax.ShapeDtypeStruct((b, m), DTYPE)
    return to_hlo_text(jax.jit(model.absorb_batch).lower(clique, sep, sep))


def build_all(out_dir: str, buckets=None, batched=None) -> list[str]:
    """Write every artifact + manifest into ``out_dir``; returns manifest
    lines (``op M K filename`` / ``op B M K filename``)."""
    buckets = BUCKETS if buckets is None else buckets
    batched = BATCHED_BUCKETS if batched is None else batched
    os.makedirs(out_dir, exist_ok=True)
    manifest: list[str] = []

    for m, k in buckets:
        for op, lower in [("marg", lower_marginalize), ("absorb", lower_absorb)]:
            fname = f"{op}_{m}x{k}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(lower(m, k))
            manifest.append(f"{op} {m} {k} {fname}")

    # one fused-message artifact (mid bucket) as the L2-composition demo
    m, k = buckets[len(buckets) // 2]
    fname = f"msg_{m}x{k}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(lower_message(m, k))
    manifest.append(f"msg {m} {k} {fname}")

    for b, m, k in batched:
        for op, lower in [("bmarg", lower_marginalize_batch), ("babsorb", lower_absorb_batch)]:
            fname = f"{op}_{b}x{m}x{k}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(lower(b, m, k))
            manifest.append(f"{op} {b} {m} {k} {fname}")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    parser.add_argument(
        "--tiny", action="store_true", help="only the smallest bucket (fast smoke builds in tests)"
    )
    args = parser.parse_args()
    buckets = BUCKETS[:1] if args.tiny else None
    batched = [] if args.tiny else None
    manifest = build_all(args.out_dir, buckets=buckets, batched=batched)
    total = sum(
        os.path.getsize(os.path.join(args.out_dir, line.split()[-1])) for line in manifest
    )
    print(f"wrote {len(manifest)} artifacts ({total} bytes of HLO text) to {args.out_dir}")


if __name__ == "__main__":
    main()
