"""L2 — the JAX compute graph over the L1 kernels.

The Rust coordinator owns the *tree traversal* (which message when); the
compute per message is a fixed dataflow over the sep-major 2-D views:

    msg   = marginalize(child)            # L1 kernel
    ratio, new, mass = sep_update(msg, sep_old)
    parent' = absorb-by-ratio(parent)     # folded into absorb()

``aot.py`` lowers three entry points per shape bucket — ``marginalize``,
``absorb`` and the fused ``message_pass`` — plus case-batched variants
(``vmap`` over a leading batch axis), and the Rust runtime executes them
via PJRT. Python never runs at inference time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import table_ops as k


def marginalize(clique):
    """L2 wrapper over the L1 row-sum kernel (``(M,K) -> (M,)``)."""
    return k.marginalize(clique)


def absorb(clique, sep_new, sep_old):
    """L2 wrapper over the fused extension+reduction kernel."""
    return k.absorb(clique, sep_new, sep_old)


def message_pass(child, parent, sep_old):
    """One full junction-tree message (see module docs).

    Returns ``(parent_out, sep_out, mass)``. ``mass`` is the
    pre-normalization separator sum; the coordinator accumulates
    ``ln(mass)`` into ``ln P(e)`` and treats ``mass == 0`` as inconsistent
    evidence.
    """
    msg = k.marginalize(child)
    ratio, norm, mass = k.sep_update(msg, sep_old)
    parent_out = k.absorb(parent, norm, sep_old)
    del ratio  # the absorb kernel recomputes the ratio fused
    return parent_out, norm, mass


def marginalize_batch(cliques):
    """Case-batched marginalization: ``(B, M, K) -> (B, M)``.

    The 2 000-test-case protocol makes the batch axis the natural
    additional parallel dimension on an accelerator; the Rust coordinator
    can pack same-bucket messages from different cases into one call.
    """
    return jax.vmap(k.marginalize)(cliques)


def absorb_batch(cliques, sep_new, sep_old):
    """Case-batched absorb: ``(B, M, K), (B, M), (B, M) -> (B, M, K)``."""
    return jax.vmap(k.absorb)(cliques, sep_new, sep_old)


def normalize(table):
    """Table normalization (used for posteriors): zero-safe."""
    total = jnp.sum(table)
    scale = jnp.where(total > 0.0, 1.0 / jnp.where(total > 0.0, total, 1.0), 0.0)
    return table * scale


def chain_calibrate(cliques, sep_olds):
    """Collect over a fixed chain of cliques (pedagogical / test target).

    ``cliques`` is a list of same-bucket (M, K) tables forming a chain
    ``c0 - c1 - ... - cn``; messages flow left to right. Returns the final
    clique and the accumulated log-mass. Demonstrates that L2 composes the
    kernels into multi-step programs that lower into a single HLO module.
    """
    log_mass = jnp.zeros((), dtype=cliques[0].dtype)
    current = cliques[0]
    for nxt, sep_old in zip(cliques[1:], sep_olds):
        nxt, _, mass = message_pass(current, nxt, sep_old)
        log_mass = log_mass + jnp.log(jnp.maximum(mass, 1e-300))
        current = nxt
    return current, log_mass
