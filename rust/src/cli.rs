//! Hand-rolled CLI (no `clap` in this offline environment).
//!
//! ```text
//! fastbn nets
//! fastbn info      --net <spec> [--heuristic min-fill]
//! fastbn query     --net <spec> --target <var> [--evidence a=x,b=y] [--engine hybrid] [--threads N]
//! fastbn mpe       --net <spec> [--evidence a=x,b=y] | [--cases N] [--obs 0.2] [--batch B] [--seed S]
//! fastbn batch     --net <spec> [--cases 2000] [--obs 0.2] [--engine hybrid] [--threads N] [--replicas 1]
//!                  [--batch B] [--seed S]
//! fastbn generate  --nodes N [--arcs M] [--max-parents 3] [--seed S] [--out net.bif]
//! fastbn learn     --net <spec> [--samples 50000] [--seed S] [--threads T] [--alpha 0.01]
//!                  [--laplace 1.0] [--max-cond L] [--name NAME] [--out net.bif]
//!                  [--save-data d.csv] | --data d.csv [--name NAME] [--out net.bif]
//! fastbn serve     --net <spec> [--bind 127.0.0.1:7979] [--engine hybrid] [--threads N]
//! fastbn serve     --nets a,b,c [--shards N] [--registry-cap K] [--batch B] [--bind ...] [--smoke] [--batch-smoke]
//!                  [--max-exact-cost C] [--samples N] [--approx-smoke] [--metrics-smoke] [--profile-smoke]
//!                  [--slow-query-ms T] [--metrics-interval SECS]
//! fastbn cluster   --backends N [--nets a,b,c] [--shards S] [--replicas R] [--vnodes V]
//!                  [--join-hosts h:p,...] [--bind ...] [--smoke]
//!                  [--max-exact-cost C] [--samples N] [--metrics-smoke] [--profile-smoke]
//! fastbn profile   --net <spec> [--queries K] [--engine hybrid] [--threads N] [--evidence a=x,b=y]
//! fastbn simulate  --net <spec> [--threads 1,2,4,8,16,32]
//! fastbn selftest
//! ```
//!
//! `<spec>` is an embedded name (`asia`, `cancer`, `sprinkler`,
//! `mixed12`), a paper-suite analog (`hailfinder-sim`, ... `munin4-sim`),
//! or a path to a `.bif` file.

use std::collections::HashMap;
use std::sync::Arc;

use crate::bn::network::Network;
use crate::bn::{bif, embedded, netgen};
use crate::cluster::{Cluster, ClusterConfig, ClusterServer};
use crate::coordinator::server::Server;
use crate::coordinator::{BatchConfig, BatchRunner};
use crate::engine::approx::ApproxEngine;
use crate::engine::simulate::{best_over_threads, simulate_seconds, CostModel};
use crate::engine::{Engine, EngineConfig, EngineKind};
use crate::fleet::{Fleet, FleetConfig, FleetServer};
use crate::infer::cases::{generate, CaseSpec};
use crate::jt::evidence::Evidence;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::jt::triangulate::TriangulationHeuristic;
use crate::{Error, Result};

/// Resolve a network spec string (see module docs); shared with the
/// serving fleet's registry via [`crate::bn::resolve_spec`].
pub fn resolve_net(spec: &str) -> Result<Network> {
    crate::bn::resolve_spec(spec)
}

/// Parsed `--flag value` arguments.
pub struct Args {
    flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

/// Flags that are boolean switches: present or absent, never taking a
/// value. Everything else must be followed by one.
const SWITCHES: &[&str] = &[
    "smoke", "fleet", "parent-watch", "batch-smoke", "learn-smoke", "approx-smoke", "metrics-smoke",
    "profile-smoke",
];

impl Args {
    /// Parse from raw argv (after the subcommand).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if SWITCHES.contains(&name) {
                    flags.insert(name.to_string(), String::new());
                } else {
                    match argv.get(i + 1) {
                        // `--evidence --engine …` is a forgotten value, not
                        // a value that happens to start with a dash-dash
                        Some(v) if !v.starts_with("--") => {
                            flags.insert(name.to_string(), v.clone());
                            i += 1;
                        }
                        _ => return Err(Error::msg(format!("--{name} needs a value"))),
                    }
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, positional })
    }

    /// String flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Boolean switch: present with no value (or any value at all).
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Required string flag.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| Error::msg(format!("missing required --{name}")))
    }

    /// Parsed flag with default.
    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| Error::msg(format!("bad value for --{name}: {v:?}"))),
        }
    }
}

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let d = EngineConfig::default();
    Ok(EngineConfig {
        threads: args.parse_or("threads", 0usize)?,
        batch: args.parse_or("batch", 1usize)?.max(1),
        samples: args.parse_or("samples", d.samples)?,
        target_half_width: args.parse_or("target-half-width", d.target_half_width)?,
        seed: args.parse_or("seed", d.seed)?,
        ..d
    })
}

fn parse_evidence(net: &Network, text: Option<&str>) -> Result<Evidence> {
    let Some(text) = text else { return Ok(Evidence::none()) };
    let mut pairs = Vec::new();
    for tok in text.split(',').filter(|t| !t.is_empty()) {
        let (var, state) = tok
            .split_once('=')
            .ok_or_else(|| Error::msg(format!("evidence token {tok:?} is not var=state")))?;
        pairs.push((var.trim(), state.trim()));
    }
    Evidence::from_pairs(net, &pairs)
}

/// Run the CLI; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[argv.len().min(1)..])?;
    match cmd {
        "nets" => cmd_nets(),
        "info" => cmd_info(&args),
        "query" => cmd_query(&args),
        "mpe" => cmd_mpe(&args),
        "batch" => cmd_batch(&args),
        "generate" => cmd_generate(&args),
        "learn" => cmd_learn(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "profile" => cmd_profile(&args),
        "simulate" => cmd_simulate(&args),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::msg(format!("unknown command {other:?}; see `fastbn help`"))),
    }
}

const HELP: &str = "\
fastbn — fast parallel exact inference on Bayesian networks (Fast-BNI reproduction)

USAGE: fastbn <command> [--flag value ...]

COMMANDS:
  nets                               list available networks
  info      --net S                  network + junction tree statistics
  query     --net S --target V       posterior of V given --evidence a=x,b=y
  mpe       --net S                  most probable explanation given --evidence;
                                     --cases N instead sweeps N generated cases
                                     --batch B lanes at a time (batched max-product)
                                     and verifies each lane bit-for-bit against
                                     the single-case driver (--obs, --seed)
  batch     --net S                  run an evidence-case batch (--cases, --obs,
                                     --engine, --threads, --replicas, --seed;
                                     --batch B fuses B cases per sweep — pair
                                     with --engine batched)
  generate  --nodes N                make a synthetic network (--arcs, --max-parents,
                                     --seed, --out file.bif)
  learn     --net S                  sample --samples rows from S and learn structure
                                     (PC-stable, pool-parallel CI tests) + parameters
                                     (Laplace MLE) back; closes the sample->learn->
                                     serve loop (--seed, --threads, --alpha, --laplace,
                                     --max-cond, --name, --out file.bif, --save-data
                                     d.csv); or learn from a CSV via --data d.csv
  serve     --net S                  TCP inference server (--bind, --engine)
  serve     --nets A,B,C             multi-network serving fleet (--shards N,
                                     --registry-cap K, --batch B lanes/shard
                                     with --engine batched, --smoke and
                                     --batch-smoke / --learn-smoke /
                                     --approx-smoke / --metrics-smoke /
                                     --profile-smoke self-checks;
                                     --max-exact-cost C serves
                                     networks whose estimated junction-tree
                                     cost exceeds C from the approximate tier,
                                     --samples per approx query;
                                     --slow-query-ms T logs queries slower
                                     than T, --metrics-interval SECS dumps
                                     the metrics exposition to stderr);
                                     verbs: LOAD LEARN USE NETS OBSERVE
                                     RETRACT COMMIT QUERY MPE BATCH CASE
                                     STATS METRICS TRACE PROFILE PING
                                     EVICT QUIT (BATCH <n> MPE batches
                                     max-product)
  cluster   --backends N             cross-process cluster tier: N fleet backend
                                     child processes + a consistent-hash front
                                     router (--nets preload, --shards, --replicas
                                     R owners per net, --vnodes ring points,
                                     --join-hosts h:p,... adopts already-running
                                     fleets, --smoke / --metrics-smoke /
                                     --profile-smoke scripted sessions;
                                     --max-exact-cost / --samples forwarded
                                     to every backend); adds verbs: PING TOPO
                                     METRICS TRACE PROFILE JOIN HANDOFF
                                     (TRACE tags queries with cluster-minted
                                     qids; TRACE q<n> replays one query's
                                     cross-tier timeline)
  profile   --net S                  arm the pool parallelism profiler + span
                                     tracer, run --queries K inferences, and
                                     report junction-tree phase times plus
                                     per-worker busy/idle lanes (--engine,
                                     --threads, --evidence)
  simulate  --net S                  modeled parallel times across --threads list
  selftest                           engine-agreement smoke check
  help                               this text

ENGINES: unb | seq | direct | primitive | element | hybrid (default)
         batched (case-major multi-case sweeps; lanes set by --batch B)
         approx (parallel likelihood weighting — no junction tree; --samples N,
         --target-half-width W, --seed S; posteriors report 95% CI half-widths)
";

fn cmd_nets() -> Result<()> {
    println!("embedded:");
    for name in embedded::NAMES {
        let net = embedded::by_name(name).unwrap();
        println!("  {:<16} {}", name, net.stats());
    }
    println!("paper suite (synthetic analogs of the Table-1 networks):");
    for spec in netgen::paper_suite() {
        let net = spec.generate();
        println!("  {:<16} {}", spec.name, net.stats());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let net = resolve_net(args.require("net")?)?;
    let heuristic: TriangulationHeuristic = args.get("heuristic").unwrap_or("min-fill").parse()?;
    println!("network: {}", net.stats());
    let t0 = std::time::Instant::now();
    let jt = JunctionTree::compile(&net, heuristic)?;
    println!("junction tree ({heuristic:?}, compiled in {:?}): {}", t0.elapsed(), jt.stats());
    let center = crate::jt::schedule::Schedule::build(&jt, crate::jt::schedule::RootStrategy::Center);
    let first = crate::jt::schedule::Schedule::build(&jt, crate::jt::schedule::RootStrategy::First);
    println!(
        "layers: {} with center root (paper's root selection), {} with naive first root",
        center.height(),
        first.height()
    );
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let net = Arc::new(resolve_net(args.require("net")?)?);
    let target = args.require("target")?;
    let engine_kind: EngineKind = args.get("engine").unwrap_or("hybrid").parse()?;
    let cfg = engine_config(args)?;
    let ev = parse_evidence(&net, args.get("evidence"))?;
    // `--engine approx` samples the network directly — no junction tree is
    // ever compiled, so this path serves networks exact compilation can't
    let (mut engine, mut state): (Box<dyn Engine>, TreeState) = if engine_kind == EngineKind::Approx {
        (Box::new(ApproxEngine::from_net(Arc::clone(&net), &cfg)), TreeState::detached())
    } else {
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
        (engine_kind.build(Arc::clone(&jt), &cfg), TreeState::fresh(&jt))
    };
    let t0 = std::time::Instant::now();
    let post = engine.infer(&mut state, &ev)?;
    let elapsed = t0.elapsed();
    let v = net.var_id(target)?;
    println!("P({target} | {}) [{} in {elapsed:?}]:", ev.describe(&net), engine.name());
    for (s, p) in net.vars[v].states.iter().zip(&post.probs[v]) {
        println!("  {s:<16} {p:.6}");
    }
    println!("ln P(e) = {:.6}", post.log_z);
    if let Some(info) = &post.approx {
        println!(
            "approx: samples={} ess={:.0} max 95% half-width={:.6}",
            info.n_samples,
            info.effective_samples,
            info.max_half_width()
        );
    }
    Ok(())
}

fn cmd_mpe(args: &Args) -> Result<()> {
    let net = resolve_net(args.require("net")?)?;
    let ev = parse_evidence(&net, args.get("evidence"))?;
    let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?;
    let sched = crate::jt::schedule::Schedule::build(&jt, crate::jt::schedule::RootStrategy::Center);
    // `--cases N` switches to the batched driver: N generated evidence
    // cases swept `--batch B` lanes at a time, then re-run through the
    // single-case driver to check the lane kernels' bit-identity contract
    if args.get("cases").is_some() {
        return mpe_batched(args, &net, &jt, &sched);
    }
    let mut state = TreeState::fresh(&jt);
    let t0 = std::time::Instant::now();
    let mpe = crate::jt::mpe::most_probable_explanation(&jt, &sched, &mut state, &ev)?;
    println!("MPE given {} (found in {:?}):", ev.describe(&net), t0.elapsed());
    for v in 0..net.n() {
        let marker = if ev.get(v).is_some() { " (observed)" } else { "" };
        println!("  {:<16} = {}{}", net.vars[v].name, net.vars[v].states[mpe.assignment[v]], marker);
    }
    println!("ln P(assignment) = {:.6}", mpe.log_prob);
    Ok(())
}

/// `fastbn mpe --cases N`: the batched max-product sweep as a command —
/// generate N cases, run them through [`crate::jt::mpe`]'s lane-parallel
/// driver, and fail unless every lane matches the single-case driver
/// bit-for-bit (assignment, `to_bits`-equal log-probability, and
/// feasibility verdict alike).
fn mpe_batched(
    args: &Args,
    net: &Network,
    jt: &JunctionTree,
    sched: &crate::jt::schedule::Schedule,
) -> Result<()> {
    let spec = CaseSpec {
        n_cases: args.parse_or("cases", 2000usize)?,
        observed_fraction: args.parse_or("obs", 0.2f64)?,
        seed: args.parse_or("seed", 0xCA5Eu64)?,
    };
    let lanes = args.parse_or("batch", crate::jt::simd::LANE_WIDTH)?.max(1);
    let cases = generate(net, &spec);
    let mut bstate = crate::jt::state::BatchState::fresh(jt, lanes);
    let t0 = std::time::Instant::now();
    let batched = crate::jt::mpe::most_probable_explanation_batch(jt, sched, &mut bstate, &cases);
    let wall = t0.elapsed();

    let mut state = TreeState::fresh(jt);
    let t1 = std::time::Instant::now();
    let mut feasible = 0usize;
    let mut mismatches = 0usize;
    for (ev, got) in cases.iter().zip(&batched) {
        match (got, crate::jt::mpe::most_probable_explanation(jt, sched, &mut state, ev)) {
            (Ok(b), Ok(s)) => {
                feasible += 1;
                if b.assignment != s.assignment || b.log_prob.to_bits() != s.log_prob.to_bits() {
                    mismatches += 1;
                }
            }
            (Err(_), Err(_)) => {}
            _ => mismatches += 1,
        }
    }
    let single_wall = t1.elapsed();
    println!("{} | {}", net.stats(), jt.stats());
    println!(
        "batched MPE: {} cases × {lanes} lanes in {wall:?} ({:.1} cases/s) | single-case driver {single_wall:?} | {feasible} feasible | {mismatches} mismatches",
        cases.len(),
        cases.len() as f64 / wall.as_secs_f64()
    );
    if mismatches > 0 {
        return Err(Error::msg(format!("{mismatches} batched MPE results differ from the single-case driver")));
    }
    Ok(())
}

fn cmd_batch(args: &Args) -> Result<()> {
    let net = resolve_net(args.require("net")?)?;
    let engine: EngineKind = args.get("engine").unwrap_or("hybrid").parse()?;
    let spec = CaseSpec {
        n_cases: args.parse_or("cases", 2000usize)?,
        observed_fraction: args.parse_or("obs", 0.2f64)?,
        seed: args.parse_or("seed", 0xCA5Eu64)?,
    };
    let cfg = BatchConfig {
        engine,
        engine_cfg: engine_config(args)?,
        replicas: args.parse_or("replicas", 1usize)?,
        // `--batch B` fuses B cases per infer_batch chunk; with
        // `--engine batched` each chunk is one sweep
        fused_batch: args.parse_or("batch", 0usize)?,
    };
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    println!("{} | {}", net.stats(), jt.stats());
    let cases = generate(&net, &spec);
    let runner = BatchRunner::new(jt);
    let report = runner.run(&cases, &cfg)?;
    println!(
        "engine {} | {} cases in {:?} | throughput {:.1} cases/s | {} failures",
        report.engine,
        report.latency.count,
        report.wall,
        report.throughput(),
        report.failures.len()
    );
    println!("latency: {}", report.latency);
    println!("mean ln P(e): {:.6}", report.mean_log_z);
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let nodes = args.parse_or("nodes", 50usize)?;
    let spec = netgen::NetSpec {
        name: args.get("name").unwrap_or("generated").to_string(),
        nodes,
        arcs: args.parse_or("arcs", nodes * 3 / 2)?,
        max_parents: args.parse_or("max-parents", 3usize)?,
        card_choices: vec![(2, 0.6), (3, 0.25), (4, 0.15)],
        locality: args.parse_or("locality", 8usize)?,
        max_table: args.parse_or("max-table", 1usize << 14)?,
        alpha: args.parse_or("alpha", 1.0f64)?,
        seed: args.parse_or("seed", 1u64)?,
    };
    let net = spec.generate();
    let text = bif::write(&net);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {} ({})", path, net.stats());
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `fastbn learn`: the closed loop as a command — sample from a known
/// network (or read a CSV), learn structure + parameters, report recovery
/// quality against the generating network when there is one, and
/// optionally write the learned net as BIF.
fn cmd_learn(args: &Args) -> Result<()> {
    let cfg = crate::learn::LearnConfig {
        alpha: args.parse_or("alpha", 0.01f64)?,
        laplace: args.parse_or("laplace", 1.0f64)?,
        max_cond: args.parse_or("max-cond", crate::learn::LearnConfig::default().max_cond)?,
        threads: args.parse_or("threads", 0usize)?,
    };
    let seed = args.parse_or("seed", 0xA51Au64)?;
    let samples = args.parse_or("samples", 50_000usize)?;

    // data source: a generating network (closed loop) or a CSV file
    let (data, truth): (crate::learn::Dataset, Option<Network>) = match args.get("data") {
        Some(path) => (crate::learn::Dataset::load(path)?, None),
        None => {
            let net = resolve_net(args.require("net")?)?;
            let t0 = std::time::Instant::now();
            let data = crate::learn::Dataset::from_network(&net, samples, seed);
            println!(
                "sampled {} rows x {} vars from {} in {:?} (seed {seed})",
                data.n_rows(),
                data.n_vars(),
                net.name,
                t0.elapsed()
            );
            (data, Some(net))
        }
    };
    if let Some(path) = args.get("save-data") {
        data.save(path)?;
        println!("wrote dataset to {path}");
    }

    let default_name = match &truth {
        Some(net) => format!("{}-learned", net.name),
        None => "learned".to_string(),
    };
    let name = args.get("name").unwrap_or(&default_name);
    let report = crate::learn::learn(&data, name, &cfg)?;
    println!(
        "learned {} in {:?}: {} CI tests over {} levels (alpha {}, threads {})",
        report.net.name,
        report.elapsed,
        report.ci_tests(),
        report.levels.len(),
        cfg.alpha,
        cfg.threads
    );
    for (l, stats) in report.levels.iter().enumerate() {
        println!("  level {l}: {} edges, {} tests, {} removed", stats.edges, stats.tests, stats.removed);
    }
    let fmt_edge = |&(x, y): &(usize, usize)| format!("{}-{}", data.names()[x], data.names()[y]);
    println!(
        "skeleton ({} edges): {}",
        report.skeleton.len(),
        report.skeleton.iter().map(fmt_edge).collect::<Vec<_>>().join(" ")
    );
    println!("cpdag: {} compelled, {} reversible", report.compelled.len(), report.reversible.len());
    println!("network: {}", report.net.stats());

    if let Some(truth) = &truth {
        // skeleton recovery vs the generating net (ids align: the dataset
        // columns come from the same network)
        let mut want: Vec<(usize, usize)> = (0..truth.n())
            .flat_map(|v| truth.parents(v).iter().map(move |&p| (p.min(v), p.max(v))))
            .collect();
        want.sort_unstable();
        want.dedup();
        let got: std::collections::BTreeSet<_> = report.skeleton.iter().copied().collect();
        let want_set: std::collections::BTreeSet<_> = want.iter().copied().collect();
        let missing: Vec<String> = want_set.difference(&got).map(|e| fmt_edge(e)).collect();
        let extra: Vec<String> = got.difference(&want_set).map(|e| fmt_edge(e)).collect();
        println!(
            "skeleton vs {}: {}/{} true edges, {} missing [{}], {} extra [{}]",
            truth.name,
            want.len() - missing.len(),
            want.len(),
            missing.len(),
            missing.join(" "),
            extra.len(),
            extra.join(" ")
        );
        // posterior agreement: compile both and compare single-variable
        // priors in total variation — the closed-loop quality headline
        let jt_t = Arc::new(JunctionTree::compile(truth, TriangulationHeuristic::MinFill)?);
        let jt_l = Arc::new(JunctionTree::compile(&report.net, TriangulationHeuristic::MinFill)?);
        let cfg1 = EngineConfig::default().with_threads(1);
        let mut eng_t = EngineKind::Seq.build(Arc::clone(&jt_t), &cfg1);
        let mut eng_l = EngineKind::Seq.build(Arc::clone(&jt_l), &cfg1);
        let post_t = eng_t.infer(&mut TreeState::fresh(&jt_t), &Evidence::none())?;
        let post_l = eng_l.infer(&mut TreeState::fresh(&jt_l), &Evidence::none())?;
        let mut worst = (0usize, 0.0f64);
        for v in 0..truth.n() {
            let lv = report.net.var_id(&truth.vars[v].name)?;
            let tv = 0.5
                * post_t.probs[v]
                    .iter()
                    .zip(&post_l.probs[lv])
                    .map(|(a, b)| (a - b).abs())
                    .sum::<f64>();
            if tv > worst.1 {
                worst = (v, tv);
            }
        }
        println!("worst single-variable TV vs {}: {:.5} ({})", truth.name, worst.1, truth.vars[worst.0].name);
    }

    if let Some(path) = args.get("out") {
        std::fs::write(path, bif::write(&report.net))?;
        println!("wrote {} ({})", path, report.net.stats());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine: EngineKind = args.get("engine").unwrap_or("hybrid").parse()?;
    let cfg = engine_config(args)?;
    let bind = args.get("bind").unwrap_or("127.0.0.1:7979");
    if args.has("parent-watch") {
        spawn_parent_watch();
    }

    if args.get("nets").is_some() || args.has("fleet") {
        // fleet mode: many networks, shard groups, streaming sessions.
        // --fleet allows an *empty* fleet — the shape of a cluster
        // backend, which receives its networks via LOAD hand-offs.
        let specs: Vec<&str> = args.get("nets").unwrap_or("").split(',').filter(|s| !s.is_empty()).collect();
        if specs.is_empty() && !args.has("fleet") {
            return Err(Error::msg("--nets needs a comma-separated list of network specs"));
        }
        let fleet_cfg = FleetConfig {
            engine,
            engine_cfg: cfg,
            shards: args.parse_or("shards", 2usize)?,
            registry_capacity: args.parse_or("registry-cap", 8usize)?.max(specs.len()),
            max_exact_cost: args.parse_or("max-exact-cost", f64::INFINITY)?,
        };
        let shards = fleet_cfg.shards;
        let fleet = Arc::new(Fleet::new(fleet_cfg));
        for spec in &specs {
            let e = fleet.load(spec)?;
            println!(
                "loaded {:<16} {} cliques, {} entries, compiled in {:?}, tier {}",
                e.name, e.cliques, e.entries, e.compile_time, e.tier
            );
        }
        // observability knobs: queries slower than --slow-query-ms land in
        // the slow-query trace log; --metrics-interval dumps the full
        // exposition to stderr periodically (stdout stays protocol-clean
        // for the cluster's FLEET READY handshake)
        let slow_ms = args.parse_or("slow-query-ms", 0u64)?;
        if slow_ms > 0 {
            crate::obs::trace::set_slow_query_us(slow_ms.saturating_mul(1000));
        }
        let metrics_interval = args.parse_or("metrics-interval", 0u64)?;
        if metrics_interval > 0 {
            let dump_fleet = Arc::clone(&fleet);
            std::thread::spawn(move || loop {
                std::thread::sleep(std::time::Duration::from_secs(metrics_interval));
                eprintln!("--- metrics ---\n{}", dump_fleet.metrics_exposition());
            });
        }
        let server = FleetServer::start(Arc::clone(&fleet), bind)?;
        // machine-readable start announcement: `fastbn cluster` parses
        // this from child stdout to learn each backend's ephemeral port
        println!("FLEET READY addr={}", server.addr());
        println!(
            "serving fleet of {} nets × {} shards on {} with {} — verbs: LOAD/LEARN/USE/NETS/OBSERVE/RETRACT/COMMIT/QUERY/MPE/BATCH/CASE/STATS/METRICS/TRACE/PROFILE/PING/EVICT/QUIT",
            fleet.loaded().len(),
            shards,
            server.addr(),
            engine.label()
        );
        if args.has("smoke") {
            // scripted self-check: drive a session through our own TCP
            // socket, assert on every reply, then exit (make serve-smoke)
            return serve_smoke(&server);
        }
        if args.has("batch-smoke") {
            // scripted BATCH-verb self-check over a live socket: N
            // evidence lines in, N posterior lines out (make batch-smoke)
            return batch_smoke(&server);
        }
        if args.has("learn-smoke") {
            // scripted sample→learn→serve→QUERY round trip over a live
            // socket, learned twice to assert determinism (make learn-smoke)
            return learn_smoke(&server);
        }
        if args.has("approx-smoke") {
            // scripted cost-fallback self-check over a live socket: an
            // intractable LOAD answers from the approximate tier with CI
            // half-widths, a tractable one stays exact (make approx-smoke)
            return approx_smoke(&server);
        }
        if args.has("metrics-smoke") {
            // scripted observability self-check over a live socket:
            // interleaved QUERYs must show up in the METRICS exposition
            // with matching per-net counts, and TRACE must replay the
            // last query's span tree (make metrics-smoke)
            return metrics_smoke(&server);
        }
        if args.has("profile-smoke") {
            // scripted parallelism-profiler self-check over a live socket:
            // QUERYs under an armed PROFILE must report busy worker lanes
            // and a bounded imbalance ratio (make profile-smoke)
            return profile_smoke(&server);
        }
        // serve until killed
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    let net = resolve_net(args.require("net")?)?;
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    let server = Server::start(jt, engine, cfg, bind)?;
    println!(
        "serving {} on {} with {} — protocol: QUERY <var> [| ev=state ...] / MPE [| ev=state ...] / STATS / QUIT",
        net.name,
        server.addr(),
        engine.label()
    );
    // serve until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Drive a scripted session through a running fleet server and verify the
/// replies — the `make serve-smoke` assertion path.
fn serve_smoke(server: &FleetServer) -> Result<()> {
    let entries = server.fleet().loaded();
    if entries.len() < 2 {
        return Err(Error::msg("--smoke needs at least two loaded networks (--nets a,b)"));
    }
    let (a, b) = (&entries[0], &entries[1]);
    let jt_a = server.fleet().tree(&a.name).ok_or_else(|| Error::msg("smoke: first net missing"))?;
    let jt_b = server.fleet().tree(&b.name).ok_or_else(|| Error::msg("smoke: second net missing"))?;
    let (obs_var, obs_state) = (&jt_a.net.vars[0].name, &jt_a.net.vars[0].states[0]);
    let target_a = &jt_a.net.vars[jt_a.net.n() - 1].name;
    let target_b = &jt_b.net.vars[jt_b.net.n() - 1].name;

    // (request, prefix the reply must start with, substring it must contain)
    let script: Vec<(String, String, String)> = vec![
        ("NETS".into(), format!("OK nets={}", entries.len()), format!("{}[cliques=", a.name)),
        (format!("USE {}", a.name), format!("OK using {}", a.name), "vars=".into()),
        (format!("OBSERVE {obs_var}={obs_state}"), "OK staged 1".into(), "pending=1".into()),
        ("COMMIT".into(), "OK committed evidence=1".into(), "applied=1".into()),
        (format!("QUERY {target_a}"), "OK ".into(), "logZ=".into()),
        (format!("USE {}", b.name), format!("OK using {}", b.name), "vars=".into()),
        (format!("QUERY {target_b}"), "OK ".into(), "logZ=".into()),
        ("STATS".into(), "STATS ".into(), format!("| {} queries=1", b.name)),
        ("USE not-loaded-anywhere".into(), "ERR not loaded".into(), String::new()),
    ];
    run_script(server.addr(), &script)?;
    println!("serve-smoke passed ({} nets)", entries.len());
    Ok(())
}

/// One-connection line-protocol driver shared by the socket smokes:
/// logs every exchange and reads a fixed number of reply lines per
/// request (the `BATCH` final `CASE` answers with n lines).
struct SmokeClient {
    label: &'static str,
    stream: std::net::TcpStream,
    reader: std::io::BufReader<std::net::TcpStream>,
}

impl SmokeClient {
    fn connect(label: &'static str, addr: std::net::SocketAddr) -> Result<SmokeClient> {
        let stream = std::net::TcpStream::connect(addr)?;
        let reader = std::io::BufReader::new(stream.try_clone()?);
        Ok(SmokeClient { label, stream, reader })
    }

    /// Send one request, read `expect_lines` reply lines.
    fn ask_lines(&mut self, req: &str, expect_lines: usize) -> Result<Vec<String>> {
        use std::io::{BufRead, Write};
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut replies = Vec::with_capacity(expect_lines);
        for _ in 0..expect_lines {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            let line = line.trim().to_string();
            println!("> {req}\n< {line}");
            replies.push(line);
        }
        Ok(replies)
    }

    /// Send one request, read one reply line.
    fn ask(&mut self, req: &str) -> Result<String> {
        Ok(self.ask_lines(req, 1)?.remove(0))
    }

    /// Send one request, read a counted reply block: a header carrying
    /// `lines=<n>` (the `METRICS` reply shape) followed by n body lines.
    fn ask_block(&mut self, req: &str) -> Result<(String, Vec<String>)> {
        use std::io::BufRead;
        let header = self.ask(req)?;
        let n: usize = header
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix("lines="))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        let mut body = Vec::with_capacity(n);
        for _ in 0..n {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(Error::msg(format!("{} failed: server closed mid-block after {req:?}", self.label)));
            }
            body.push(line.trim_end().to_string());
        }
        println!("< … {n} exposition lines");
        Ok((header, body))
    }

    /// `ask` + assert the reply's prefix; returns the full reply.
    fn expect(&mut self, req: &str, prefix: &str) -> Result<String> {
        let reply = self.ask(req)?;
        if reply.starts_with(prefix) {
            Ok(reply)
        } else {
            Err(Error::msg(format!("{} failed: reply {reply:?}, wanted prefix {prefix:?}", self.label)))
        }
    }

    fn quit(mut self) -> Result<()> {
        use std::io::Write;
        self.stream.write_all(b"QUIT\n")?;
        Ok(())
    }
}

/// Drive the `BATCH` verb through a live fleet socket and verify that the
/// batched replies are byte-identical to the equivalent `QUERY` replies —
/// the `make batch-smoke` assertion path.
fn batch_smoke(server: &FleetServer) -> Result<()> {
    let entries = server.fleet().loaded();
    let first = entries.first().ok_or_else(|| Error::msg("--batch-smoke needs a loaded network (--nets a)"))?;
    let jt = server.fleet().tree(&first.name).ok_or_else(|| Error::msg("batch-smoke: net missing"))?;
    let (obs_var, obs_state) = (&jt.net.vars[0].name, &jt.net.vars[0].states[0]);
    let target = &jt.net.vars[jt.net.n() - 1].name;

    let mut client = SmokeClient::connect("batch-smoke", server.addr())?;
    client.expect(&format!("USE {}", first.name), "OK using")?;
    // references via QUERY, then the same three cases via one BATCH
    let want_obs = client.expect(&format!("QUERY {target} | {obs_var}={obs_state}"), "OK ")?;
    let want_prior = client.expect(&format!("QUERY {target}"), "OK ")?;
    client.expect(&format!("BATCH 3 {target}"), "OK batch expect=3")?;
    client.expect(&format!("CASE {obs_var}={obs_state}"), "OK case 1/3")?;
    client.expect("CASE", "OK case 2/3")?;
    let results = client.ask_lines(&format!("CASE {obs_var}={obs_state}"), 3)?;
    if results[0] != want_obs || results[1] != want_prior || results[2] != want_obs {
        return Err(Error::msg(format!(
            "batch-smoke failed: BATCH results {results:?} do not match QUERY replies [{want_obs:?}, {want_prior:?}]"
        )));
    }
    // same contract for max-product: a `BATCH <n> MPE` reply must match
    // the single-verb MPE replies byte-for-byte (the lane kernels'
    // bit-identity over the wire)
    let want_mpe_obs = client.expect(&format!("MPE | {obs_var}={obs_state}"), "OK mpe logp=")?;
    let want_mpe_prior = client.expect("MPE", "OK mpe logp=")?;
    client.expect("BATCH 2 MPE", "OK batch expect=2 target=MPE")?;
    client.expect(&format!("CASE {obs_var}={obs_state}"), "OK case 1/2")?;
    let mpe_results = client.ask_lines("CASE", 2)?;
    if mpe_results[0] != want_mpe_obs || mpe_results[1] != want_mpe_prior {
        return Err(Error::msg(format!(
            "batch-smoke failed: BATCH MPE results {mpe_results:?} do not match MPE replies [{want_mpe_obs:?}, {want_mpe_prior:?}]"
        )));
    }
    client.quit()?;
    println!("batch-smoke passed ({} cases, engine {})", 3, server.fleet().config().engine.label());
    Ok(())
}

/// Drive the `LEARN` verb through a live fleet socket: sample→learn→
/// serve→QUERY in one round trip, then learn the identical spec under a
/// second name and assert the two nets answer **byte-identically** — the
/// determinism the cluster tier's hand-off re-learning relies on. The
/// `make learn-smoke` assertion path.
fn learn_smoke(server: &FleetServer) -> Result<()> {
    let mut client = SmokeClient::connect("learn-smoke", server.addr())?;
    client.expect("LEARN smoke-a asia 20000 7", "OK learned smoke-a")?;
    client.expect("USE smoke-a", "OK using smoke-a vars=8")?;
    let first = client.expect("QUERY dysp | smoke=yes", "OK ")?;
    // the same learn spec under a different name: must serve byte-identically
    client.expect("LEARN smoke-b asia 20000 7", "OK learned smoke-b")?;
    client.expect("USE smoke-b", "OK using smoke-b vars=8")?;
    let second = client.expect("QUERY dysp | smoke=yes", "OK ")?;
    if first != second {
        return Err(Error::msg(format!(
            "learn-smoke failed: re-learned net answered {second:?}, first learned net answered {first:?}"
        )));
    }
    client.quit()?;
    println!("learn-smoke passed (sample → learn → serve → QUERY, deterministic re-learn)");
    Ok(())
}

/// Drive the cost-based engine fallback through a live fleet socket: an
/// intractable network must be served by the approximate tier (the reply
/// carrying its tier and CI half-width), while a tractable one keeps the
/// exact tier — the `make approx-smoke` assertion path.
fn approx_smoke(server: &FleetServer) -> Result<()> {
    if !server.fleet().config().max_exact_cost.is_finite() {
        return Err(Error::msg("--approx-smoke needs a finite --max-exact-cost so the fallback can trigger"));
    }
    let hard = resolve_net("intractable-sim")?;
    let target = &hard.vars[hard.n() - 1].name;

    let mut client = SmokeClient::connect("approx-smoke", server.addr())?;
    let loaded = client.expect("LOAD intractable-sim", "OK loaded intractable-sim")?;
    if !loaded.contains("tier=approx") || !loaded.contains("cost=") {
        return Err(Error::msg(format!(
            "approx-smoke failed: LOAD reply {loaded:?} did not fall back (wanted tier=approx cost=…)"
        )));
    }
    client.expect("LOAD asia", "OK loaded asia")?;
    client.expect("USE intractable-sim", "OK using intractable-sim")?;
    let reply = client.expect(&format!("QUERY {target}"), "OK ")?;
    if !reply.contains(" tier=approx ci95=") || !reply.contains(" ess=") {
        return Err(Error::msg(format!(
            "approx-smoke failed: approx QUERY reply {reply:?} lacks tier=approx ci95=…/ess=…"
        )));
    }
    // determinism over the wire: the same query answers byte-identically
    let again = client.expect(&format!("QUERY {target}"), "OK ")?;
    if again != reply {
        return Err(Error::msg(format!(
            "approx-smoke failed: repeated approx QUERY was not deterministic ({reply:?} vs {again:?})"
        )));
    }
    client.expect("USE asia", "OK using asia")?;
    let exact = client.expect("QUERY dysp | smoke=yes", "OK ")?;
    if exact.contains("tier=approx") {
        return Err(Error::msg(format!("approx-smoke failed: tractable net answered approx: {exact:?}")));
    }
    let nets = client.expect("NETS", "OK nets=")?;
    if !nets.contains("tier=approx") || !nets.contains("tier=exact") {
        return Err(Error::msg(format!("approx-smoke failed: NETS reply {nets:?} lacks both tiers")));
    }
    let stats = client.expect("STATS", "STATS ")?;
    if !stats.contains("tier=approx") {
        return Err(Error::msg(format!("approx-smoke failed: STATS reply {stats:?} lacks tier=approx")));
    }
    client.quit()?;
    println!("approx-smoke passed (intractable-sim → approx tier with ci95, asia → exact tier)");
    Ok(())
}

/// Drive the observability surface through a live fleet socket: three
/// QUERYs must show up in the `METRICS` exposition with a per-net counter
/// and histogram count of exactly three, and `TRACE` must toggle and
/// replay the last query's span tree — the `make metrics-smoke` assertion
/// path.
fn metrics_smoke(server: &FleetServer) -> Result<()> {
    let mut client = SmokeClient::connect("metrics-smoke", server.addr())?;
    client.expect("LOAD asia", "OK loaded asia")?;
    client.expect("USE asia", "OK using asia")?;
    client.expect("TRACE on", "OK trace on")?;
    for _ in 0..3 {
        client.expect("QUERY dysp | smoke=yes", "OK ")?;
    }
    let (header, body) = client.ask_block("METRICS")?;
    if !header.starts_with("OK metrics lines=") {
        return Err(Error::msg(format!("metrics-smoke failed: METRICS header {header:?}")));
    }
    let text = body.join("\n");
    let checks: &[(&str, u64)] = &[
        ("fastbn_queries_total{net=\"asia\"}", 3),
        ("fastbn_query_latency_us_count{net=\"asia\"}", 3),
        ("fastbn_query_latency_us_bucket{net=\"asia\",le=\"+Inf\"}", 3),
    ];
    for (key, want) in checks {
        let got = crate::obs::scrape::value(&text, key);
        if got != Some(*want) {
            return Err(Error::msg(format!("metrics-smoke failed: {key} = {got:?}, wanted {want}")));
        }
    }
    client.expect("TRACE last", "OK trace total_us=")?;
    client.expect("TRACE off", "OK trace off")?;
    client.quit()?;
    println!("metrics-smoke passed (3 queries counted, latency histogram complete, trace replayed)");
    Ok(())
}

/// Drive the `PROFILE` verb through a live fleet socket: three QUERYs
/// under an armed profiler must yield region report lines with non-zero
/// busy time on at least one worker lane and a load-imbalance ratio
/// bounded by the lane count — the fleet half of `make profile-smoke`.
fn profile_smoke(server: &FleetServer) -> Result<()> {
    // a mid-size suite net so per-lane busy time is comfortably measurable
    let net = resolve_net("hailfinder-sim")?;
    let (obs_var, obs_state) = (&net.vars[0].name, &net.vars[0].states[0]);
    let target = &net.vars[net.n() - 1].name;

    let mut client = SmokeClient::connect("profile-smoke", server.addr())?;
    client.expect("LOAD hailfinder-sim", "OK loaded hailfinder-sim")?;
    client.expect("USE hailfinder-sim", "OK using hailfinder-sim")?;
    client.expect("PROFILE on", "OK profile on")?;
    for _ in 0..3 {
        client.expect(&format!("QUERY {target} | {obs_var}={obs_state}"), "OK ")?;
    }
    let (header, body) = client.ask_block("PROFILE")?;
    if !header.starts_with("OK profile lines=") {
        return Err(Error::msg(format!("profile-smoke failed: PROFILE header {header:?}")));
    }
    if body.is_empty() {
        return Err(Error::msg("profile-smoke failed: no pool regions profiled (queries never hit the pool)"));
    }
    let mut busy_lanes = 0usize;
    for line in &body {
        let num = |key: &str| -> Result<f64> {
            line.split_whitespace()
                .find_map(|tok| tok.strip_prefix(key))
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| Error::msg(format!("profile-smoke failed: no numeric {key} in {line:?}")))
        };
        let workers = num("workers=")?;
        let imbalance = num("imbalance=")?;
        if imbalance < 1.0 - 1e-9 || imbalance > workers + 1e-9 {
            return Err(Error::msg(format!(
                "profile-smoke failed: imbalance {imbalance} outside [1, workers={workers}] in {line:?}"
            )));
        }
        let busy = line
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix("busy_us="))
            .ok_or_else(|| Error::msg(format!("profile-smoke failed: no busy_us in {line:?}")))?;
        busy_lanes += busy.split(',').filter(|v| *v != "0").count();
    }
    if busy_lanes == 0 {
        return Err(Error::msg("profile-smoke failed: every worker lane reports zero busy time"));
    }
    client.expect("PROFILE off", "OK profile off")?;
    client.quit()?;
    println!(
        "profile-smoke passed ({} regions, {busy_lanes} busy lanes, imbalance within the worker bound)",
        body.len()
    );
    Ok(())
}

/// Drive the cluster-wide scrape through a live front-tier socket: the
/// merged `METRICS` block must list every backend's labeled series and an
/// aggregate query counter matching the interleaved QUERYs — the cluster
/// half of `make metrics-smoke`.
fn cluster_metrics_smoke(server: &ClusterServer, specs: &[String], n_backends: usize) -> Result<()> {
    let net = resolve_net(&specs[0])?;
    let target = &net.vars[net.n() - 1].name;

    let mut client = SmokeClient::connect("cluster-metrics-smoke", server.addr())?;
    client.expect(&format!("USE {}", net.name), &format!("OK using {}", net.name))?;
    client.expect(&format!("QUERY {target}"), "OK ")?;
    let (header, body) = client.ask_block("METRICS")?;
    let want_header = format!("OK metrics backends={n_backends} lines=");
    if !header.starts_with(&want_header) {
        return Err(Error::msg(format!(
            "cluster-metrics-smoke failed: METRICS header {header:?}, wanted prefix {want_header:?}"
        )));
    }
    let text = body.join("\n");
    for i in 0..n_backends {
        let label = format!("backend=\"b{i}\"");
        if !text.contains(&label) {
            return Err(Error::msg(format!("cluster-metrics-smoke failed: no series labeled {label} in scrape")));
        }
    }
    let key = format!("fastbn_queries_total{{net=\"{}\"}}", net.name);
    let got = crate::obs::scrape::value(&text, &key);
    if got != Some(1) {
        return Err(Error::msg(format!("cluster-metrics-smoke failed: aggregate {key} = {got:?}, wanted 1")));
    }
    client.quit()?;
    println!("cluster-metrics-smoke passed ({n_backends} backends scraped and merged)");
    Ok(())
}

/// Drive the cluster-correlated tracing surface through a live front-tier
/// socket: an armed `TRACE` must mint a qid for each `QUERY`, `TRACE
/// <qid>` must assemble exactly one cross-tier timeline (front route →
/// owning backend → its span tree), and the merged `PROFILE` scrape must
/// prefix every region line with its backend — the cluster half of
/// `make profile-smoke`.
fn cluster_profile_smoke(server: &ClusterServer, specs: &[String], n_backends: usize) -> Result<()> {
    let net = resolve_net(&specs[0])?;
    let target = &net.vars[net.n() - 1].name;

    let mut client = SmokeClient::connect("cluster-profile-smoke", server.addr())?;
    client.expect(&format!("USE {}", net.name), &format!("OK using {}", net.name))?;
    client.expect("TRACE on", "OK trace on backends=")?;
    let reply = client.expect(&format!("QUERY {target}"), "OK ")?;
    let qid = reply
        .split_whitespace()
        .rev()
        .find_map(|tok| tok.strip_prefix("qid="))
        .ok_or_else(|| Error::msg(format!("cluster-profile-smoke failed: no qid= in QUERY reply {reply:?}")))?
        .to_string();
    let timeline = client.expect(&format!("TRACE {qid}"), &format!("OK trace qid={qid} "))?;
    for want in ["net=", "backend=\"", "route_us=", "total_us="] {
        if !timeline.contains(want) {
            return Err(Error::msg(format!("cluster-profile-smoke failed: timeline {timeline:?} lacks {want}")));
        }
    }
    // exactly one merged timeline: one backend tag, one span tree
    let tags = timeline.matches("backend=\"").count();
    if tags != 1 {
        return Err(Error::msg(format!(
            "cluster-profile-smoke failed: wanted exactly one backend timeline, got {tags}: {timeline:?}"
        )));
    }
    // the merged PROFILE scrape labels every region line with its backend
    client.expect("PROFILE on", "OK profile on backends=")?;
    client.expect(&format!("QUERY {target}"), "OK ")?;
    let (header, body) = client.ask_block("PROFILE")?;
    let want_header = format!("OK profile backends={n_backends} lines=");
    if !header.starts_with(&want_header) {
        return Err(Error::msg(format!(
            "cluster-profile-smoke failed: PROFILE header {header:?}, wanted prefix {want_header:?}"
        )));
    }
    if body.is_empty() {
        return Err(Error::msg("cluster-profile-smoke failed: no backend reported any profiled region"));
    }
    for line in &body {
        if !line.starts_with("backend=\"") {
            return Err(Error::msg(format!("cluster-profile-smoke failed: unlabeled PROFILE line {line:?}")));
        }
    }
    client.expect("PROFILE off", "OK profile off backends=")?;
    client.expect("TRACE off", "OK trace off backends=")?;
    client.quit()?;
    println!("cluster-profile-smoke passed ({n_backends} backends, qid {qid} traced cross-tier)");
    Ok(())
}

/// Drive a scripted line-protocol session against `addr`, checking each
/// reply's prefix and (optionally) a required substring — the assertion
/// loop shared by the serve and cluster smokes.
fn run_script(addr: std::net::SocketAddr, script: &[(String, String, String)]) -> Result<()> {
    let mut client = SmokeClient::connect("smoke", addr)?;
    for (request, prefix, contains) in script {
        let reply = client.ask(request)?;
        if !reply.starts_with(prefix.as_str()) {
            return Err(Error::msg(format!("smoke failed: {request:?} replied {reply:?}, wanted prefix {prefix:?}")));
        }
        if !contains.is_empty() && !reply.contains(contains.as_str()) {
            return Err(Error::msg(format!("smoke failed: {request:?} replied {reply:?}, wanted {contains:?}")));
        }
    }
    client.quit()
}

/// Exit when our stdin reaches EOF — i.e. when the parent that spawned
/// us with a piped stdin dies or drops the pipe. Cluster backends run
/// with this watch so a killed front tier never strands orphans.
fn spawn_parent_watch() {
    std::thread::spawn(|| {
        use std::io::Read;
        let mut sink = [0u8; 64];
        let mut stdin = std::io::stdin();
        loop {
            match stdin.read(&mut sink) {
                Ok(0) | Err(_) => std::process::exit(0),
                Ok(_) => {}
            }
        }
    });
}

/// Children killed (and reaped) however `cmd_cluster` exits.
#[derive(Default)]
struct ChildGuard {
    children: Vec<std::process::Child>,
}

impl ChildGuard {
    fn kill_all(&mut self) {
        for child in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
        self.children.clear();
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        self.kill_all();
    }
}

/// Read child stdout lines until the `FLEET READY addr=…` announcement.
fn read_ready_addr(reader: &mut impl std::io::BufRead, i: usize) -> Result<std::net::SocketAddr> {
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Err(Error::msg(format!("backend {i} exited before announcing an address")));
        }
        if let Some(addr) = line.trim().strip_prefix("FLEET READY addr=") {
            return addr.parse().map_err(|_| Error::msg(format!("backend {i} announced a bad address {addr:?}")));
        }
    }
}

fn cmd_cluster(args: &Args) -> Result<()> {
    // already-running `fastbn serve --fleet` processes to adopt over TCP
    // (the static-list twin of the `JOIN <addr>` verb)
    let join_hosts: Vec<std::net::SocketAddr> = match args.get("join-hosts") {
        None => Vec::new(),
        Some(text) => text
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().map_err(|_| Error::msg(format!("bad --join-hosts address {s:?}"))))
            .collect::<Result<_>>()?,
    };
    // with external hosts to adopt, spawning no children is legitimate
    let n_backends: usize = args.parse_or("backends", if join_hosts.is_empty() { 2usize } else { 0 })?;
    if n_backends == 0 && join_hosts.is_empty() {
        return Err(Error::msg("--backends must be ≥ 1 (or pass --join-hosts)"));
    }
    let engine_text = args.get("engine").unwrap_or("hybrid");
    let _validated: EngineKind = engine_text.parse()?; // fail before spawning anything
    let bind = args.get("bind").unwrap_or("127.0.0.1:7878");
    let smoke = args.has("smoke");
    let metrics_smoke = args.has("metrics-smoke");
    let profile_smoke = args.has("profile-smoke");
    let specs: Vec<String> = match args.get("nets") {
        Some(text) => text.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect(),
        None if smoke || metrics_smoke || profile_smoke => vec!["asia".into(), "cancer".into()],
        None => Vec::new(),
    };
    if smoke && specs.len() < 2 {
        return Err(Error::msg("--smoke needs at least two networks (--nets a,b)"));
    }

    // each backend is a real child process: `fastbn serve --fleet` on an
    // ephemeral port, announced over stdout, watching our stdin so it
    // dies with us
    let exe = std::env::current_exe()?;
    let shards = args.parse_or("shards", 2usize)?.to_string();
    let threads = args.parse_or("threads", 0usize)?.to_string();
    let registry_cap = args.parse_or("registry-cap", 8usize)?.to_string();
    // forwarded so every backend applies the same exact-vs-approx policy
    // (`f64` round-trips "inf" through Display/FromStr)
    let max_exact_cost = args.parse_or("max-exact-cost", f64::INFINITY)?.to_string();
    let samples = args.parse_or("samples", EngineConfig::default().samples)?.to_string();
    let mut children = ChildGuard::default();
    let mut addrs = Vec::new();
    for i in 0..n_backends {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(["serve", "--fleet", "--bind", "127.0.0.1:0", "--parent-watch"])
            .args(["--engine", engine_text])
            .args(["--shards", &shards])
            .args(["--threads", &threads])
            .args(["--registry-cap", &registry_cap])
            .args(["--max-exact-cost", &max_exact_cost])
            .args(["--samples", &samples])
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit());
        let mut child = cmd.spawn()?;
        let stdout = child.stdout.take().ok_or_else(|| Error::msg("backend stdout was not captured"))?;
        children.children.push(child);
        let mut reader = std::io::BufReader::new(stdout);
        addrs.push(read_ready_addr(&mut reader, i)?);
        // keep draining the child's stdout so it can never block on a
        // full pipe once it starts logging
        std::thread::spawn(move || {
            use std::io::BufRead;
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                sink.clear();
            }
        });
    }

    let cluster_cfg = ClusterConfig {
        replicas: args.parse_or("replicas", 1usize)?,
        vnodes: args.parse_or("vnodes", 64usize)?,
        ..Default::default()
    };
    let cluster = Cluster::start(cluster_cfg)?;
    for addr in &addrs {
        let id = cluster.join(*addr)?;
        println!("backend {id} ready at {addr}");
    }
    for addr in &join_hosts {
        let id = cluster.join(*addr)?;
        println!("backend {id} adopted at {addr}");
    }
    let n_backends = n_backends + join_hosts.len();
    for spec in &specs {
        let reply = cluster.load(spec);
        println!("{reply}");
        if !reply.starts_with("OK") {
            return Err(Error::msg(reply));
        }
    }
    let server = ClusterServer::start(Arc::clone(&cluster), bind)?;
    println!(
        "cluster front tier on {} over {n_backends} backends ({} nets) — verbs: LOAD/LEARN/USE/NETS/OBSERVE/RETRACT/COMMIT/QUERY/MPE/BATCH/CASE/STATS/METRICS/TRACE/PROFILE/PING/TOPO/JOIN/HANDOFF/QUIT",
        server.addr(),
        specs.len()
    );
    if smoke {
        let outcome = cluster_smoke(&server, &specs, n_backends);
        server.shutdown();
        cluster.shutdown();
        children.kill_all();
        return outcome;
    }
    if metrics_smoke {
        let outcome = cluster_metrics_smoke(&server, &specs, n_backends);
        server.shutdown();
        cluster.shutdown();
        children.kill_all();
        return outcome;
    }
    if profile_smoke {
        let outcome = cluster_profile_smoke(&server, &specs, n_backends);
        server.shutdown();
        cluster.shutdown();
        children.kill_all();
        return outcome;
    }
    // serve until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Drive a scripted session through a running cluster front tier and
/// verify the replies — the `make cluster-smoke` assertion path.
fn cluster_smoke(server: &ClusterServer, specs: &[String], n_backends: usize) -> Result<()> {
    let net_a = resolve_net(&specs[0])?;
    let net_b = resolve_net(&specs[1])?;
    let (obs_var, obs_state) = (&net_a.vars[0].name, &net_a.vars[0].states[0]);
    let target_a = &net_a.vars[net_a.n() - 1].name;
    let target_b = &net_b.vars[net_b.n() - 1].name;

    // (request, prefix the reply must start with, substring it must contain)
    let script: Vec<(String, String, String)> = vec![
        ("PING".into(), "OK pong".into(), format!("alive={n_backends}")),
        (format!("LOAD {}", specs[0]), format!("OK loaded {}", net_a.name), "backend=".into()),
        ("TOPO".into(), format!("OK backends={n_backends}"), "alive=true".into()),
        (format!("USE {}", net_a.name), format!("OK using {}", net_a.name), "vars=".into()),
        (format!("OBSERVE {obs_var}={obs_state}"), "OK staged 1".into(), "pending=1".into()),
        ("COMMIT".into(), "OK committed evidence=1".into(), "applied=1".into()),
        (format!("QUERY {target_a}"), "OK ".into(), "logZ=".into()),
        // max-product through the front tier: the committed observation
        // must appear in the assignment
        ("MPE".into(), "OK mpe logp=".into(), format!("{obs_var}={obs_state}")),
        (format!("USE {}", net_b.name), format!("OK using {}", net_b.name), "vars=".into()),
        (format!("QUERY {target_b}"), "OK ".into(), "logZ=".into()),
        // switching nets reset the evidence mirror: the hand-off export
        // for this session is empty
        ("HANDOFF".into(), format!("OK handoff net={}", net_b.name), "evidence=0".into()),
        ("JOIN nonsense".into(), "ERR usage: JOIN".into(), String::new()),
        ("NETS".into(), "OK nets=".into(), format!("{}[", net_a.name)),
        ("STATS".into(), "STATS cluster".into(), format!("backends={n_backends}")),
        ("USE not-loaded-anywhere".into(), "ERR not loaded".into(), String::new()),
    ];
    run_script(server.addr(), &script)?;
    println!("cluster-smoke passed ({n_backends} backends, {} nets)", specs.len());
    Ok(())
}

/// `fastbn profile`: arm the pool parallelism profiler and the span
/// tracer, compile the network and run `--queries` inferences locally,
/// then report where the wall time went — junction-tree phases from the
/// captured span trees (`jt.compile`, `hybrid.up`, `hybrid.down`, …) and
/// per-worker lane busy/idle from the profiler store. The CLI face of
/// the fleet's `PROFILE` verb.
fn cmd_profile(args: &Args) -> Result<()> {
    let net = Arc::new(resolve_net(args.require("net")?)?);
    let engine_kind: EngineKind = args.get("engine").unwrap_or("hybrid").parse()?;
    let cfg = engine_config(args)?;
    let queries = args.parse_or("queries", 16usize)?.max(1);
    let ev = parse_evidence(&net, args.get("evidence"))?;

    crate::obs::profile::set_armed(true);
    crate::obs::trace::set_enabled(true);
    let outcome = profile_window(&net, engine_kind, &cfg, queries, &ev);
    let regions = crate::obs::profile::snapshot();
    crate::obs::trace::set_enabled(false);
    crate::obs::profile::set_armed(false);
    let (compile_trace, query_trace, wall, engine_name) = outcome?;

    println!("network: {}", net.stats());
    println!(
        "{queries} queries with {engine_name} in {wall:?} ({:.1} queries/s)",
        queries as f64 / wall.as_secs_f64()
    );
    for (title, trace) in [("compile phases", &compile_trace), ("last query phases", &query_trace)] {
        let Some(trace) = trace else { continue };
        println!("{title} ({} µs total):", trace.total_us);
        for s in &trace.spans {
            let note = if s.note.is_empty() { String::new() } else { format!(" [{}]", s.note) };
            println!("  {:>9} µs  {}{}{note}", s.dur_us, ". ".repeat(s.depth), s.name);
        }
    }
    if regions.is_empty() {
        println!("pool regions: none entered (sequential path — pass --threads 2 or more)");
    } else {
        println!("pool regions (per-worker lanes over the whole window):");
        for p in &regions {
            println!("  {}", p.render_line());
        }
    }
    Ok(())
}

/// The measured window of [`cmd_profile`], split out so the arming
/// toggles around the call wrap every early return.
fn profile_window(
    net: &Arc<Network>,
    engine_kind: EngineKind,
    cfg: &EngineConfig,
    queries: usize,
    ev: &Evidence,
) -> Result<(Option<crate::obs::trace::Trace>, Option<crate::obs::trace::Trace>, std::time::Duration, String)> {
    let (mut engine, mut state, compile_trace): (Box<dyn Engine>, TreeState, Option<crate::obs::trace::Trace>) =
        if engine_kind == EngineKind::Approx {
            // no junction tree: the approx engine samples the network
            // directly, so only its round spans show up below
            (Box::new(ApproxEngine::from_net(Arc::clone(net), cfg)), TreeState::detached(), None)
        } else {
            let jt = Arc::new(JunctionTree::compile(net, TriangulationHeuristic::MinFill)?);
            let compile_trace = crate::obs::trace::last();
            (engine_kind.build(Arc::clone(&jt), cfg), TreeState::fresh(&jt), compile_trace)
        };
    let t0 = std::time::Instant::now();
    for _ in 0..queries {
        engine.infer(&mut state, ev)?;
    }
    let wall = t0.elapsed();
    Ok((compile_trace, crate::obs::trace::last(), wall, engine.name().to_string()))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let net = resolve_net(args.require("net")?)?;
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    let threads: Vec<usize> = args
        .get("threads")
        .unwrap_or("1,2,4,8,16,32")
        .split(',')
        .map(|t| t.parse().map_err(|_| Error::msg("bad --threads list")))
        .collect::<Result<_>>()?;
    println!("calibrating cost model...");
    let model = CostModel::calibrate();
    println!("{model:?}");
    let cfg = EngineConfig::default();
    println!("modeled per-case seconds on {} (see DESIGN.md §3 hardware substitution):", net.name);
    print!("{:>10}", "t");
    for kind in EngineKind::ALL {
        print!("{:>14}", kind.label());
    }
    println!();
    for &t in &threads {
        print!("{t:>10}");
        for kind in EngineKind::ALL {
            let s = simulate_seconds(kind, &jt, t, &cfg, &model);
            print!("{:>14.6}", s);
        }
        println!();
    }
    let (best_t, best) = best_over_threads(EngineKind::Hybrid, &jt, &threads, &cfg, &model);
    println!("hybrid best: {best:.6}s at t={best_t}");
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    let net = embedded::asia();
    let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill)?);
    let ev = Evidence::from_pairs(&net, &[("dysp", "yes")])?;
    let exact = crate::infer::exact::enumerate(&net, &ev)?;
    for kind in EngineKind::ALL {
        let mut engine = kind.build(Arc::clone(&jt), &EngineConfig { threads: 2, min_chunk: 4, ..Default::default() });
        let mut state = TreeState::fresh(&jt);
        let post = engine.infer(&mut state, &ev)?;
        let mut worst = 0.0f64;
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                worst = worst.max((post.probs[v][s] - exact.probs[v][s]).abs());
            }
        }
        println!("{:<14} max |Δ| vs oracle = {:.2e}  {}", kind.label(), worst, if worst < 1e-9 { "OK" } else { "FAIL" });
        if worst >= 1e-9 {
            return Err(Error::msg(format!("{kind} disagrees with the oracle")));
        }
    }
    println!("selftest passed");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parsing() {
        let argv: Vec<String> =
            ["--net", "asia", "--threads=4", "pos1"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv).unwrap();
        assert_eq!(a.get("net"), Some("asia"));
        assert_eq!(a.parse_or("threads", 0usize).unwrap(), 4);
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(a.require("missing").is_err());
        assert!(a.parse_or::<usize>("net", 0).is_err());
    }

    #[test]
    fn boolean_switches_parse_without_values() {
        let argv: Vec<String> = ["--smoke", "--nets", "asia,cancer"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv).unwrap();
        assert!(a.has("smoke"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get("nets"), Some("asia,cancer"));
        // the cluster-backend switches are switches too
        let a = Args::parse(&["--fleet".to_string(), "--parent-watch".to_string()]).unwrap();
        assert!(a.has("fleet") && a.has("parent-watch"));
        // a trailing switch needs no value
        let a = Args::parse(&["--smoke".to_string()]).unwrap();
        assert!(a.has("smoke"));
        // non-switch flags still demand one — a following flag is not it
        assert!(Args::parse(&["--evidence".to_string()]).is_err());
        assert!(Args::parse(&["--evidence".to_string(), "--engine".to_string()]).is_err());
    }

    #[test]
    fn serve_smoke_runs_a_two_net_fleet() {
        let argv: Vec<String> = [
            "serve", "--nets", "asia,cancer", "--shards", "2", "--engine", "seq", "--threads", "1",
            "--bind", "127.0.0.1:0", "--smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn batch_smoke_drives_the_batch_verb_through_a_socket() {
        let argv: Vec<String> = [
            "serve", "--nets", "asia", "--shards", "1", "--engine", "batched", "--batch", "4",
            "--threads", "2", "--bind", "127.0.0.1:0", "--batch-smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn batch_command_runs_fused_with_the_batched_engine() {
        let argv: Vec<String> = [
            "batch", "--net", "asia", "--cases", "10", "--engine", "batched", "--batch", "4", "--threads", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn cluster_rejects_bad_arguments_before_spawning() {
        // all of these must fail during validation — no child processes
        // (under `cargo test` current_exe is the test binary, so actually
        // spawning here would be wrong twice over)
        assert_ne!(run(vec!["cluster".into(), "--backends".into(), "0".into()]), 0);
        assert_ne!(run(vec!["cluster".into(), "--backends".into(), "two".into()]), 0);
        assert_ne!(run(vec!["cluster".into(), "--engine".into(), "warp-drive".into()]), 0);
        let argv: Vec<String> =
            ["cluster", "--smoke", "--nets", "asia"].iter().map(|s| s.to_string()).collect();
        assert_ne!(run(argv), 0); // --smoke needs two nets
    }

    #[test]
    fn resolve_embedded_paper_and_missing() {
        assert!(resolve_net("asia").is_ok());
        assert!(resolve_net("pigs-sim").is_ok());
        assert!(resolve_net("no-such-net").is_err());
    }

    #[test]
    fn evidence_parser() {
        let net = embedded::asia();
        let ev = parse_evidence(&net, Some("smoke=yes,xray=no")).unwrap();
        assert_eq!(ev.len(), 2);
        assert!(parse_evidence(&net, Some("bogus")).is_err());
        assert!(parse_evidence(&net, None).unwrap().is_empty());
    }

    #[test]
    fn selftest_passes() {
        cmd_selftest().unwrap();
    }

    #[test]
    fn query_command_runs() {
        let argv: Vec<String> = ["query", "--net", "asia", "--target", "lung", "--evidence", "smoke=yes", "--threads", "1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn unknown_command_errors() {
        assert_ne!(run(vec!["frobnicate".into()]), 0);
    }

    #[test]
    fn info_and_nets_commands_run() {
        let argv: Vec<String> = ["info", "--net", "asia"].iter().map(|s| s.to_string()).collect();
        assert_eq!(run(argv), 0);
        assert_eq!(run(vec!["help".into()]), 0);
    }

    #[test]
    fn batch_command_runs_small() {
        let argv: Vec<String> =
            ["batch", "--net", "asia", "--cases", "5", "--engine", "seq", "--threads", "1"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn mpe_command_runs() {
        let argv: Vec<String> = ["mpe", "--net", "asia", "--evidence", "dysp=yes"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn mpe_command_runs_batched_and_self_verifies() {
        // exit code 0 means every batched lane matched the single-case
        // driver bit-for-bit (mpe_batched errors on any mismatch)
        let argv: Vec<String> = [
            "mpe", "--net", "asia", "--cases", "13", "--obs", "0.3", "--batch", "4", "--seed", "11",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn learn_command_closes_the_loop() {
        let out = std::env::temp_dir().join(format!("fastbn-learn-{}.bif", std::process::id()));
        let csv = std::env::temp_dir().join(format!("fastbn-learn-{}.csv", std::process::id()));
        let argv: Vec<String> = [
            "learn", "--net", "cancer", "--samples", "4000", "--seed", "9", "--threads", "2",
            "--out", out.to_str().unwrap(), "--save-data", csv.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
        // the written BIF is a loadable spec...
        let net = resolve_net(out.to_str().unwrap()).unwrap();
        assert_eq!(net.n(), 5);
        // ...and the saved CSV feeds the --data path
        let argv: Vec<String> = ["learn", "--data", csv.to_str().unwrap(), "--name", "from-csv"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(run(argv), 0);
        let _ = std::fs::remove_file(out);
        let _ = std::fs::remove_file(csv);
    }

    #[test]
    fn learn_command_rejects_bad_arguments() {
        assert_ne!(run(vec!["learn".into()]), 0); // no --net and no --data
        let argv: Vec<String> =
            ["learn", "--net", "no-such-net", "--samples", "10"].iter().map(|s| s.to_string()).collect();
        assert_ne!(run(argv), 0);
    }

    #[test]
    fn query_command_runs_with_the_approx_engine() {
        let argv: Vec<String> = [
            "query", "--net", "asia", "--target", "lung", "--evidence", "smoke=yes", "--engine", "approx",
            "--samples", "5000", "--threads", "2", "--seed", "7",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn approx_smoke_drives_the_fallback_through_a_socket() {
        let argv: Vec<String> = [
            "serve", "--fleet", "--shards", "1", "--engine", "seq", "--threads", "2", "--samples", "20000",
            "--max-exact-cost", "1e6", "--bind", "127.0.0.1:0", "--approx-smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn approx_smoke_requires_a_finite_cost_threshold() {
        // without --max-exact-cost the fallback can never trigger — the
        // smoke must refuse to run rather than compile intractable-sim
        let argv: Vec<String> = [
            "serve", "--fleet", "--shards", "1", "--engine", "seq", "--threads", "1",
            "--bind", "127.0.0.1:0", "--approx-smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_ne!(run(argv), 0);
    }

    #[test]
    fn metrics_smoke_drives_the_verbs_through_a_socket() {
        // the smoke flips the process-wide trace toggle over the wire;
        // serialize with the other toggle-flipping tests and reset after
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let argv: Vec<String> = [
            "serve", "--fleet", "--shards", "1", "--engine", "seq", "--threads", "1",
            "--slow-query-ms", "1000", "--bind", "127.0.0.1:0", "--metrics-smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let outcome = run(argv);
        crate::obs::trace::set_enabled(false);
        crate::obs::trace::set_slow_query_us(0);
        assert_eq!(outcome, 0);
    }

    #[test]
    fn profile_command_reports_phases_and_lanes() {
        // flips the process-wide profiler/tracer toggles; serialize with
        // the other toggle-flipping tests and reset after
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let argv: Vec<String> = [
            "profile", "--net", "asia", "--queries", "4", "--threads", "2", "--evidence", "smoke=yes",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let outcome = run(argv);
        crate::obs::trace::set_enabled(false);
        crate::obs::profile::set_armed(false);
        assert_eq!(outcome, 0);
    }

    #[test]
    fn profile_smoke_drives_the_verb_through_a_socket() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let argv: Vec<String> = [
            "serve", "--fleet", "--shards", "1", "--engine", "hybrid", "--threads", "2",
            "--bind", "127.0.0.1:0", "--profile-smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let outcome = run(argv);
        crate::obs::profile::set_armed(false);
        assert_eq!(outcome, 0);
    }

    #[test]
    fn learn_smoke_drives_the_verb_through_a_socket() {
        let argv: Vec<String> = [
            "serve", "--fleet", "--shards", "1", "--engine", "seq", "--threads", "1",
            "--bind", "127.0.0.1:0", "--learn-smoke",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
    }

    #[test]
    fn generate_roundtrips_through_a_file() {
        let path = std::env::temp_dir().join(format!("fastbn-gen-{}.bif", std::process::id()));
        let argv: Vec<String> = [
            "generate", "--nodes", "12", "--seed", "9", "--out",
            path.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(run(argv), 0);
        // the generated file is a loadable network spec
        let net = resolve_net(path.to_str().unwrap()).unwrap();
        assert_eq!(net.n(), 12);
        let _ = std::fs::remove_file(path);
    }
}
