//! Inference-level APIs: queries over a calibrated tree, the brute-force
//! oracle, and the benchmark test-case generator.

pub mod approx;
pub mod cases;
pub mod exact;
pub mod query;
