//! Brute-force exact inference by joint enumeration — the correctness
//! oracle for every engine.
//!
//! Exponential in the number of variables, so only usable on small
//! networks (≲ 20 binary variables); the property tests compare every
//! engine's posteriors against this on random tiny networks.

use crate::bn::network::Network;
use crate::jt::evidence::Evidence;
use crate::{Error, Result};

/// Exact posteriors `P(v | e)` for all variables plus `ln P(e)`, by
/// enumerating the full joint.
pub struct ExactPosteriors {
    /// `probs[v][s] = P(v = s | e)`.
    pub probs: Vec<Vec<f64>>,
    /// `ln P(e)`.
    pub log_z: f64,
}

/// Enumerate the joint distribution and accumulate the evidence-consistent
/// mass per variable/state.
pub fn enumerate(net: &Network, ev: &Evidence) -> Result<ExactPosteriors> {
    let n = net.n();
    let cards = net.cards();
    let total_states: usize = cards.iter().try_fold(1usize, |acc, &c| acc.checked_mul(c)).ok_or_else(|| {
        Error::msg("joint too large to enumerate")
    })?;
    if total_states > 1 << 26 {
        return Err(Error::msg(format!("joint has {total_states} states; oracle refuses > 2^26")));
    }

    let order = net.topo_order()?;
    let mut probs = vec![vec![0.0f64; 0]; n];
    for v in 0..n {
        probs[v] = vec![0.0; cards[v]];
    }
    let mut z = 0.0f64;

    let mut assignment = vec![0usize; n];
    'outer: loop {
        // joint probability of the current assignment, if consistent
        let mut consistent = true;
        for &(v, s) in &ev.obs {
            if assignment[v] != s {
                consistent = false;
                break;
            }
        }
        if consistent {
            let mut p = 1.0f64;
            for &v in &order {
                let cpt = &net.cpts[v];
                let config: Vec<usize> = cpt.parents.iter().map(|&q| assignment[q]).collect();
                p *= cpt.row(&config, &cards)[assignment[v]];
                if p == 0.0 {
                    break;
                }
            }
            if p > 0.0 {
                z += p;
                for v in 0..n {
                    probs[v][assignment[v]] += p;
                }
            }
        }
        // odometer step over the full assignment space
        for i in (0..n).rev() {
            assignment[i] += 1;
            if assignment[i] < cards[i] {
                continue 'outer;
            }
            assignment[i] = 0;
            if i == 0 {
                break 'outer;
            }
        }
    }

    if z <= 0.0 {
        return Err(Error::InconsistentEvidence);
    }
    for v in 0..n {
        for s in 0..cards[v] {
            probs[v][s] /= z;
        }
    }
    Ok(ExactPosteriors { probs, log_z: z.ln() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn prior_marginals_match_hand_values() {
        let net = embedded::asia();
        let ex = enumerate(&net, &Evidence::none()).unwrap();
        let lung = net.var_id("lung").unwrap();
        assert!((ex.probs[lung][0] - 0.055).abs() < 1e-12);
        assert!(ex.log_z.abs() < 1e-12);
    }

    #[test]
    fn evidence_probability_and_bayes_rule() {
        let net = embedded::asia();
        let smoke = net.var_id("smoke").unwrap();
        let lung = net.var_id("lung").unwrap();
        let ex = enumerate(&net, &Evidence::from_ids(vec![(smoke, 0)])).unwrap();
        assert!((ex.log_z.exp() - 0.5).abs() < 1e-12);
        assert!((ex.probs[lung][0] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn inconsistent_evidence_detected() {
        let net = embedded::asia();
        let either = net.var_id("either").unwrap();
        let lung = net.var_id("lung").unwrap();
        let r = enumerate(&net, &Evidence::from_ids(vec![(either, 1), (lung, 0)]));
        assert!(matches!(r, Err(Error::InconsistentEvidence)));
    }

    #[test]
    fn refuses_oversized_joints() {
        let net = crate::bn::netgen::NetSpec {
            name: "big".into(),
            nodes: 30,
            arcs: 30,
            max_parents: 2,
            card_choices: vec![(4, 1.0)],
            locality: 5,
            max_table: 1 << 10,
            alpha: 1.0,
            seed: 3,
        }
        .generate();
        assert!(enumerate(&net, &Evidence::none()).is_err());
    }

    #[test]
    fn posteriors_sum_to_one() {
        let net = embedded::cancer();
        let xray = net.var_id("Xray").unwrap();
        let ex = enumerate(&net, &Evidence::from_ids(vec![(xray, 0)])).unwrap();
        for v in 0..net.n() {
            let s: f64 = ex.probs[v].iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }
}
