//! Likelihood-weighting approximate inference — an *independent*
//! cross-check oracle for networks too large to enumerate.
//!
//! The enumeration oracle ([`crate::infer::exact`]) caps out around 2²⁶
//! joint states; likelihood weighting scales to the paper-suite networks
//! and converges to the true posterior, so the integration tests can
//! sanity-check the junction-tree engines on *large* networks as well
//! (with a statistical tolerance instead of 1e-9).

use crate::bn::network::Network;
use crate::jt::evidence::Evidence;
use crate::rng::Rng;
use crate::{Error, Result};

/// Result of a likelihood-weighting run.
pub struct LwPosteriors {
    /// `probs[v][s] ≈ P(v = s | e)`.
    pub probs: Vec<Vec<f64>>,
    /// Effective sample size `(Σw)² / Σw²` — reliability indicator.
    pub effective_samples: f64,
    /// Mean weight = unbiased estimate of `P(e_hard)` (soft weights fold
    /// into the weight product as likelihoods).
    pub mean_weight: f64,
}

/// Run likelihood weighting with `n` samples.
pub fn likelihood_weighting(net: &Network, ev: &Evidence, n: usize, seed: u64) -> Result<LwPosteriors> {
    let mut rng = Rng::new(seed);
    let order = net.topo_order()?;
    let cards = net.cards();
    let mut acc: Vec<Vec<f64>> = (0..net.n()).map(|v| vec![0.0; cards[v]]).collect();
    let mut w_sum = 0.0f64;
    let mut w_sq = 0.0f64;
    let mut assignment = vec![0usize; net.n()];

    for _ in 0..n {
        let mut weight = 1.0f64;
        for &v in &order {
            let cpt = &net.cpts[v];
            let config: Vec<usize> = cpt.parents.iter().map(|&p| assignment[p]).collect();
            let row = cpt.row(&config, &cards);
            if let Some(s) = ev.get(v) {
                assignment[v] = s;
                weight *= row[s];
            } else {
                assignment[v] = rng.categorical(row);
            }
            if weight == 0.0 {
                break;
            }
        }
        // soft findings weight the sample by the likelihood of the drawn state
        for (v, lik) in &ev.soft {
            weight *= lik[assignment[*v]];
        }
        if weight > 0.0 {
            w_sum += weight;
            w_sq += weight * weight;
            for v in 0..net.n() {
                acc[v][assignment[v]] += weight;
            }
        }
    }

    if w_sum <= 0.0 {
        return Err(Error::InconsistentEvidence);
    }
    for a in &mut acc {
        for x in a.iter_mut() {
            *x /= w_sum;
        }
    }
    Ok(LwPosteriors {
        probs: acc,
        effective_samples: w_sum * w_sum / w_sq,
        mean_weight: w_sum / n as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn matches_enumeration_on_asia() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("dysp", "yes")]).unwrap();
        let exact = crate::infer::exact::enumerate(&net, &ev).unwrap();
        let lw = likelihood_weighting(&net, &ev, 200_000, 7).unwrap();
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                assert!(
                    (lw.probs[v][s] - exact.probs[v][s]).abs() < 0.01,
                    "v{v}s{s}: {} vs {}",
                    lw.probs[v][s],
                    exact.probs[v][s]
                );
            }
        }
        assert!((lw.mean_weight - exact.log_z.exp()).abs() < 0.01);
        assert!(lw.effective_samples > 10_000.0);
    }

    #[test]
    fn handles_soft_evidence() {
        let net = embedded::asia();
        let smoke = net.var_id("smoke").unwrap();
        let ev = Evidence::none().with_soft(smoke, vec![4.0, 1.0]).unwrap();
        let lw = likelihood_weighting(&net, &ev, 100_000, 9).unwrap();
        assert!((lw.probs[smoke][0] - 0.8).abs() < 0.01, "got {}", lw.probs[smoke][0]);
    }

    #[test]
    fn impossible_evidence_detected() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(matches!(likelihood_weighting(&net, &ev, 1000, 3), Err(Error::InconsistentEvidence)));
    }
}
