//! Posterior extraction from a calibrated junction tree.

use crate::bn::network::Network;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// Posterior marginals `P(v | e)` for every variable, plus `ln P(e)`.
///
/// This is the paper's inference output: after calibration every clique
/// holds (a scaled copy of) `P(clique vars, e)`, so the marginal of each
/// variable is read off its home clique and normalized.
#[derive(Clone, Debug)]
pub struct Posteriors {
    /// `probs[v][s] = P(v = s | e)`. For observed variables this is the
    /// indicator of the observed state.
    pub probs: Vec<Vec<f64>>,
    /// Log evidence probability `ln P(e)`.
    pub log_z: f64,
    /// Accuracy contract of the approximate tier: `Some` when these
    /// posteriors were *estimated* by sampling (and every entry carries a
    /// CI half-width through [`ApproxInfo::half_width`]), `None` for
    /// exact engines.
    pub approx: Option<ApproxInfo>,
}

/// Sampling metadata attached to approximate posteriors — the explicit
/// accuracy contract: callers can recover a 95% CI half-width for any
/// reported probability from the effective sample size.
#[derive(Clone, Debug)]
pub struct ApproxInfo {
    /// Likelihood-weighting samples drawn.
    pub n_samples: usize,
    /// Effective sample size `(Σw)² / Σw²` of the importance weights.
    pub effective_samples: f64,
}

impl ApproxInfo {
    /// 95% CI half-width for a reported probability `p`, using the
    /// normal approximation with the effective (not raw) sample size.
    pub fn half_width(&self, p: f64) -> f64 {
        if self.effective_samples <= 0.0 {
            return 1.0;
        }
        1.96 * (p.clamp(0.0, 1.0) * (1.0 - p.clamp(0.0, 1.0)) / self.effective_samples).sqrt()
    }

    /// Worst-case 95% CI half-width over all probabilities (at p = 0.5).
    pub fn max_half_width(&self) -> f64 {
        self.half_width(0.5)
    }

    /// Relative variance of the normalized importance weights,
    /// `Var(w)/E[w]² = n/ESS − 1`: 0 when every weight is equal (prior
    /// sampling), growing without bound as likelihood weighting
    /// degenerates on deep-tail evidence. The fleet surfaces this as the
    /// `wvar=` health field on `STATS`.
    pub fn relative_weight_variance(&self) -> f64 {
        if self.effective_samples <= 0.0 || self.n_samples == 0 {
            return 0.0;
        }
        (self.n_samples as f64 / self.effective_samples - 1.0).max(0.0)
    }
}

impl Posteriors {
    /// Extract posteriors from a calibrated state.
    pub fn compute(jt: &JunctionTree, state: &TreeState) -> Result<Posteriors> {
        Self::compute_lane(jt, state.data(), 1, 0, state.log_z)
    }

    /// Extract the posteriors of lane `lane` from a calibrated
    /// lane-expanded arena (`data[i*lanes + b]` — see
    /// [`crate::jt::state::BatchState`]). `compute` is the `lanes = 1`
    /// case.
    pub fn compute_lane(
        jt: &JunctionTree,
        data: &[f64],
        lanes: usize,
        lane: usize,
        log_z: f64,
    ) -> Result<Posteriors> {
        let n = jt.net.n();
        let mut probs = Vec::with_capacity(n);
        for v in 0..n {
            let slot = &jt.var_slot[v];
            let r = jt.layout.clique_range(slot.clique);
            let tab = &data[r.start * lanes..r.end * lanes];
            let len = r.end - r.start;
            let mut marg = vec![0.0; slot.card];
            let stride = slot.stride;
            let card = slot.card;
            let block = stride * card;
            let mut base = 0usize;
            while base < len {
                for s in 0..card {
                    let lo = base + s * stride;
                    let mut acc = 0.0;
                    for i in lo..lo + stride {
                        acc += tab[i * lanes + lane];
                    }
                    marg[s] += acc;
                }
                base += block;
            }
            let total: f64 = marg.iter().sum();
            if total <= 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            for x in &mut marg {
                *x /= total;
            }
            probs.push(marg);
        }
        Ok(Posteriors { probs, log_z, approx: None })
    }

    /// Posterior of a variable by name.
    pub fn marginal(&self, net: &Network, var: &str) -> Result<&[f64]> {
        let v = net.var_id(var)?;
        Ok(&self.probs[v])
    }

    /// `P(e)`.
    pub fn evidence_probability(&self) -> f64 {
        self.log_z.exp()
    }

    /// Maximum absolute difference against another posterior set (used by
    /// engine-agreement tests).
    pub fn max_abs_diff(&self, other: &Posteriors) -> f64 {
        let mut worst: f64 = (self.log_z - other.log_z).abs();
        for (a, b) in self.probs.iter().zip(&other.probs) {
            for (x, y) in a.iter().zip(b) {
                worst = worst.max((x - y).abs());
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::evidence::Evidence;
    use crate::jt::propagate::{calibrate, MapMode, Scratch};
    use crate::jt::schedule::{RootStrategy, Schedule};
    use crate::jt::triangulate::TriangulationHeuristic;

    fn posterior(net: &crate::bn::network::Network, pairs: &[(&str, &str)]) -> Posteriors {
        let jt = JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = crate::jt::state::TreeState::fresh(&jt);
        let mut scratch = Scratch::for_tree(&jt);
        let ev = Evidence::from_pairs(net, pairs).unwrap();
        calibrate(&jt, &sched, &mut state, &ev, MapMode::Cached, &mut scratch).unwrap();
        Posteriors::compute(&jt, &state).unwrap()
    }

    #[test]
    fn asia_priors_match_hand_computation() {
        let net = embedded::asia();
        let post = posterior(&net, &[]);
        // P(lung=yes) = .5*.1 + .5*.01 = .055
        let lung = post.marginal(&net, "lung").unwrap();
        assert!((lung[0] - 0.055).abs() < 1e-9, "{}", lung[0]);
        // P(bronc=yes) = .5*.6 + .5*.3 = .45
        let bronc = post.marginal(&net, "bronc").unwrap();
        assert!((bronc[0] - 0.45).abs() < 1e-9);
        // P(tub=yes) = .01*.05+.99*.01 = .0104
        let tub = post.marginal(&net, "tub").unwrap();
        assert!((tub[0] - 0.0104).abs() < 1e-9);
        // P(either=yes) = 1-(1-.055)(1-.0104) ... lung ⟂ tub
        let either = post.marginal(&net, "either").unwrap();
        let expect = 1.0 - (1.0 - 0.055) * (1.0 - 0.0104);
        assert!((either[0] - expect).abs() < 1e-9);
    }

    #[test]
    fn observed_variable_has_indicator_posterior() {
        let net = embedded::asia();
        let post = posterior(&net, &[("smoke", "no")]);
        let smoke = post.marginal(&net, "smoke").unwrap();
        assert!((smoke[0] - 0.0).abs() < 1e-12);
        assert!((smoke[1] - 1.0).abs() < 1e-12);
        // conditional: P(lung=yes | smoke=no) = 0.01
        let lung = post.marginal(&net, "lung").unwrap();
        assert!((lung[0] - 0.01).abs() < 1e-9);
    }

    #[test]
    fn diagnostic_reasoning_flows_upstream() {
        // Observing dyspnoea raises P(bronc=yes)
        let net = embedded::asia();
        let prior = posterior(&net, &[]);
        let post = posterior(&net, &[("dysp", "yes")]);
        let b0 = prior.marginal(&net, "bronc").unwrap()[0];
        let b1 = post.marginal(&net, "bronc").unwrap()[0];
        assert!(b1 > b0, "bronc {b0} -> {b1} should increase");
    }

    #[test]
    fn cancer_network_posterior() {
        // P(Cancer=True) = 0.9*(0.3*0.03+0.7*0.001) + 0.1*(0.3*0.05+0.7*0.02)
        let net = embedded::cancer();
        let post = posterior(&net, &[]);
        let expect = 0.9 * (0.3 * 0.03 + 0.7 * 0.001) + 0.1 * (0.3 * 0.05 + 0.7 * 0.02);
        let got = post.marginal(&net, "Cancer").unwrap()[0];
        assert!((got - expect).abs() < 1e-9, "{got} vs {expect}");
    }

    #[test]
    fn sprinkler_explaining_away() {
        // P(sprinkler=on | wet) decreases once rain is also observed
        let net = embedded::sprinkler();
        let wet = posterior(&net, &[("wetgrass", "yes")]);
        let wet_rain = posterior(&net, &[("wetgrass", "yes"), ("rain", "yes")]);
        let s_wet = wet.marginal(&net, "sprinkler").unwrap()[0];
        let s_wet_rain = wet_rain.marginal(&net, "sprinkler").unwrap()[0];
        assert!(s_wet_rain < s_wet, "explaining away: {s_wet_rain} < {s_wet}");
    }

    #[test]
    fn approx_info_reports_half_widths() {
        let info = ApproxInfo { n_samples: 1000, effective_samples: 400.0 };
        assert!((info.max_half_width() - 1.96 * (0.25f64 / 400.0).sqrt()).abs() < 1e-12);
        assert_eq!(info.half_width(0.0), 0.0);
        assert!(info.half_width(0.5) > info.half_width(0.1));
        // degenerate ESS reports the vacuous bound, never NaN
        let degenerate = ApproxInfo { n_samples: 10, effective_samples: 0.0 };
        assert_eq!(degenerate.half_width(0.5), 1.0);
        // exact posteriors carry no sampling contract
        assert!(posterior(&embedded::asia(), &[]).approx.is_none());
    }

    #[test]
    fn max_abs_diff_detects_difference() {
        let net = embedded::asia();
        let a = posterior(&net, &[]);
        let b = posterior(&net, &[("smoke", "yes")]);
        assert!(a.max_abs_diff(&b) > 1e-3);
        assert!(a.max_abs_diff(&a) < 1e-15);
    }
}
