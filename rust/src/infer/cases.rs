//! Benchmark test-case generation.
//!
//! §3 of the paper: *"We randomly generated 2,000 test cases from each
//! network, each with 20% of the observed variables."* A case is an
//! evidence set; we draw a full assignment by forward sampling (so the
//! evidence always has non-zero probability) and keep a random 20% subset
//! of the variables as observations.

use crate::bn::network::Network;
use crate::bn::sample::forward_sample;
use crate::jt::evidence::Evidence;
use crate::rng::Rng;

/// Generator parameters (paper defaults).
#[derive(Clone, Debug)]
pub struct CaseSpec {
    /// Number of cases (paper: 2000).
    pub n_cases: usize,
    /// Fraction of variables observed per case (paper: 0.2).
    pub observed_fraction: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for CaseSpec {
    fn default() -> Self {
        CaseSpec { n_cases: 2000, observed_fraction: 0.2, seed: 0xCA5E }
    }
}

/// Generate the evidence cases for a network.
pub fn generate(net: &Network, spec: &CaseSpec) -> Vec<Evidence> {
    let mut rng = Rng::new(spec.seed);
    let n_obs = ((net.n() as f64) * spec.observed_fraction).round() as usize;
    let n_obs = n_obs.min(net.n());
    (0..spec.n_cases)
        .map(|_| {
            let full = forward_sample(net, &mut rng);
            let vars = rng.sample_indices(net.n(), n_obs);
            Evidence::from_ids(vars.into_iter().map(|v| (v, full[v])).collect())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn cases_have_requested_shape() {
        let net = embedded::asia();
        let spec = CaseSpec { n_cases: 50, observed_fraction: 0.2, seed: 1 };
        let cases = generate(&net, &spec);
        assert_eq!(cases.len(), 50);
        // 20% of 8 variables rounds to 2
        for c in &cases {
            assert_eq!(c.len(), 2);
            for &(v, s) in &c.obs {
                assert!(v < net.n());
                assert!(s < net.card(v));
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let net = embedded::asia();
        let spec = CaseSpec { n_cases: 10, observed_fraction: 0.25, seed: 7 };
        assert_eq!(generate(&net, &spec), generate(&net, &spec));
    }

    #[test]
    fn sampled_evidence_is_consistent() {
        // forward-sampled evidence always has P(e) > 0: the oracle must not
        // report inconsistency
        let net = embedded::asia();
        let spec = CaseSpec { n_cases: 25, observed_fraction: 0.5, seed: 3 };
        for ev in generate(&net, &spec) {
            crate::infer::exact::enumerate(&net, &ev).unwrap();
        }
    }

    #[test]
    fn full_observation_fraction() {
        let net = embedded::asia();
        let spec = CaseSpec { n_cases: 3, observed_fraction: 1.0, seed: 4 };
        for c in generate(&net, &spec) {
            assert_eq!(c.len(), net.n());
        }
    }
}
