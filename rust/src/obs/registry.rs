//! Process-wide metrics registry: counters, gauges, and fixed-bucket
//! latency histograms with a Prometheus-style text exposition.
//!
//! Histograms use log2 buckets (`le = 1, 2, 4, … 2^26` µs, then `+Inf`),
//! so recording is two relaxed atomic adds and percentiles are a bucket
//! walk — no reservoir lock ever sits on the hot path. The price is
//! resolution: a percentile read from buckets is an *upper bound* within
//! 2× of the true value, which is the right trade for serving telemetry.
//!
//! Series are keyed by their full exposition name (`name{k="v"}`, built
//! with [`series`]); a [`Registry`] renders deterministically (BTreeMap
//! order) so scrapes diff cleanly. One process-global registry
//! ([`global`]) carries engine/compiler series; each `Fleet` owns its own
//! registry for per-network series so in-process fleets (tests, the
//! cluster harness) never bleed counters into each other.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Number of histogram buckets: `le = 2^0 … 2^26` µs plus `+Inf`. The
/// top finite bound (~67 s) leaves room for learn-spec `LOAD`s and big
/// JT compiles, which blew past the original 2^20 (~1 s) ladder and
/// vanished into `+Inf`.
pub const BUCKETS: usize = 28;

/// A monotonically increasing counter (relaxed atomics; cheap anywhere).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed log2-bucket histogram over non-negative integer values
/// (latencies in µs, occupancies, …). Recording is lock-free.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0), count: AtomicU64::new(0) }
    }
}

/// Index of the first bucket whose upper bound holds `v`.
fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        return 0;
    }
    // ceil(log2 v): 2 → 1 (le=2), 3..=4 → 2 (le=4), …; past 2^26 → +Inf
    let bits = 64 - (v - 1).leading_zeros() as usize;
    bits.min(BUCKETS - 1)
}

/// Upper bound of bucket `i` (`u64::MAX` stands in for `+Inf`).
pub fn bucket_bound(i: usize) -> u64 {
    if i + 1 < BUCKETS {
        1u64 << i
    } else {
        u64::MAX
    }
}

impl Histogram {
    /// Record one duration (in µs resolution).
    pub fn record(&self, d: Duration) {
        self.record_value(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Record one raw value.
    pub fn record_value(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket (non-cumulative) counts.
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed))
    }

    /// Upper bound on the `p`-percentile (0 < p ≤ 1): the bound of the
    /// bucket holding the nearest-rank observation — within 2× of the
    /// true value by construction. Overflowed observations report the
    /// first out-of-range power of two rather than `+Inf`.
    pub fn percentile(&self, p: f64) -> u64 {
        let counts = self.bucket_counts();
        percentile_from_buckets(&counts, p)
    }
}

/// Percentile walk over non-cumulative log2 bucket counts — shared with
/// the cluster's cross-backend bucket merge ([`crate::obs::scrape`]).
pub fn percentile_from_buckets(counts: &[u64; BUCKETS], p: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let rank = ((p * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, c) in counts.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return if i + 1 < BUCKETS { 1u64 << i } else { 1u64 << BUCKETS };
        }
    }
    1u64 << BUCKETS
}

/// Build a full series key: `name{k="v",…}` (or just `name`).
pub fn series(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let inner: Vec<String> = labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", inner.join(","))
}

fn base_of(key: &str) -> &str {
    key.split('{').next().unwrap_or(key)
}

fn labels_of(key: &str) -> &str {
    match key.split_once('{') {
        Some((_, rest)) => rest.strip_suffix('}').unwrap_or(rest),
        None => "",
    }
}

type GaugeFn = Box<dyn Fn() -> u64 + Send + Sync>;

/// A registry of named series. Lookup takes a short mutex (cold relative
/// to inference); the returned `Arc` handles record lock-free thereafter.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, GaugeFn>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Get or create the counter for `key` (a full series name).
    pub fn counter(&self, key: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(key.to_string()).or_default())
    }

    /// Get or create the histogram for `key`.
    pub fn histogram(&self, key: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap();
        Arc::clone(map.entry(key.to_string()).or_default())
    }

    /// Register (or replace) a gauge callback — read at render time, so
    /// live values (connection counts, LRU totals) need no bookkeeping.
    pub fn register_gauge(&self, key: &str, f: impl Fn() -> u64 + Send + Sync + 'static) {
        self.gauges.lock().unwrap().insert(key.to_string(), Box::new(f));
    }

    /// Drop every counter/histogram series whose key contains `needle` —
    /// the eviction hook (`needle` is `net="<name>"`), matching the fleet
    /// metrics' rule that evicted networks never leave ghost series.
    pub fn remove_matching(&self, needle: &str) {
        self.counters.lock().unwrap().retain(|k, _| !k.contains(needle));
        self.histograms.lock().unwrap().retain(|k, _| !k.contains(needle));
    }

    /// Render the Prometheus-style text exposition: counters, then
    /// gauges, then histograms, each section in sorted series order with
    /// one `# TYPE` line per metric base name. Deterministic by
    /// construction; no trailing newline.
    pub fn render(&self) -> String {
        let mut out: Vec<String> = Vec::new();
        {
            let counters = self.counters.lock().unwrap();
            let mut last = "";
            for (key, c) in counters.iter() {
                let base = base_of(key);
                if base != last {
                    out.push(format!("# TYPE {base} counter"));
                }
                out.push(format!("{key} {}", c.get()));
                last = base_of(key);
            }
        }
        {
            let gauges = self.gauges.lock().unwrap();
            let mut last = "";
            for (key, f) in gauges.iter() {
                let base = base_of(key);
                if base != last {
                    out.push(format!("# TYPE {base} gauge"));
                }
                out.push(format!("{key} {}", f()));
                last = base_of(key);
            }
        }
        {
            let histograms = self.histograms.lock().unwrap();
            let mut last = "";
            for (key, h) in histograms.iter() {
                let base = base_of(key);
                let labels = labels_of(key);
                if base != last {
                    out.push(format!("# TYPE {base} histogram"));
                }
                let with_le = |le: &str| -> String {
                    if labels.is_empty() {
                        format!("{{le=\"{le}\"}}")
                    } else {
                        format!("{{{labels},le=\"{le}\"}}")
                    }
                };
                let tail = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
                let mut cum = 0u64;
                for (i, c) in h.bucket_counts().iter().enumerate() {
                    cum += c;
                    let le = if i + 1 < BUCKETS { format!("{}", 1u64 << i) } else { "+Inf".to_string() };
                    out.push(format!("{base}_bucket{} {cum}", with_le(&le)));
                }
                out.push(format!("{base}_sum{tail} {}", h.sum()));
                out.push(format!("{base}_count{tail} {}", h.count()));
                last = base_of(key);
            }
        }
        out.join("\n")
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-global registry: engine sweeps, pool regions, lane
/// occupancy, sampling rounds, JT compiles, slow-query counts. Per-fleet
/// series live on `Fleet`'s own registry instead.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_ceil_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(1 << 20), 20);
        assert_eq!(bucket_index(1 << 26), 26);
        assert_eq!(bucket_index((1 << 26) + 1), BUCKETS - 1);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn histogram_percentile_is_a_tight_upper_bound() {
        let h = Histogram::default();
        for v in [3u64, 3, 3, 100] {
            h.record_value(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 109);
        // p50 rank 2 lands with the 3s (le=4); p99 rank 4 with the 100 (le=128)
        assert_eq!(h.percentile(0.50), 4);
        assert_eq!(h.percentile(0.99), 128);
        assert!(h.percentile(0.50) >= 3 && h.percentile(0.50) <= 2 * 3);
        assert!(h.percentile(0.99) >= 100 && h.percentile(0.99) <= 2 * 100);
        assert_eq!(Histogram::default().percentile(0.99), 0);
    }

    #[test]
    fn series_builds_label_sets() {
        assert_eq!(series("a_total", &[]), "a_total");
        assert_eq!(series("a_total", &[("net", "asia")]), "a_total{net=\"asia\"}");
        assert_eq!(series("a", &[("x", "1"), ("y", "2")]), "a{x=\"1\",y=\"2\"}");
        assert_eq!(base_of("a_total{net=\"asia\"}"), "a_total");
        assert_eq!(labels_of("a_total{net=\"asia\"}"), "net=\"asia\"");
        assert_eq!(labels_of("a_total"), "");
    }

    #[test]
    fn render_is_deterministic_and_grouped() {
        let r = Registry::default();
        r.counter("q_total{net=\"asia\"}").add(3);
        r.counter("q_total{net=\"cancer\"}").inc();
        r.register_gauge("conns_active", || 7);
        let text = r.render();
        let want = "# TYPE q_total counter\nq_total{net=\"asia\"} 3\nq_total{net=\"cancer\"} 1\n\
                    # TYPE conns_active gauge\nconns_active 7";
        assert_eq!(text, want);
        assert_eq!(text, r.render(), "render must be stable");
    }

    #[test]
    fn histogram_renders_cumulative_buckets() {
        let r = Registry::default();
        r.histogram("lat_us{net=\"asia\"}").record(Duration::from_micros(3));
        let text = r.render();
        assert!(text.contains("# TYPE lat_us histogram"), "{text}");
        assert!(text.contains("lat_us_bucket{net=\"asia\",le=\"2\"} 0"), "{text}");
        assert!(text.contains("lat_us_bucket{net=\"asia\",le=\"4\"} 1"), "{text}");
        assert!(text.contains("lat_us_bucket{net=\"asia\",le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("lat_us_sum{net=\"asia\"} 3"), "{text}");
        assert!(text.contains("lat_us_count{net=\"asia\"} 1"), "{text}");
    }

    #[test]
    fn remove_matching_drops_only_the_named_net() {
        let r = Registry::default();
        r.counter("q_total{net=\"asia\"}").inc();
        r.counter("q_total{net=\"cancer\"}").inc();
        r.histogram("lat_us{net=\"asia\"}").record_value(1);
        r.remove_matching("net=\"asia\"");
        let text = r.render();
        assert!(!text.contains("asia"), "{text}");
        assert!(text.contains("q_total{net=\"cancer\"} 1"), "{text}");
    }
}
