//! Zero-dependency observability: a process/fleet metrics registry with
//! Prometheus-style text exposition ([`registry`]), per-query trace
//! spans with a bounded ring and slow-query log ([`trace`]), the
//! pool parallelism profiler ([`profile`]), and the cluster-side scrape
//! parser/merger ([`scrape`]).
//!
//! Layering: engines and the JT compiler record into the process-global
//! registry ([`global`]) and open [`trace::span`]s; pool regions fold
//! per-worker busy/idle tallies into [`profile`] when armed; each
//! `Fleet` owns a private registry for per-network series; the fleet
//! wire surface adds `METRICS` / `TRACE <on|off|last|qid>` / `PROFILE`
//! verbs; the cluster front scrapes and merges its backends and
//! correlates traces across tiers by query id. Instrumentation reads
//! clocks and bumps atomics only — posteriors are byte-identical with
//! telemetry on or off.

pub mod profile;
pub mod registry;
pub mod scrape;
pub mod trace;

pub use registry::{global, series, Counter, Histogram, Registry};
