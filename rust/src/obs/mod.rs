//! Zero-dependency observability: a process/fleet metrics registry with
//! Prometheus-style text exposition ([`registry`]), per-query trace
//! spans with a bounded ring and slow-query log ([`trace`]), and the
//! cluster-side scrape parser/merger ([`scrape`]).
//!
//! Layering: engines and the JT compiler record into the process-global
//! registry ([`global`]) and open [`trace::span`]s; each `Fleet` owns a
//! private registry for per-network series; the fleet wire surface adds
//! `METRICS` / `TRACE <on|off|last>` verbs; the cluster front scrapes
//! and merges its backends. Instrumentation reads clocks and bumps
//! atomics only — posteriors are byte-identical with telemetry on or
//! off.

pub mod registry;
pub mod scrape;
pub mod trace;

pub use registry::{global, series, Counter, Histogram, Registry};
