//! Continuous parallelism profiler for the worker pool.
//!
//! The paper's hybrid design lives or dies on how well the per-layer
//! pool regions keep every worker busy; this module measures exactly
//! that. When **armed**, [`crate::engine::pool::Pool::parallel_region`]
//! allocates one [`RegionTally`] per region entry and each task claim
//! pays two monotonic clock reads plus two relaxed atomic adds (busy
//! nanoseconds + task count, per worker lane). The leader folds the
//! tally into a process-wide store keyed by region name and into
//! `fastbn_pool_*` series on the global registry. **Disarmed** (the
//! default), the only cost is one relaxed load per region entry — the
//! same contract as [`crate::obs::trace`]: telemetry never changes a
//! reply byte.
//!
//! Derived per region: **utilization** (Σ busy / (wall × workers)),
//! **load imbalance** (max worker busy / mean worker busy, ≥ 1, ≤
//! worker count by construction), and **barrier-wait share** (leader
//! time blocked on the end-of-region barrier / wall). Idle is derived,
//! not measured: `wall − busy` per lane.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

static ARMED: AtomicBool = AtomicBool::new(false);

static STORE: Mutex<BTreeMap<&'static str, RegionAcc>> = Mutex::new(BTreeMap::new());

/// Arm or disarm the profiler. Arming resets the store so every report
/// describes one contiguous profiling window.
pub fn set_armed(on: bool) {
    if on {
        reset();
    }
    ARMED.store(on, Ordering::Relaxed);
}

/// Is the profiler collecting? One relaxed load — the pool checks this
/// once per region entry.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Drop all accumulated region profiles.
pub fn reset() {
    STORE.lock().unwrap().clear();
}

/// Per-region-entry scratch shared between the leader and the workers:
/// one busy-nanoseconds and one task-count lane per pool thread. All
/// adds are relaxed — lanes are only read after the region barrier.
pub struct RegionTally {
    pub busy_ns: Vec<AtomicU64>,
    pub tasks: Vec<AtomicU64>,
}

impl RegionTally {
    /// Zeroed tally with one lane per pool thread.
    pub fn new(threads: usize) -> Self {
        RegionTally {
            busy_ns: (0..threads).map(|_| AtomicU64::new(0)).collect(),
            tasks: (0..threads).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// Accumulated totals for one region name across entries. Kept in
/// nanoseconds so sub-microsecond region entries (small layers on small
/// nets) still accumulate instead of flooring to zero per entry; the
/// snapshot converts once, after summation.
#[derive(Clone, Default)]
struct RegionAcc {
    entries: u64,
    wall_ns: u64,
    barrier_ns: u64,
    busy_ns: Vec<u64>,
    tasks: Vec<u64>,
}

/// One region's accumulated profile, as reported by [`snapshot`].
#[derive(Clone, Debug)]
pub struct RegionProfile {
    /// Region name (e.g. `hybrid.B1`).
    pub region: &'static str,
    /// Times the region was entered while armed.
    pub entries: u64,
    /// Total wall time inside the region (leader-measured), µs.
    pub wall_us: u64,
    /// Leader time blocked on the end-of-region barrier, µs.
    pub barrier_us: u64,
    /// Per-worker-lane busy time, µs (lane 0 = leader).
    pub busy_us: Vec<u64>,
    /// Per-worker-lane completed task counts.
    pub tasks: Vec<u64>,
}

impl RegionProfile {
    /// Worker lanes seen for this region (the pool's thread count).
    pub fn workers(&self) -> usize {
        self.busy_us.len()
    }

    /// Σ busy / (wall × workers): 1.0 = every lane busy for the whole
    /// region, every entry.
    pub fn utilization(&self) -> f64 {
        let denom = self.wall_us.saturating_mul(self.workers() as u64);
        if denom == 0 {
            return 0.0;
        }
        self.busy_us.iter().sum::<u64>() as f64 / denom as f64
    }

    /// Max lane busy / mean lane busy. 1.0 = perfectly balanced; equal
    /// to the worker count when one lane did all the work.
    pub fn imbalance(&self) -> f64 {
        let total: u64 = self.busy_us.iter().sum();
        if total == 0 || self.busy_us.is_empty() {
            return 1.0;
        }
        let max = *self.busy_us.iter().max().expect("non-empty") as f64;
        max / (total as f64 / self.busy_us.len() as f64)
    }

    /// Fraction of region wall time the leader spent in the barrier.
    pub fn barrier_share(&self) -> f64 {
        if self.wall_us == 0 {
            return 0.0;
        }
        self.barrier_us as f64 / self.wall_us as f64
    }

    /// Per-lane derived idle time (`wall − busy`, saturating), µs.
    pub fn idle_us(&self) -> Vec<u64> {
        self.busy_us.iter().map(|b| self.wall_us.saturating_sub(*b)).collect()
    }

    /// One self-describing report line, `key=value` tokens only —
    /// machine-greppable and append-only extensible.
    pub fn render_line(&self) -> String {
        let join = |v: &[u64]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        format!(
            "region={} entries={} workers={} wall_us={} barrier_us={} util={:.3} imbalance={:.2} \
             barrier_share={:.3} busy_us={} idle_us={} tasks={}",
            self.region,
            self.entries,
            self.workers(),
            self.wall_us,
            self.barrier_us,
            self.utilization(),
            self.imbalance(),
            self.barrier_share(),
            join(&self.busy_us),
            join(&self.idle_us()),
            join(&self.tasks)
        )
    }
}

/// Fold one completed region entry into the store and the global
/// registry (`fastbn_pool_*` series). Called by the pool leader after
/// the region barrier; never on the per-task path.
pub fn record_region(region: &'static str, wall: Duration, barrier: Duration, tally: &RegionTally) {
    let wall_ns = wall.as_nanos() as u64;
    let barrier_ns = barrier.as_nanos() as u64;
    let busy_ns: Vec<u64> = tally.busy_ns.iter().map(|b| b.load(Ordering::Relaxed)).collect();
    let tasks: Vec<u64> = tally.tasks.iter().map(|t| t.load(Ordering::Relaxed)).collect();
    {
        let mut store = STORE.lock().unwrap();
        let acc = store.entry(region).or_default();
        acc.entries += 1;
        acc.wall_ns += wall_ns;
        acc.barrier_ns += barrier_ns;
        if acc.busy_ns.len() < busy_ns.len() {
            acc.busy_ns.resize(busy_ns.len(), 0);
            acc.tasks.resize(busy_ns.len(), 0);
        }
        for (lane, b) in busy_ns.iter().enumerate() {
            acc.busy_ns[lane] += b;
        }
        for (lane, t) in tasks.iter().enumerate() {
            acc.tasks[lane] += t;
        }
    }
    // registry counters are µs (the exposition's convention); sub-µs
    // entries round down here but stay exact in the ns store above
    let reg = crate::obs::global();
    let rl = [("region", region)];
    reg.counter(&crate::obs::series("fastbn_pool_region_entries_total", &rl)).inc();
    reg.counter(&crate::obs::series("fastbn_pool_region_wall_us_total", &rl)).add(wall_ns / 1_000);
    reg.counter(&crate::obs::series("fastbn_pool_region_barrier_us_total", &rl)).add(barrier_ns / 1_000);
    for (lane, (b, t)) in busy_ns.iter().zip(&tasks).enumerate() {
        if *t == 0 && *b == 0 {
            continue;
        }
        let lane = lane.to_string();
        let wl = [("region", region), ("worker", lane.as_str())];
        reg.counter(&crate::obs::series("fastbn_pool_worker_busy_us_total", &wl)).add(*b / 1_000);
        reg.counter(&crate::obs::series("fastbn_pool_worker_tasks_total", &wl)).add(*t);
    }
}

/// Snapshot of every profiled region, sorted by region name.
pub fn snapshot() -> Vec<RegionProfile> {
    let store = STORE.lock().unwrap();
    store
        .iter()
        .map(|(region, acc)| RegionProfile {
            region,
            entries: acc.entries,
            wall_us: acc.wall_ns / 1_000,
            barrier_us: acc.barrier_ns / 1_000,
            busy_us: acc.busy_ns.iter().map(|b| b / 1_000).collect(),
            tasks: acc.tasks.clone(),
        })
        .collect()
}

/// The `PROFILE` counted-block body: one [`RegionProfile::render_line`]
/// per region (empty string when nothing was profiled).
pub fn render() -> String {
    snapshot().iter().map(|p| p.render_line()).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    // The store is process-wide and arming resets it, so every test
    // touching it serializes on the shared obs toggle lock and keys its
    // assertions on unique region names.

    #[test]
    fn record_and_snapshot_round_trip() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let tally = RegionTally::new(2);
        tally.busy_ns[0].store(90_000, Ordering::Relaxed); // 90 µs
        tally.busy_ns[1].store(30_000, Ordering::Relaxed); // 30 µs
        tally.tasks[0].store(3, Ordering::Relaxed);
        tally.tasks[1].store(1, Ordering::Relaxed);
        record_region("prof-test-rt", Duration::from_micros(100), Duration::from_micros(10), &tally);
        let snap = snapshot();
        let p = snap.iter().find(|p| p.region == "prof-test-rt").expect("recorded region");
        assert_eq!(p.entries, 1);
        assert_eq!(p.workers(), 2);
        assert_eq!(p.busy_us, vec![90, 30]);
        assert_eq!(p.tasks, vec![3, 1]);
        // util = 120 / (100 × 2); imbalance = 90 / 60; barrier = 10/100
        assert!((p.utilization() - 0.6).abs() < 1e-9);
        assert!((p.imbalance() - 1.5).abs() < 1e-9);
        assert!((p.barrier_share() - 0.1).abs() < 1e-9);
        assert_eq!(p.idle_us(), vec![10, 70]);
        let line = p.render_line();
        assert!(line.starts_with("region=prof-test-rt entries=1 workers=2 wall_us=100"), "{line}");
        assert!(line.contains("busy_us=90,30"), "{line}");
        assert!(line.contains("tasks=3,1"), "{line}");
        // registry series landed too
        let text = crate::obs::global().render();
        assert!(text.contains("fastbn_pool_region_entries_total{region=\"prof-test-rt\"}"), "{text}");
        assert!(text.contains("fastbn_pool_worker_busy_us_total{region=\"prof-test-rt\",worker=\"0\"} 90"), "{text}");
    }

    #[test]
    fn entries_accumulate_and_imbalance_is_bounded_by_workers() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        for _ in 0..2 {
            let tally = RegionTally::new(4);
            tally.busy_ns[2].store(50_000, Ordering::Relaxed);
            tally.tasks[2].store(5, Ordering::Relaxed);
            record_region("prof-test-acc", Duration::from_micros(60), Duration::ZERO, &tally);
        }
        let snap = snapshot();
        let p = snap.iter().find(|p| p.region == "prof-test-acc").expect("recorded region");
        assert_eq!(p.entries, 2);
        assert_eq!(p.busy_us[2], 100);
        assert_eq!(p.tasks[2], 10);
        // one lane did everything: imbalance hits exactly the lane count
        assert!((p.imbalance() - 4.0).abs() < 1e-9);
        assert!(p.imbalance() <= p.workers() as f64 + 1e-9);
    }

    #[test]
    fn zero_work_region_is_well_defined() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let tally = RegionTally::new(3);
        record_region("prof-test-zero", Duration::ZERO, Duration::ZERO, &tally);
        let snap = snapshot();
        let p = snap.iter().find(|p| p.region == "prof-test-zero").expect("recorded region");
        assert_eq!(p.utilization(), 0.0);
        assert_eq!(p.imbalance(), 1.0);
        assert_eq!(p.barrier_share(), 0.0);
    }
}
