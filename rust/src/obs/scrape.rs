//! Parse and merge Prometheus-style text expositions.
//!
//! The cluster front scrapes `METRICS` from every live backend and
//! serves one merged exposition: counters and gauges are summed,
//! histograms are added bucket-wise (cumulative `le` counts sum
//! series-wise, so the merge stays a valid cumulative histogram), and
//! each backend's raw series are re-emitted with a `backend="<id>"`
//! label so per-backend drill-down survives the merge.

use std::collections::BTreeMap;

use super::registry::{percentile_from_buckets, BUCKETS};

/// A parsed exposition: metric base name → declared type, plus every
/// raw series (`name{labels}` → value).
#[derive(Clone, Debug, Default)]
pub struct Scrape {
    pub types: BTreeMap<String, String>,
    pub samples: BTreeMap<String, u64>,
}

/// Parse exposition text. Unknown or malformed lines are skipped — the
/// scraper must tolerate backends newer than the front.
pub fn parse(text: &str) -> Scrape {
    let mut s = Scrape::default();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            if let (Some(base), Some(kind)) = (it.next(), it.next()) {
                s.types.insert(base.to_string(), kind.to_string());
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        if let Some((key, value)) = line.rsplit_once(' ') {
            if let Ok(v) = value.trim().parse::<u64>() {
                s.samples.insert(key.trim().to_string(), v);
            }
        }
    }
    s
}

/// Look up one raw series in exposition text (test/smoke helper).
pub fn value(text: &str, key: &str) -> Option<u64> {
    parse(text).samples.get(key).copied()
}

fn with_backend_label(key: &str, backend: &str) -> String {
    match key.split_once('{') {
        Some((base, rest)) => format!("{base}{{backend=\"{backend}\",{rest}"),
        None => format!("{key}{{backend=\"{backend}\"}}"),
    }
}

/// Merge per-backend scrapes into one exposition: for every series, an
/// aggregate line summing all backends, then the per-backend lines with
/// a `backend="<id>"` label injected. Deterministic (sorted) order; no
/// trailing newline.
pub fn merge(parts: &[(String, Scrape)]) -> String {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut totals: BTreeMap<String, u64> = BTreeMap::new();
    let mut labeled: BTreeMap<String, u64> = BTreeMap::new();
    for (backend, scrape) in parts {
        for (base, kind) in &scrape.types {
            types.entry(base.clone()).or_insert_with(|| kind.clone());
        }
        for (key, v) in &scrape.samples {
            *totals.entry(key.clone()).or_insert(0) += v;
            labeled.insert(with_backend_label(key, backend), *v);
        }
    }
    let mut out: Vec<String> = Vec::new();
    for (base, kind) in &types {
        out.push(format!("# TYPE {base} {kind}"));
    }
    for (key, v) in &totals {
        out.push(format!("{key} {v}"));
    }
    for (key, v) in &labeled {
        out.push(format!("{key} {v}"));
    }
    out.join("\n")
}

/// Convenience: parse raw exposition texts, then [`merge`].
pub fn merge_exposition(parts: &[(String, String)]) -> String {
    let parsed: Vec<(String, Scrape)> = parts.iter().map(|(id, text)| (id.clone(), parse(text))).collect();
    merge(&parsed)
}

fn le_to_bucket_index(le: &str) -> Option<usize> {
    if le == "+Inf" {
        return Some(BUCKETS - 1);
    }
    let bound: u64 = le.parse().ok()?;
    (0..BUCKETS - 1).find(|&i| 1u64 << i == bound)
}

/// Extract the `le` label from a `…_bucket{…}` series key.
fn le_of(key: &str) -> Option<&str> {
    let (_, labels) = key.split_once('{')?;
    for part in labels.trim_end_matches('}').split(',') {
        if let Some(v) = part.strip_prefix("le=") {
            return Some(v.trim_matches('"'));
        }
    }
    None
}

/// Sum every `<base>_bucket` series across scrapes (all label sets, all
/// backends) into one cumulative histogram and read percentiles off it.
/// Returns `None` when no observations exist — the caller reports that
/// (`stats=partial`) rather than estimating.
///
/// A valid cumulative histogram is monotone in `le`. A corrupt or
/// mid-write exposition can violate that; the `u64` de-cumulation would
/// underflow and turn one bad bucket into a ~2^64 count that swamps
/// every percentile. Each non-monotone step is therefore clamped to
/// zero and counted on the global `fastbn_scrape_malformed_total`
/// counter — the merge degrades by at most the corrupt bucket, and the
/// corruption is visible in the front's own exposition instead of
/// silent.
pub fn merged_percentiles(scrapes: &[&Scrape], base: &str, ps: &[f64]) -> Option<Vec<u64>> {
    let prefix = format!("{base}_bucket{{");
    let mut cumulative = [0u64; BUCKETS];
    for s in scrapes {
        for (key, v) in &s.samples {
            if !key.starts_with(&prefix) {
                continue;
            }
            if let Some(i) = le_of(key).and_then(le_to_bucket_index) {
                cumulative[i] += v;
            }
        }
    }
    // De-cumulate: bucket i's own count is cum[i] - cum[i-1].
    let mut counts = [0u64; BUCKETS];
    let mut prev = 0u64;
    let mut malformed = 0u64;
    for i in 0..BUCKETS {
        if cumulative[i] < prev {
            malformed += 1;
        }
        counts[i] = cumulative[i].saturating_sub(prev);
        prev = cumulative[i].max(prev);
    }
    if malformed > 0 {
        super::registry::global().counter("fastbn_scrape_malformed_total").add(malformed);
    }
    if counts.iter().sum::<u64>() == 0 {
        return None;
    }
    Some(ps.iter().map(|&p| percentile_from_buckets(&counts, p)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;
    use std::time::Duration;

    #[test]
    fn parse_reads_types_and_samples() {
        let text = "# TYPE q_total counter\nq_total{net=\"asia\"} 3\n# TYPE lat_us histogram\nlat_us_count 2";
        let s = parse(text);
        assert_eq!(s.types.get("q_total").map(String::as_str), Some("counter"));
        assert_eq!(s.types.get("lat_us").map(String::as_str), Some("histogram"));
        assert_eq!(s.samples.get("q_total{net=\"asia\"}"), Some(&3));
        assert_eq!(s.samples.get("lat_us_count"), Some(&2));
        assert_eq!(value(text, "q_total{net=\"asia\"}"), Some(3));
    }

    #[test]
    fn merge_sums_and_labels_by_backend() {
        let a = "# TYPE q_total counter\nq_total{net=\"asia\"} 3";
        let b = "# TYPE q_total counter\nq_total{net=\"asia\"} 2";
        let merged = merge_exposition(&[("b0".into(), a.into()), ("b1".into(), b.into())]);
        assert_eq!(value(&merged, "q_total{net=\"asia\"}"), Some(5));
        assert_eq!(value(&merged, "q_total{backend=\"b0\",net=\"asia\"}"), Some(3));
        assert_eq!(value(&merged, "q_total{backend=\"b1\",net=\"asia\"}"), Some(2));
        assert!(merged.contains("# TYPE q_total counter"));
    }

    #[test]
    fn merged_percentiles_come_from_summed_buckets() {
        // Two "backends": one fast (3µs ×30), one slow (100µs ×10) —
        // the exact shape where count-weighted percentile averaging is
        // biased, and bucket merging is not.
        let fast = Registry::default();
        for _ in 0..30 {
            fast.histogram("lat_us{net=\"asia\"}").record(Duration::from_micros(3));
        }
        let slow = Registry::default();
        for _ in 0..10 {
            slow.histogram("lat_us{net=\"asia\"}").record(Duration::from_micros(100));
        }
        let (sa, sb) = (parse(&fast.render()), parse(&slow.render()));
        let ps = merged_percentiles(&[&sa, &sb], "lat_us", &[0.5, 0.99]).expect("observations exist");
        // p50 (rank 20 of 40) is a fast query: bound 4µs, not a blend.
        assert_eq!(ps[0], 4);
        // p99 (rank 40) is a slow query: bound 128µs.
        assert_eq!(ps[1], 128);
        assert!(merged_percentiles(&[], "lat_us", &[0.5]).is_none());
        assert!(merged_percentiles(&[&Scrape::default()], "lat_us", &[0.5]).is_none());
    }

    #[test]
    fn non_monotone_buckets_saturate_and_are_counted() {
        let before = crate::obs::registry::global().counter("fastbn_scrape_malformed_total").get();
        // a mid-write / corrupt exposition: cumulative counts dip at
        // le="2" — a plain u64 de-cumulation would underflow to ~2^64
        let text = "# TYPE lat_us histogram\n\
                    lat_us_bucket{le=\"1\"} 5\n\
                    lat_us_bucket{le=\"2\"} 3\n\
                    lat_us_bucket{le=\"4\"} 8\n\
                    lat_us_bucket{le=\"+Inf\"} 8";
        let s = parse(text);
        let ps = merged_percentiles(&[&s], "lat_us", &[0.5, 0.99]).expect("observations survive the clamp");
        // the corrupt bucket clamps to zero; ranks land in the real
        // buckets on either side of it, not at the top of the histogram
        assert_eq!(ps[0], 1);
        assert_eq!(ps[1], 4);
        let after = crate::obs::registry::global().counter("fastbn_scrape_malformed_total").get();
        assert!(after >= before + 1, "malformed exposition not counted: {before} -> {after}");
    }
}
