//! Per-query trace spans and the slow-query log.
//!
//! A [`span`] pushes onto a thread-local stack; one query's inference
//! runs on one worker thread, so the stack *is* the span tree. The
//! outermost guard's drop assembles a [`Trace`] and publishes it to a
//! bounded global ring (`TRACE last` reads the newest) and, when the
//! root duration crosses the configured slow threshold, to a separate
//! slow-query ring plus a `fastbn_slow_queries_total` counter on the
//! global registry.
//!
//! Spans are inert unless tracing is enabled (`TRACE on`) or a slow
//! threshold is set (`--slow-query-ms`): the fast path is one relaxed
//! atomic load. Instrumentation only reads the clock — it never touches
//! the numeric pipeline or any RNG, so posteriors are byte-identical
//! with tracing on or off (asserted in `tests/obs.rs`).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Completed traces retained for `TRACE last`.
const RING_CAP: usize = 64;
/// Slow-query outliers retained with their full span tree.
const SLOW_CAP: usize = 32;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SLOW_US: AtomicU64 = AtomicU64::new(0);

static RING: Mutex<VecDeque<Trace>> = Mutex::new(VecDeque::new());
static SLOW: Mutex<VecDeque<Trace>> = Mutex::new(VecDeque::new());

/// One timed region. `start_us` is relative to the trace root; `depth`
/// is the nesting level (0 = root).
#[derive(Clone, Debug)]
pub struct Span {
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    pub depth: usize,
    pub note: String,
}

/// A completed span tree, spans in start order. `at_unix_us` is the
/// publish instant (µs since the Unix epoch) — the cluster front uses
/// it to pick the freshest trace across replicas; `qid` is the
/// cluster-minted query id, if the query carried one.
#[derive(Clone, Debug)]
pub struct Trace {
    pub spans: Vec<Span>,
    pub total_us: u64,
    pub qid: Option<String>,
    pub at_unix_us: u64,
}

impl Trace {
    /// Single-line rendering (wire replies are one line per trace):
    /// `total_us=N root=Nus .child=Nus[note] … at=N [qid=qN]`. The
    /// `at=`/`qid=` tokens are appended at the **end** so every client
    /// asserting `starts_with("OK trace total_us=")` keeps parsing.
    pub fn render(&self) -> String {
        let mut out = format!("total_us={}", self.total_us);
        for s in &self.spans {
            out.push(' ');
            for _ in 0..s.depth {
                out.push('.');
            }
            out.push_str(&format!("{}={}us", s.name, s.dur_us));
            if !s.note.is_empty() {
                out.push_str(&format!("[{}]", s.note));
            }
        }
        out.push_str(&format!(" at={}", self.at_unix_us));
        if let Some(qid) = &self.qid {
            out.push_str(&format!(" qid={qid}"));
        }
        out
    }

    /// The root span, if any.
    pub fn root(&self) -> Option<&Span> {
        self.spans.first()
    }
}

struct Builder {
    started: Instant,
    open: Vec<usize>,
    spans: Vec<Span>,
    qid: Option<String>,
}

thread_local! {
    static BUILDER: RefCell<Option<Builder>> = const { RefCell::new(None) };
}

/// Enable/disable recording of every query into the trace ring.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is ring recording enabled?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Set the slow-query threshold in µs (0 disables the slow log).
pub fn set_slow_query_us(us: u64) {
    SLOW_US.store(us, Ordering::Relaxed);
}

/// Current slow-query threshold in µs.
pub fn slow_query_us() -> u64 {
    SLOW_US.load(Ordering::Relaxed)
}

/// Spans record only when someone is listening.
pub fn active() -> bool {
    enabled() || slow_query_us() > 0
}

/// Open a span. Returns an inert guard when tracing is off. Guards must
/// drop in LIFO order (natural with lexical scoping); the root guard's
/// drop publishes the trace.
pub fn span(name: &'static str) -> SpanGuard {
    if !active() {
        return SpanGuard { idx: None };
    }
    BUILDER.with(|cell| {
        let mut slot = cell.borrow_mut();
        let b = slot.get_or_insert_with(|| {
            Builder { started: Instant::now(), open: Vec::new(), spans: Vec::new(), qid: None }
        });
        let depth = b.open.len();
        let start_us = b.started.elapsed().as_micros() as u64;
        let idx = b.spans.len();
        b.spans.push(Span { name, start_us, dur_us: 0, depth, note: String::new() });
        b.open.push(idx);
        SpanGuard { idx: Some(idx) }
    })
}

/// Guard for an open span; closes it (and possibly the trace) on drop.
#[must_use = "a span guard times its scope; dropping it immediately records nothing"]
pub struct SpanGuard {
    idx: Option<usize>,
}

impl SpanGuard {
    /// Attach a note (shown in brackets by `Trace::render`). No-op on an
    /// inert guard. Notes must stay single-line for the wire format.
    pub fn note(&self, text: &str) {
        let Some(idx) = self.idx else { return };
        BUILDER.with(|cell| {
            if let Some(b) = cell.borrow_mut().as_mut() {
                if let Some(s) = b.spans.get_mut(idx) {
                    s.note = text.to_string();
                }
            }
        });
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(idx) = self.idx else { return };
        BUILDER.with(|cell| {
            let mut slot = cell.borrow_mut();
            let Some(b) = slot.as_mut() else { return };
            // Close every span down to ours: drops are LIFO under normal
            // control flow, and unwinds still close the whole subtree.
            while let Some(open) = b.open.pop() {
                let end = b.started.elapsed().as_micros() as u64;
                let s = &mut b.spans[open];
                s.dur_us = end.saturating_sub(s.start_us);
                if open == idx {
                    break;
                }
            }
            if b.open.is_empty() {
                let done = slot.take().unwrap();
                publish(done);
            }
        });
    }
}

/// Tag the thread's in-progress trace with a query id (the cluster
/// front mints these and backends thread them through the shard
/// dispatch). No-op when no trace is being built — so, like spans, it
/// costs nothing while tracing is inactive.
pub fn tag_qid(qid: &str) {
    BUILDER.with(|cell| {
        if let Some(b) = cell.borrow_mut().as_mut() {
            b.qid = Some(qid.to_string());
        }
    });
}

fn publish(b: Builder) {
    let total_us = b.spans.first().map(|s| s.dur_us).unwrap_or(0);
    let at_unix_us = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0);
    let trace = Trace { spans: b.spans, total_us, qid: b.qid, at_unix_us };
    if enabled() {
        let mut ring = RING.lock().unwrap();
        if ring.len() >= RING_CAP {
            ring.pop_front();
        }
        ring.push_back(trace.clone());
    }
    let slow = slow_query_us();
    if slow > 0 && total_us >= slow {
        crate::obs::global().counter("fastbn_slow_queries_total").inc();
        let mut ring = SLOW.lock().unwrap();
        if ring.len() >= SLOW_CAP {
            ring.pop_front();
        }
        ring.push_back(trace);
    }
}

/// The most recently completed trace, if recording has captured one.
pub fn last() -> Option<Trace> {
    RING.lock().unwrap().back().cloned()
}

/// The newest trace tagged with `qid`, searching the ring first and the
/// slow-query log as a fallback (a slow trace may have aged out of the
/// main ring but still be held by the slow log).
pub fn find(qid: &str) -> Option<Trace> {
    let hit = RING.lock().unwrap().iter().rev().find(|t| t.qid.as_deref() == Some(qid)).cloned();
    hit.or_else(|| SLOW.lock().unwrap().iter().rev().find(|t| t.qid.as_deref() == Some(qid)).cloned())
}

/// Snapshot of the slow-query log, oldest first.
pub fn slow_queries() -> Vec<Trace> {
    SLOW.lock().unwrap().iter().cloned().collect()
}

/// Serializes unit tests that flip the process-wide toggles, so a test
/// disabling tracing cannot race another between its enable and its
/// query. Lock with `lock().unwrap_or_else(|e| e.into_inner())` — a
/// poisoned lock just means another test failed.
#[cfg(test)]
pub(crate) static TEST_TOGGLE_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    // Trace globals are process-wide; keep every assertion keyed on the
    // unique span names below so concurrent tests cannot interfere.
    #[test]
    fn spans_nest_and_publish_on_root_drop() {
        let _serialized = TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        {
            let root = span("trace-test-root");
            {
                let child = span("trace-test-child");
                child.note("k=1");
                drop(child);
            }
            root.note("done");
        }
        set_enabled(false);
        let t = last().expect("a trace was recorded");
        // Another thread may have published since; only inspect ours.
        if t.root().map(|s| s.name) == Some("trace-test-root") {
            assert_eq!(t.spans.len(), 2);
            assert_eq!(t.spans[1].name, "trace-test-child");
            assert_eq!(t.spans[1].depth, 1);
            let line = t.render();
            assert!(line.contains("trace-test-root="), "{line}");
            assert!(line.contains(".trace-test-child="), "{line}");
            assert!(line.contains("[k=1]"), "{line}");
        }
    }

    #[test]
    fn qid_tag_is_published_and_findable() {
        let _serialized = TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        {
            let root = span("trace-test-qid-root");
            tag_qid("q900001");
            drop(root);
        }
        set_enabled(false);
        let t = find("q900001").expect("tagged trace is findable by qid");
        assert_eq!(t.qid.as_deref(), Some("q900001"));
        assert!(t.at_unix_us > 0, "publish stamps a wall-clock instant");
        let line = t.render();
        assert!(line.ends_with(" qid=q900001"), "{line}");
        assert!(line.contains(" at="), "{line}");
        assert!(find("q900001-never-minted").is_none());
    }

    #[test]
    fn inert_when_inactive() {
        let _serialized = TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // Not asserting on globals: just exercise the no-listener path.
        if !active() {
            let g = span("trace-test-inert");
            g.note("ignored");
            assert!(g.idx.is_none());
        }
    }
}
