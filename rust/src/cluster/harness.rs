//! In-process cluster topology for tests, benches, and fault injection.
//!
//! [`ClusterHarness`] stands a whole cluster up inside one process — N
//! backend [`FleetServer`]s on ephemeral ports, a front-tier
//! [`ClusterServer`] routing to them — while every hop still crosses a
//! real TCP socket, so the protocol surface under test is exactly what
//! separate processes would exercise, without per-test process spawning.
//! The one capability real processes can't offer a test: deterministic
//! murder. [`ClusterHarness::kill_backend`] shuts a backend's server
//! down in place (listener closed, connections dropped within the
//! server's read-timeout tick), which is how the fault-injection suite
//! in `rust/tests/cluster.rs` creates a mid-session backend death the
//! front tier must detect, reroute around, and report cleanly.
//!
//! Two more topology levers mirror the PR-8 capabilities: an **external
//! backend** ([`ClusterHarness::spawn_external_backend`]) runs like a
//! remote already-serving fleet — started *without* joining, so a test
//! adopts it through the `JOIN <addr>` verb exactly as an operator
//! would — and a **peer front router**
//! ([`ClusterHarness::start_peer_front`]) stands a second
//! independently-derived router over the same backends, which is what
//! the `HANDOFF` dual-router tests kill the primary against
//! ([`ClusterHarness::kill_primary_front`]).

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::backend::BackendConn;
use crate::cluster::front::Cluster;
use crate::cluster::server::ClusterServer;
use crate::cluster::ClusterConfig;
use crate::fleet::{Fleet, FleetConfig, FleetServer};
use crate::jt::evidence::Evidence;
use crate::{Error, Result};

struct BackendSlot {
    /// Cluster-assigned id; empty for an external backend until a `JOIN`
    /// adopts it and [`ClusterHarness::adopt_external_ids`] syncs it back.
    id: String,
    fleet: Arc<Fleet>,
    server: FleetServer,
}

/// A self-contained cluster: backends + front tier, all on ephemeral
/// ports. Dropping it tears everything down (fronts first, then probers,
/// then backends, so nothing routes at a half-dead topology).
pub struct ClusterHarness {
    backend_cfg: FleetConfig,
    cluster_cfg: ClusterConfig,
    backends: Vec<Option<BackendSlot>>,
    cluster: Arc<Cluster>,
    front: Option<ClusterServer>,
    peer: Option<(Arc<Cluster>, ClusterServer)>,
}

impl ClusterHarness {
    /// Spawn `n_backends` fleet servers and a front tier over them.
    /// `backend_cfg` is reused for late [`Self::add_backend`] joins;
    /// `cluster_cfg` for a late [`Self::start_peer_front`].
    pub fn start(n_backends: usize, backend_cfg: FleetConfig, cluster_cfg: ClusterConfig) -> Result<ClusterHarness> {
        let cluster = Cluster::start(cluster_cfg.clone())?;
        let mut harness =
            ClusterHarness { backend_cfg, cluster_cfg, backends: Vec::new(), cluster, front: None, peer: None };
        for _ in 0..n_backends {
            harness.add_backend()?;
        }
        harness.front = Some(ClusterServer::start(Arc::clone(&harness.cluster), "127.0.0.1:0")?);
        Ok(harness)
    }

    /// Spawn one more backend and join it — the membership-change lever
    /// (ownership of ~K/N networks hands off to the joiner). Returns the
    /// assigned backend id.
    pub fn add_backend(&mut self) -> Result<String> {
        let fleet = Arc::new(Fleet::new(self.backend_cfg.clone()));
        let server = FleetServer::start(Arc::clone(&fleet), "127.0.0.1:0")?;
        let id = self.cluster.join(server.addr())?;
        self.backends.push(Some(BackendSlot { id: id.clone(), fleet, server }));
        Ok(id)
    }

    /// Spawn a fleet server that is **not** joined to the cluster — from
    /// the front tier's point of view, an already-running remote
    /// `fastbn serve --fleet` process. Returns its address; the test
    /// adopts it with the `JOIN <addr>` verb (or `Cluster::join`), then
    /// calls [`Self::adopt_external_ids`] so the harness can address it
    /// by its assigned id.
    pub fn spawn_external_backend(&mut self) -> Result<SocketAddr> {
        let fleet = Arc::new(Fleet::new(self.backend_cfg.clone()));
        let server = FleetServer::start(Arc::clone(&fleet), "127.0.0.1:0")?;
        let addr = server.addr();
        self.backends.push(Some(BackendSlot { id: String::new(), fleet, server }));
        Ok(addr)
    }

    /// Sync cluster-assigned ids back onto external backend slots (by
    /// address) after `JOIN`s, so [`Self::kill_backend`] and
    /// [`Self::backend_fleet`] can address them.
    pub fn adopt_external_ids(&mut self) {
        let statuses = self.cluster.backends();
        for slot in self.backends.iter_mut().flatten() {
            if slot.id.is_empty() {
                if let Some(s) = statuses.iter().find(|s| s.addr == slot.server.addr()) {
                    slot.id = s.id.clone();
                }
            }
        }
    }

    /// Kill a backend in place: its listener closes and its connections
    /// drop. The cluster is *not* told — discovery (session report or
    /// prober) is the behavior under test. Returns false for an unknown
    /// or already-killed id.
    pub fn kill_backend(&mut self, id: &str) -> bool {
        for slot in self.backends.iter_mut() {
            if slot.as_ref().map(|s| s.id == id).unwrap_or(false) {
                let s = slot.take().expect("checked above");
                s.server.shutdown();
                drop(s.fleet);
                return true;
            }
        }
        false
    }

    /// Stand up a **second front router** over the same backends: a fresh
    /// [`Cluster`] with the same config that joins every backend the
    /// primary currently sees alive (in id order, so the deterministic
    /// ring re-derives the identical placement under the identical ids)
    /// and re-`LOAD`s the primary's directory specs — backend `LOAD` is
    /// compile-once, so already-resident nets cache-hit and the peer's
    /// directory converges on the same replica sets without any
    /// router-to-router state transfer. Returns the peer's client
    /// address. Session state does *not* converge by itself — that is
    /// what the `HANDOFF` verb is for.
    pub fn start_peer_front(&mut self) -> Result<SocketAddr> {
        if self.peer.is_some() {
            return Err(Error::msg("peer front already running"));
        }
        let peer = Cluster::start(self.cluster_cfg.clone())?;
        // Cluster::backends() is id-sorted; join order fixes the peer's
        // id assignment to match the primary's
        for s in self.cluster.backends().iter().filter(|s| s.alive) {
            peer.join(s.addr)?;
        }
        for (net, _) in self.cluster.directory() {
            let Some(spec) = self.cluster.spec_of(&net) else { continue };
            let reply = peer.load(&spec);
            if !reply.starts_with("OK") {
                peer.shutdown();
                return Err(Error::msg(format!("peer front failed to re-load {net:?}: {reply}")));
            }
        }
        let server = ClusterServer::start(Arc::clone(&peer), "127.0.0.1:0")?;
        let addr = server.addr();
        self.peer = Some((peer, server));
        Ok(addr)
    }

    /// The peer front's router state, if one is running.
    pub fn peer_cluster(&self) -> Option<&Arc<Cluster>> {
        self.peer.as_ref().map(|(c, _)| c)
    }

    /// Address clients connect to on the peer front, if one is running.
    pub fn peer_front_addr(&self) -> Option<SocketAddr> {
        self.peer.as_ref().map(|(_, s)| s.addr())
    }

    /// Kill the **primary** front router: its listener closes, every
    /// client session on it drops, its prober stops. The backends (and a
    /// peer front, if any) keep running — the dual-router failover
    /// surface. Returns false if it was already killed.
    pub fn kill_primary_front(&mut self) -> bool {
        let Some(front) = self.front.take() else { return false };
        front.shutdown();
        self.cluster.shutdown();
        true
    }

    /// The primary front-tier router state (ownership, health,
    /// directory).
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Address clients connect to (the primary front).
    pub fn front_addr(&self) -> SocketAddr {
        self.front.as_ref().expect("primary front is running").addr()
    }

    /// Direct handle to a live backend's in-process fleet — the
    /// full-precision oracle surface (wire replies round to 6 decimals;
    /// consistency tests at 1e-9 need the actual `Posteriors`).
    pub fn backend_fleet(&self, id: &str) -> Option<Arc<Fleet>> {
        self.backends
            .iter()
            .flatten()
            .find(|s| s.id == id)
            .map(|s| Arc::clone(&s.fleet))
    }

    /// Ids of backends the harness still has running (externals show up
    /// once adopted).
    pub fn live_backend_ids(&self) -> Vec<String> {
        self.backends.iter().flatten().filter(|s| !s.id.is_empty()).map(|s| s.id.clone()).collect()
    }

    /// A TCP client session against the primary front, with bounded
    /// timeouts so a routing bug is a test failure, not a hang.
    pub fn client(&self) -> Result<ClusterClient> {
        ClusterClient::connect(self.front_addr())
    }

    /// A TCP client session against the peer front.
    pub fn peer_client(&self) -> Result<ClusterClient> {
        let addr = self.peer_front_addr().ok_or_else(|| Error::msg("no peer front running"))?;
        ClusterClient::connect(addr)
    }
}

impl Drop for ClusterHarness {
    fn drop(&mut self) {
        if let Some((peer, server)) = self.peer.take() {
            server.shutdown();
            peer.shutdown();
        }
        if let Some(front) = self.front.take() {
            front.shutdown();
        }
        self.cluster.shutdown();
        for slot in self.backends.iter_mut() {
            if let Some(s) = slot.take() {
                s.server.shutdown();
            }
        }
    }
}

/// Line-protocol client for driving a front tier (or any fleet server)
/// from tests and benches.
pub struct ClusterClient {
    conn: BackendConn,
}

impl ClusterClient {
    /// Connect with test-friendly bounds (1s connect, 10s per reply).
    pub fn connect(addr: SocketAddr) -> Result<ClusterClient> {
        let conn = BackendConn::connect(addr, Duration::from_secs(1), Duration::from_secs(10))
            .map_err(Error::Io)?;
        Ok(ClusterClient { conn })
    }

    /// One request line → one reply line.
    pub fn request(&mut self, line: &str) -> Result<String> {
        self.conn.request(line).map_err(Error::Io)
    }

    /// One request line → `n` reply lines (the final `CASE` of an n-case
    /// `BATCH` comes back as n result lines).
    pub fn request_lines(&mut self, line: &str, n: usize) -> Result<Vec<String>> {
        self.conn.request_lines(line, n).map_err(Error::Io)
    }
}

/// Render a `QUERY` protocol line for `target` under `ev` — the inline
/// `var=state` grammar both the fleet and cluster servers accept.
/// Shared by the consistency tests and the cluster bench.
pub fn query_line(net: &crate::bn::network::Network, target: &str, ev: &Evidence) -> String {
    let mut line = format!("QUERY {target}");
    let mut first = true;
    for v in 0..net.n() {
        if let Some(s) = ev.get(v) {
            line.push_str(if first { " |" } else { "" });
            first = false;
            line.push_str(&format!(" {}={}", net.vars[v].name, net.vars[v].states[s]));
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, EngineKind};

    fn harness(n: usize) -> ClusterHarness {
        ClusterHarness::start(
            n,
            FleetConfig {
                engine: EngineKind::Seq,
                engine_cfg: EngineConfig::default().with_threads(1),
                shards: 1,
                registry_capacity: 8,
                max_exact_cost: f64::INFINITY,
            },
            ClusterConfig {
                connect_timeout: Duration::from_millis(500),
                io_timeout: Duration::from_secs(5),
                probe_timeout: Duration::from_millis(500),
                probe_interval: Duration::from_millis(100),
                probe_backoff_max: Duration::from_secs(1),
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn one_backend_roundtrip_through_the_front_tier() {
        let h = harness(1);
        let mut c = h.client().unwrap();
        let r = c.request("LOAD asia").unwrap();
        assert!(r.starts_with("OK loaded asia"), "{r}");
        assert!(r.contains("backend=b0"), "{r}");
        assert!(r.contains("replicas=1"), "{r}");
        assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
        assert!(c.request("QUERY lung | smoke=yes").unwrap().starts_with("OK yes=0.100000"));
        assert_eq!(h.cluster().owner("asia"), Some("b0".to_string()));
        let topo = c.request("TOPO").unwrap();
        assert!(topo.contains("b0[addr="), "{topo}");
        assert!(topo.contains("nets=1"), "{topo}");
    }

    #[test]
    fn streamed_evidence_lives_on_the_backend_session() {
        let h = harness(2);
        let mut c = h.client().unwrap();
        c.request("LOAD asia").unwrap();
        assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
        assert!(c.request("OBSERVE smoke=yes").unwrap().starts_with("OK staged 1"));
        assert!(c.request("COMMIT").unwrap().starts_with("OK committed evidence=1"));
        assert!(c.request("QUERY lung").unwrap().starts_with("OK yes=0.100000"));
        // a second front session shares the net but not the evidence
        let mut c2 = h.client().unwrap();
        assert!(c2.request("USE asia").unwrap().starts_with("OK using asia"));
        assert!(c2.request("QUERY lung").unwrap().starts_with("OK yes=0.055000"));
    }

    #[test]
    fn mpe_round_trips_through_the_front_tier() {
        let h = harness(2);
        let mut c = h.client().unwrap();
        c.request("LOAD asia").unwrap();
        assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
        // clean session: MPE spreads over replicas exactly like QUERY —
        // replicas are byte-identical, so the reply is too
        let prior = c.request("MPE").unwrap();
        assert!(prior.starts_with("OK mpe logp=-"), "{prior}");
        let smoking = c.request("MPE | smoke=yes").unwrap();
        assert!(smoking.contains(" smoke=yes"), "{smoking}");
        // evidence-bearing session: the pinned conn answers identically
        assert!(c.request("OBSERVE smoke=yes").unwrap().starts_with("OK staged 1"));
        assert!(c.request("COMMIT").unwrap().starts_with("OK committed evidence=1"));
        assert_eq!(c.request("MPE").unwrap(), smoking);
        // batched MPE through the front: n CASE lines in, n assignment
        // lines out, matching the single-verb replies byte-for-byte
        assert!(c.request("RETRACT smoke").unwrap().starts_with("OK retracted"));
        assert!(c.request("COMMIT").unwrap().starts_with("OK committed evidence=0"));
        assert_eq!(c.request("BATCH 2 MPE").unwrap(), "OK batch expect=2 target=MPE");
        assert_eq!(c.request("CASE smoke=yes").unwrap(), "OK case 1/2");
        let lines = c.request_lines("CASE", 2).unwrap();
        assert_eq!(lines[0], smoking);
        assert_eq!(lines[1], prior);
    }

    #[test]
    fn graceful_leave_hands_networks_off_and_forgets_the_backend() {
        let h = harness(2);
        let mut c = h.client().unwrap();
        assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
        assert!(c.request("LOAD cancer").unwrap().starts_with("OK loaded cancer"));
        let leaver = h.cluster().owner("asia").unwrap();
        let stayer = h.live_backend_ids().into_iter().find(|id| *id != leaver).unwrap();

        h.cluster().leave(&leaver).unwrap();
        // both nets now live on the stayer, with the hand-off completed:
        // resident there, evicted from the leaver's (still running) fleet
        for net in ["asia", "cancer"] {
            assert_eq!(h.cluster().owner(net).as_deref(), Some(stayer.as_str()), "{net}");
            assert!(h.backend_fleet(&stayer).unwrap().tree(net).is_some(), "{net} not on {stayer}");
        }
        assert!(h.backend_fleet(&leaver).unwrap().tree("asia").is_none(), "asia still resident on {leaver}");
        // the leaver is forgotten entirely
        assert_eq!(h.cluster().backends().len(), 1);
        assert!(h.cluster().leave(&leaver).is_err(), "double leave must error");
        // and service continues through the front tier
        assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
        assert!(c.request("QUERY lung | smoke=yes").unwrap().starts_with("OK yes=0.100000"));
    }

    #[test]
    fn external_backend_is_adopted_via_the_join_verb() {
        let mut h = harness(1);
        let ext = h.spawn_external_backend().unwrap();
        // the front knows nothing about it until a client JOINs it
        assert_eq!(h.cluster().backends().len(), 1);
        let mut c = h.client().unwrap();
        let r = c.request(&format!("JOIN {ext}")).unwrap();
        assert!(r.starts_with("OK joined b1 addr="), "{r}");
        assert!(c.request(&format!("JOIN {ext}")).unwrap().starts_with("ERR backend b1"), "double join must error");
        h.adopt_external_ids();
        assert!(h.live_backend_ids().contains(&"b1".to_string()));
        // the adopted backend serves like any spawned one
        assert!(c.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
        assert!(c.request("USE asia").unwrap().starts_with("OK using asia"));
        assert!(c.request("QUERY lung | smoke=yes").unwrap().starts_with("OK yes=0.100000"));
    }

    #[test]
    fn query_line_renders_inline_evidence() {
        let net = crate::bn::embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        assert_eq!(query_line(&net, "lung", &ev), "QUERY lung | smoke=yes");
        assert_eq!(query_line(&net, "lung", &Evidence::none()), "QUERY lung");
    }
}
