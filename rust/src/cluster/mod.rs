//! The cross-process cluster tier — many fleet *processes*, one front
//! router.
//!
//! [`crate::fleet`] scales one process to many networks; this module
//! scales past the process boundary: a front-tier [`front::Cluster`] owns
//! a deterministic consistent-hash [`ring::Ring`] mapping each network
//! name to its R replica owners among N backend fleet processes
//! ([`ring::Ring::owners`] successor walk), proxies the existing line
//! protocol to an owning backend over TCP ([`backend::BackendConn`]),
//! and manages membership — a join (spawned child or an already-running
//! remote fleet adopted via the `JOIN <addr>` verb / `--join-hosts`) or
//! graceful leave re-homes networks (`LOAD` on new owners, `EVICT` on
//! old), a health prober with exponential backoff marks dead backends
//! and reroutes their networks to surviving replicas, and cluster-wide
//! `STATS` aggregates every backend's snapshot.
//!
//! ```text
//!            clients (same line protocol as a single fleet)
//!                │                       │
//!        ┌───────▼────────┐      ┌───────▼────────┐
//!        │  ClusterServer │      │  peer router   │  same ring, same
//!        │   (front tier) │◄────►│  (optional)    │  placement — sessions
//!        └──┬─────────┬───┘ HANDOFF └─┬───────────┘  replay via HANDOFF
//!     TCP   │         │               │
//!    ┌──────▼───┐ ┌───▼──────┐ ┌──────▼───┐
//!    │ fleet b0 │ │ fleet b1 │ │ fleet b2 │ … backends, each net on R
//!    └──────────┘ └──────────┘ └──────────┘   replicas (byte-identical)
//! ```
//!
//! Front-tier verbs beyond the fleet protocol: `PING` (front liveness +
//! topology counts), `TOPO` (per-backend health and ownership), `JOIN
//! <addr>` (adopt a running backend over TCP), and `HANDOFF` (export a
//! session's committed evidence / replay it on a peer router — see
//! [`front::ClusterSession`]). `TRACE` and `PROFILE` are answered by the
//! front as cluster-wide scrapes: `TRACE on|off` broadcasts the recorder
//! toggle and arms per-query id minting (each `QUERY`/`MPE` is tagged
//! `#q<n>` on the wire and its `OK` reply carries ` qid=q<n>`), `TRACE
//! last` returns the freshest trace across all alive backends tagged
//! `backend="id"`, `TRACE q<n>` assembles one tagged query's cross-tier
//! timeline (front route → owner → its span tree), and `PROFILE` merges
//! every backend's pool-parallelism report with `backend="id"` prefixes.
//! Sessions are *sticky*: `USE` pins the session to an owning backend's
//! connection so streamed `OBSERVE`/`COMMIT` state lives where the tree
//! lives; when ownership moves (rebalance or failover) the next verb gets
//! a clean `ERR … USE it again` instead of silently rerouting — stale
//! evidence must never be misapplied to a freshly compiled tree. A
//! session that has *no* evidence in flight is not pinned at all: its
//! `QUERY`s round-robin across alive replicas and hop to a surviving
//! replica transparently when one dies, because every replica answers
//! byte-identically.
//!
//! [`harness::ClusterHarness`] spins a whole topology up in-process (real
//! TCP, ephemeral ports) and can kill backends mid-session — the
//! fault-injection surface `rust/tests/cluster.rs` drives.

pub mod backend;
pub mod front;
pub mod harness;
pub mod ring;
pub mod server;

use std::time::Duration;

pub use backend::BackendConn;
pub use front::{BackendStatus, Cluster, ClusterSession, Confirm, Lookup};
pub use harness::{ClusterClient, ClusterHarness};
pub use ring::Ring;
pub use server::ClusterServer;

/// Front-tier construction parameters.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Replication factor R: each network is placed on the first R
    /// distinct members clockwise from its hash ([`Ring::owners`]).
    /// Replicas are byte-identical by construction (same spec → same
    /// deterministic compile; `learn:` specs re-learn bit-identically),
    /// so read-only `QUERY`/`BATCH` spread across them and fail over
    /// inside the set without an error reply, while session verbs stay
    /// pinned to one replica. Clamped to ≥ 1; clamped to the member
    /// count at placement time.
    pub replicas: usize,
    /// Virtual points per backend on the consistent-hash ring.
    pub vnodes: usize,
    /// TCP connect bound for every backend socket.
    pub connect_timeout: Duration,
    /// Read/write bound on data-plane and control-plane requests
    /// (covers a backend-side `LOAD` compile).
    pub io_timeout: Duration,
    /// Read bound on control-plane requests that run the **learning
    /// pipeline** on a backend (`LEARN`, and hand-off re-`LOAD`s of
    /// `learn:` specs). Learning a large sample count takes orders of
    /// magnitude longer than a tree compile, so it gets its own budget —
    /// size it to the biggest learn the deployment allows. Client
    /// `LEARN`s run outside the control mutex, but hand-off
    /// **re-learning inside a rebalance** is a serialized transition
    /// like any other: while it runs, further membership changes queue
    /// behind it for up to this long per learned net (async hand-off
    /// re-learning is a ROADMAP follow-up).
    pub learn_timeout: Duration,
    /// Read bound on health probes — short, so a wedged backend stalls
    /// the prober for at most this long.
    pub probe_timeout: Duration,
    /// Health-probe cadence for live backends.
    pub probe_interval: Duration,
    /// Probe backoff cap for dead backends (doubles from
    /// `probe_interval` up to this).
    pub probe_backoff_max: Duration,
    /// Consecutive failed probes before a live backend is marked dead.
    pub fail_threshold: u32,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            vnodes: 64,
            connect_timeout: Duration::from_secs(1),
            io_timeout: Duration::from_secs(10),
            learn_timeout: Duration::from_secs(300),
            probe_timeout: Duration::from_secs(1),
            probe_interval: Duration::from_secs(1),
            probe_backoff_max: Duration::from_secs(8),
            fail_threshold: 2,
        }
    }
}
