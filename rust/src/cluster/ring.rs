//! Deterministic consistent-hash ring: network name → backend id.
//!
//! Placement must be reproducible across processes, hosts, and runs — a
//! restarted front tier has to re-derive the same ownership map the old
//! one advertised, and two front tiers (a future multi-router deployment)
//! must agree without talking. So the hash is fixed rather than seeded:
//! FNV-1a (64-bit) over the key bytes, then a murmur3-style avalanche
//! finalizer. Plain FNV clusters badly on short, similar strings (all of
//! `net-000 … net-199` can land on one member); the finalizer spreads the
//! high bits the `BTreeSet` ordering routes on.
//!
//! Each member contributes `vnodes` virtual points so load splits
//! evenly and membership change moves only the keys adjacent to the
//! joining/leaving member's points — the minimal-movement property the
//! unit tests pin down with concrete margins.
//!
//! Replication reuses the same walk: [`Ring::owners`] takes the first R
//! *distinct* members clockwise from the key's hash (the classic
//! successor-list placement), so `owners(k, 1)[0] == owner(k)` and a
//! membership change perturbs replica sets as minimally as it perturbs
//! single ownership.

use std::collections::BTreeSet;

/// Fixed 64-bit hash: FNV-1a over the bytes, then a murmur3 `fmix64`
/// avalanche. Deterministic across processes and runs by construction
/// (no per-process seeding à la `RandomState`).
pub fn hash64(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// Consistent-hash ring over backend ids.
///
/// A key is owned by the member whose virtual point is the first at or
/// clockwise after the key's hash (wrapping). Points are `(hash, id)`
/// pairs, so a (vanishingly unlikely) point collision between two members
/// resolves by id order — ownership never depends on insertion order.
pub struct Ring {
    vnodes: usize,
    points: BTreeSet<(u64, String)>,
    members: BTreeSet<String>,
}

impl Ring {
    /// Empty ring; each member will contribute `vnodes` points
    /// (clamped to ≥ 1).
    pub fn new(vnodes: usize) -> Self {
        Ring { vnodes: vnodes.max(1), points: BTreeSet::new(), members: BTreeSet::new() }
    }

    /// Add a member (idempotent).
    pub fn add(&mut self, id: &str) {
        if !self.members.insert(id.to_string()) {
            return;
        }
        for k in 0..self.vnodes {
            self.points.insert((hash64(&format!("{id}#{k}")), id.to_string()));
        }
    }

    /// Remove a member (idempotent).
    pub fn remove(&mut self, id: &str) {
        if !self.members.remove(id) {
            return;
        }
        for k in 0..self.vnodes {
            self.points.remove(&(hash64(&format!("{id}#{k}")), id.to_string()));
        }
    }

    /// The member owning `key`, or `None` on an empty ring.
    pub fn owner(&self, key: &str) -> Option<String> {
        let h = hash64(key);
        self.points
            .range((h, String::new())..)
            .next()
            .or_else(|| self.points.iter().next())
            .map(|(_, id)| id.clone())
    }

    /// The first `r` *distinct* members clockwise from `key`'s hash —
    /// the replica set for `key`, primary first. Clamped to the member
    /// count (and to ≥ 1), so a 2-member ring asked for R=3 returns both
    /// members rather than duplicating one. `owners(key, 1)` is exactly
    /// `[owner(key)]`.
    pub fn owners(&self, key: &str, r: usize) -> Vec<String> {
        let want = r.max(1).min(self.members.len());
        let mut out: Vec<String> = Vec::with_capacity(want);
        if want == 0 {
            return out;
        }
        let h = hash64(key);
        // one full wrap: the clockwise tail, then the whole ring from the
        // start (duplicate points past the wrap are skipped by the
        // distinctness check before `out` fills up)
        for (_, id) in self.points.range((h, String::new())..).chain(self.points.iter()) {
            if !out.iter().any(|o| o == id) {
                out.push(id.clone());
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    /// Current members, sorted.
    pub fn members(&self) -> Vec<String> {
        self.members.iter().cloned().collect()
    }

    /// Whether `id` is a member.
    pub fn contains(&self, id: &str) -> bool {
        self.members.contains(id)
    }

    /// Number of members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True with no members.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("net-{i:03}")).collect()
    }

    fn ring_of(ids: &[&str]) -> Ring {
        let mut r = Ring::new(64);
        for id in ids {
            r.add(id);
        }
        r
    }

    #[test]
    fn hash_is_pinned_across_runs_and_processes() {
        // literal expected values: any accidental seeding (RandomState,
        // time, pid) or a drive-by change to the mixing constants fails
        // here, not in a cross-host ownership disagreement
        assert_eq!(hash64("asia"), 0x9c73_0338_2b18_cc74);
        assert_eq!(hash64("b0#0"), 0x795f_e381_668b_9d96);
        assert_eq!(hash64("asia"), hash64("asia"));
        assert_ne!(hash64("b0"), hash64("b1"));
    }

    #[test]
    fn ownership_is_insertion_order_independent() {
        let ab = ring_of(&["b0", "b1", "b2"]);
        let ba = ring_of(&["b2", "b0", "b1"]);
        for k in keys(100) {
            assert_eq!(ab.owner(&k), ba.owner(&k), "{k}");
        }
    }

    #[test]
    fn add_is_minimal_movement_with_a_concrete_margin() {
        const K: usize = 200;
        let before = ring_of(&["b0", "b1", "b2"]);
        let after = ring_of(&["b0", "b1", "b2", "b3"]);
        let mut moved = 0usize;
        for k in keys(K) {
            let (was, is) = (before.owner(&k).unwrap(), after.owner(&k).unwrap());
            if was != is {
                // movement only ever targets the new member — keys never
                // shuffle between survivors (the exact ring property)
                assert_eq!(is, "b3", "{k} moved {was} -> {is}");
                moved += 1;
            }
        }
        // expected movement is K/N = 50 of 200 keys; at 64 points per
        // member the concentration is good enough for a 1.75x margin
        // (the fixed hash makes this exact: 38 keys move)
        assert!(moved >= 1, "a K/N-sized join moved nothing");
        assert!(moved <= K / 4 * 7 / 4, "moved {moved} of {K}, want ≤ {}", K / 4 * 7 / 4);
    }

    #[test]
    fn remove_moves_exactly_the_removed_members_keys() {
        let before = ring_of(&["b0", "b1", "b2"]);
        let after = ring_of(&["b0", "b2"]);
        for k in keys(200) {
            let was = before.owner(&k).unwrap();
            let is = after.owner(&k).unwrap();
            if was == "b1" {
                assert_ne!(is, "b1");
            } else {
                assert_eq!(was, is, "{k} moved {was} -> {is} though b1 never owned it");
            }
        }
    }

    #[test]
    fn load_is_roughly_balanced() {
        let ring = ring_of(&["b0", "b1", "b2", "b3"]);
        let mut counts = std::collections::BTreeMap::new();
        for k in keys(200) {
            *counts.entry(ring.owner(&k).unwrap()).or_insert(0usize) += 1;
        }
        // fixed hash → fixed split (56/59/47/38 at 64 replicas); assert a
        // loose band so the margin survives replica-count tuning
        for (id, n) in &counts {
            assert!((10..=100).contains(n), "{id} owns {n} of 200");
        }
        assert_eq!(counts.len(), 4);
    }

    #[test]
    fn owners_walk_is_distinct_primary_first_and_clamped() {
        let ring = ring_of(&["b0", "b1", "b2", "b3"]);
        for k in keys(100) {
            let two = ring.owners(&k, 2);
            assert_eq!(two.len(), 2, "{k}");
            assert_ne!(two[0], two[1], "{k}: duplicate replica");
            // primary of the replica set is the single-owner answer
            assert_eq!(two[0], ring.owner(&k).unwrap(), "{k}");
            assert_eq!(ring.owners(&k, 1), vec![ring.owner(&k).unwrap()], "{k}");
            // R past the member count clamps: all four members, distinct
            let all = ring.owners(&k, 9);
            assert_eq!(all.len(), 4, "{k}");
            let set: BTreeSet<&String> = all.iter().collect();
            assert_eq!(set.len(), 4, "{k}: owners(_, 9) repeated a member");
            // R=0 clamps to 1 (a replicated deployment never loses the primary)
            assert_eq!(ring.owners(&k, 0), vec![all[0].clone()], "{k}");
        }
        assert!(Ring::new(64).owners("asia", 2).is_empty(), "empty ring has no owners");
    }

    #[test]
    fn owners_move_minimally_on_join() {
        const K: usize = 200;
        let before = ring_of(&["b0", "b1", "b2"]);
        let after = ring_of(&["b0", "b1", "b2", "b3"]);
        let mut changed = 0usize;
        for k in keys(K) {
            let was: BTreeSet<String> = before.owners(&k, 2).into_iter().collect();
            let is: BTreeSet<String> = after.owners(&k, 2).into_iter().collect();
            if was != is {
                // a join only ever swaps the new member in — survivors
                // never trade a key's replica slot among themselves
                assert!(is.contains("b3"), "{k}: {was:?} -> {is:?} without b3");
                assert_eq!(was.difference(&is).count(), 1, "{k}: {was:?} -> {is:?}");
                changed += 1;
            }
        }
        // expected churn ~ 2·K/N = 100; fixed hash keeps it well inside 2x
        assert!(changed >= 1 && changed <= K, "changed {changed} of {K}");
    }

    #[test]
    fn membership_edge_cases() {
        let mut r = Ring::new(0); // clamps to 1 vnode
        assert!(r.is_empty());
        assert_eq!(r.owner("asia"), None);
        r.add("b0");
        r.add("b0"); // idempotent
        assert_eq!(r.len(), 1);
        assert_eq!(r.owner("anything"), Some("b0".to_string()));
        r.remove("b1"); // not a member: no-op
        r.remove("b0");
        assert!(r.is_empty());
        assert_eq!(r.owner("asia"), None);
        assert_eq!(ring_of(&["b0", "b1"]).members(), vec!["b0".to_string(), "b1".to_string()]);
        assert!(ring_of(&["b0"]).contains("b0"));
    }
}
