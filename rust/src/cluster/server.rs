//! TCP front end for a [`Cluster`] (`fastbn cluster …`).
//!
//! The accept loop, per-connection threads, reaping, and shutdown are the
//! shared [`LineServer`] scaffolding — identical behavior to the fleet
//! server (slow clients, gauges, drop semantics); each connection drives
//! a [`ClusterSession`] that proxies to backends instead of an
//! in-process fleet.

use std::sync::Arc;

use crate::cluster::front::{Cluster, ClusterSession};
use crate::coordinator::server::LineServer;
use crate::fleet::SessionReply;
use crate::Result;

/// Server handle; dropping it stops accepting and joins every thread.
pub struct ClusterServer {
    inner: LineServer,
    cluster: Arc<Cluster>,
}

impl ClusterServer {
    /// Start serving `cluster` on `bind` (port 0 for an ephemeral port).
    pub fn start(cluster: Arc<Cluster>, bind: &str) -> Result<ClusterServer> {
        let session_cluster = Arc::clone(&cluster);
        let inner = LineServer::start(bind, "cluster-accept", move || {
            let mut session = ClusterSession::new(Arc::clone(&session_cluster));
            Box::new(move |line: &str| match session.handle(line) {
                SessionReply::Line(reply) => Some(reply),
                SessionReply::Quit => None,
            })
        })?;
        Ok(ClusterServer { inner, cluster })
    }

    /// Bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// The cluster being served.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Live connection count.
    pub fn active_connections(&self) -> usize {
        self.inner.active_connections()
    }

    /// Finished connection threads joined by the accept loop so far.
    pub fn reaped_connections(&self) -> u64 {
        self.inner.reaped_connections()
    }

    /// Stop accepting and wait for every connection thread to end.
    pub fn shutdown(mut self) {
        self.inner.stop_and_join();
    }
}
