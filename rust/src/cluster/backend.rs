//! Line-protocol client for one backend fleet process.
//!
//! The front tier speaks to backends over the same TCP line protocol the
//! fleet serves to everyone else — there is no private RPC surface, so
//! anything the router does (LOAD, EVICT, PING, STATS) an operator can
//! replay by hand with `nc`. Every socket carries connect/read/write
//! timeouts: a dead or wedged backend turns into a bounded `Err`, never a
//! hang, which is what lets the fault-injection tests assert "clean
//! protocol error" with a deadline.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// One request/reply TCP connection to a backend.
///
/// Sticky sessions (a client's `USE`/`OBSERVE`/`COMMIT` state lives in the
/// *backend's* session) hold one of these open per selected backend;
/// control-plane verbs open short-lived ones.
pub struct BackendConn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl BackendConn {
    /// Connect with a bounded connect timeout; reads and writes on the
    /// resulting connection are bounded by `io_timeout`.
    pub fn connect(addr: SocketAddr, connect_timeout: Duration, io_timeout: Duration) -> std::io::Result<Self> {
        let stream = TcpStream::connect_timeout(&addr, connect_timeout)?;
        stream.set_read_timeout(Some(io_timeout))?;
        stream.set_write_timeout(Some(io_timeout))?;
        let _ = stream.set_nodelay(true); // latency over batching; best effort
        let reader = BufReader::new(stream.try_clone()?);
        Ok(BackendConn { stream, reader })
    }

    /// Send one request line, read one reply line.
    ///
    /// Any error — timeout included — poisons the connection as far as the
    /// caller is concerned: a timed-out read may leave a half-consumed
    /// reply in the buffer, so callers drop the conn and reconnect rather
    /// than retry on it.
    pub fn request(&mut self, line: &str) -> std::io::Result<String> {
        let mut lines = self.request_lines(line, 1)?;
        Ok(lines.pop().expect("request_lines(_, 1) returns one line"))
    }

    /// Send one request line, read exactly `n` reply lines — the `BATCH`
    /// passthrough: the final `CASE` line of an n-case batch comes back as
    /// n result lines. Timeout/EOF poisons the conn exactly like
    /// [`BackendConn::request`].
    pub fn request_lines(&mut self, line: &str, n: usize) -> std::io::Result<Vec<String>> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut reply = String::new();
            let got = self.reader.read_line(&mut reply)?;
            if got == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "backend closed the connection",
                ));
            }
            out.push(reply.trim_end().to_string());
        }
        Ok(out)
    }

    /// Send one request line, read a counted reply block: a header line
    /// carrying `lines=<n>` (the `METRICS` reply shape) followed by
    /// exactly n body lines. A header without `lines=` — an `ERR`, or an
    /// old backend — is returned with an empty body rather than guessed
    /// at. Timeout/EOF poisons the conn exactly like
    /// [`BackendConn::request`].
    pub fn request_block(&mut self, line: &str) -> std::io::Result<(String, Vec<String>)> {
        let header = self.request(line)?;
        let n: usize = header
            .split_whitespace()
            .find_map(|tok| tok.strip_prefix("lines="))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        let mut body = Vec::with_capacity(n);
        for _ in 0..n {
            let mut reply = String::new();
            let got = self.reader.read_line(&mut reply)?;
            if got == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "backend closed the connection mid-block",
                ));
            }
            body.push(reply.trim_end().to_string());
        }
        Ok((header, body))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, EngineKind};
    use crate::fleet::{Fleet, FleetConfig, FleetServer};
    use std::sync::Arc;

    fn backend() -> FleetServer {
        let fleet = Arc::new(Fleet::new(FleetConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            shards: 1,
            registry_capacity: 4,
            max_exact_cost: f64::INFINITY,
        }));
        FleetServer::start(fleet, "127.0.0.1:0").unwrap()
    }

    #[test]
    fn request_roundtrips_one_line() {
        let server = backend();
        let mut conn =
            BackendConn::connect(server.addr(), Duration::from_secs(1), Duration::from_secs(2)).unwrap();
        assert!(conn.request("PING").unwrap().starts_with("OK pong"));
        assert!(conn.request("LOAD asia").unwrap().starts_with("OK loaded asia"));
        server.shutdown();
    }

    #[test]
    fn request_block_reads_a_counted_reply() {
        let server = backend();
        let mut conn =
            BackendConn::connect(server.addr(), Duration::from_secs(1), Duration::from_secs(2)).unwrap();
        conn.request("LOAD asia").unwrap();
        conn.request("USE asia").unwrap();
        assert!(conn.request("QUERY lung").unwrap().starts_with("OK yes="));
        let (header, body) = conn.request_block("METRICS").unwrap();
        assert!(header.starts_with("OK metrics lines="), "{header}");
        assert!(body.iter().any(|l| l == "fastbn_queries_total{net=\"asia\"} 1"), "{body:?}");
        // a non-counted reply has an empty body and the conn stays usable
        let (header, body) = conn.request_block("PING").unwrap();
        assert!(header.starts_with("OK pong"), "{header}");
        assert!(body.is_empty());
        assert!(conn.request("PING").unwrap().starts_with("OK pong"));
        server.shutdown();
    }

    #[test]
    fn dead_backend_is_a_bounded_error_not_a_hang() {
        let server = backend();
        let addr = server.addr();
        let mut conn = BackendConn::connect(addr, Duration::from_secs(1), Duration::from_secs(2)).unwrap();
        server.shutdown();
        let t0 = std::time::Instant::now();
        // the listener is gone: the in-flight conn errors (EOF/reset) and a
        // fresh connect is refused — both within the configured timeouts
        assert!(conn.request("PING").is_err());
        assert!(BackendConn::connect(addr, Duration::from_secs(1), Duration::from_secs(2)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(8), "not bounded: {:?}", t0.elapsed());
    }
}
