//! The front-tier router: membership, ownership, failover, and proxying.
//!
//! One [`Cluster`] owns three pieces of state behind a short-hold lock —
//! the consistent-hash ring (alive backends only), the backend table
//! (addresses + health), and the directory (network → spec + owner) — and
//! a `control` mutex that serializes every *transition* (join, leave,
//! death, revival, load) so a hand-off can never interleave with another:
//! all the network I/O a transition performs happens under `control` but
//! never under the state lock, so sessions keep routing while a
//! rebalance is in flight.
//!
//! Failure handling is two-track. A background prober `PING`s every
//! backend (exponential backoff once dead); a session that trips over a
//! dead connection reports it, the report is *verified* with one probe
//! (transient hiccups must not evict a healthy backend), and a confirmed
//! death triggers synchronous failover — by the time the session's error
//! reply reaches the client, the network usually has a new owner and a
//! plain `USE` resumes service.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::cluster::backend::BackendConn;
use crate::cluster::ring::Ring;
use crate::cluster::ClusterConfig;
use crate::coordinator::metrics::LatencySummary;
use crate::fleet::SessionReply;
use crate::{Error, Result};

/// Health + ownership snapshot for one backend (diagnostics, `TOPO`).
#[derive(Clone, Debug)]
pub struct BackendStatus {
    /// Stable id (`b0`, `b1`, … in join order).
    pub id: String,
    /// Line-protocol address.
    pub addr: SocketAddr,
    /// False once the prober (or a verified session report) declared it dead.
    pub alive: bool,
    /// Networks the directory currently assigns to it.
    pub owned_nets: usize,
}

/// Outcome of resolving a network name to its owning backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lookup {
    /// Owned by a live backend.
    Owned {
        /// Owning backend id.
        id: String,
        /// Its address.
        addr: SocketAddr,
    },
    /// Known network, but no live backend currently hosts it.
    Orphaned,
    /// Never loaded through this cluster.
    Unknown,
}

/// Is a session's pinned (network, backend) pair still the owner?
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Confirm {
    /// Yes — forward.
    Current,
    /// Ownership moved (rebalance or failover) or the net is orphaned.
    Moved,
    /// The network left the directory entirely.
    Unloaded,
}

struct BackendEntry {
    addr: SocketAddr,
    alive: bool,
    consecutive_failures: u32,
    backoff: Duration,
    next_probe: Instant,
}

struct NetEntry {
    spec: String,
    owner: Option<String>,
}

struct State {
    ring: Ring,
    backends: BTreeMap<String, BackendEntry>,
    directory: BTreeMap<String, NetEntry>,
    next_backend_seq: usize,
}

/// The cluster front tier. See the module docs for the locking story.
pub struct Cluster {
    cfg: ClusterConfig,
    state: Mutex<State>,
    /// Serializes control-plane transitions (join/leave/death/load).
    control: Mutex<()>,
    stop: Arc<AtomicBool>,
    prober: Mutex<Option<std::thread::JoinHandle<()>>>,
    started: Instant,
}

enum ProbeAction {
    None,
    Died,
    Revived,
}

impl Cluster {
    /// Create the front tier and start its health prober.
    pub fn start(cfg: ClusterConfig) -> Result<Arc<Cluster>> {
        let cluster = Arc::new(Cluster {
            state: Mutex::new(State {
                ring: Ring::new(cfg.replicas),
                backends: BTreeMap::new(),
                directory: BTreeMap::new(),
                next_backend_seq: 0,
            }),
            control: Mutex::new(()),
            stop: Arc::new(AtomicBool::new(false)),
            prober: Mutex::new(None),
            started: Instant::now(),
            cfg,
        });
        let weak: Weak<Cluster> = Arc::downgrade(&cluster);
        let stop = Arc::clone(&cluster.stop);
        let step = cluster.cfg.probe_interval.min(Duration::from_millis(50)).max(Duration::from_millis(5));
        let handle = std::thread::Builder::new().name("cluster-probe".into()).spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(step);
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                // Weak: the prober never keeps the cluster alive, so a
                // dropped Cluster ends the thread on its next wake
                let Some(cluster) = weak.upgrade() else { break };
                cluster.probe_tick();
            }
        })?;
        *cluster.prober.lock().unwrap() = Some(handle);
        Ok(cluster)
    }

    /// The configuration in use.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Stop the prober (idempotent; also run on drop).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let handle = self.prober.lock().unwrap().take();
        if let Some(handle) = handle {
            // drop can run *on the prober*: mid-tick it holds the last Arc
            // upgrade, and joining yourself deadlocks — the stop flag is
            // set, so just let the thread run off its loop end
            if handle.thread().id() != std::thread::current().id() {
                let _ = handle.join();
            }
        }
    }

    // ---- membership -----------------------------------------------------

    /// Add a backend: verify it answers `PING`, put it on the ring, and
    /// rebalance — networks whose ring owner becomes the joiner are
    /// `LOAD`ed there and `EVICT`ed from their previous owner. Returns the
    /// assigned id (`b0`, `b1`, … in join order). An address that
    /// previously died rejoins under its old id.
    pub fn join(&self, addr: SocketAddr) -> Result<String> {
        let _ctl = self.control.lock().unwrap();
        if !self.ping_addr(addr) {
            return Err(Error::msg(format!("backend at {addr} did not answer PING")));
        }
        let id = {
            let mut st = self.state.lock().unwrap();
            let existing = st.backends.iter().find(|(_, b)| b.addr == addr).map(|(id, b)| (id.clone(), b.alive));
            match existing {
                Some((id, true)) => return Err(Error::msg(format!("backend {id} at {addr} already joined"))),
                Some((id, false)) => {
                    Self::set_alive(&mut st, &id);
                    id
                }
                None => {
                    let id = format!("b{}", st.next_backend_seq);
                    st.next_backend_seq += 1;
                    let entry = BackendEntry {
                        addr,
                        alive: true,
                        consecutive_failures: 0,
                        backoff: self.cfg.probe_interval,
                        next_probe: Instant::now() + self.cfg.probe_interval,
                    };
                    st.backends.insert(id.clone(), entry);
                    st.ring.add(&id);
                    id
                }
            }
        };
        self.rebalance(true);
        Ok(id)
    }

    /// Gracefully remove a backend: take it off the ring, hand its
    /// networks to the new ring owners (`LOAD` there, `EVICT` here), then
    /// forget it. If any hand-off `LOAD` fails the backend is kept —
    /// alive but off-ring, still serving what it owns — and an error says
    /// so; retrying `leave` retries the hand-off.
    pub fn leave(&self, id: &str) -> Result<()> {
        let _ctl = self.control.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            if !st.backends.contains_key(id) {
                return Err(Error::msg(format!("no such backend {id:?}")));
            }
            // off the ring but still addressable, so the hand-off can
            // EVICT its residents before the entry disappears
            st.ring.remove(id);
        }
        self.rebalance(true);
        let remaining = {
            let st = self.state.lock().unwrap();
            st.directory.values().filter(|e| e.owner.as_deref() == Some(id)).count()
        };
        if remaining > 0 {
            return Err(Error::msg(format!(
                "backend {id} still owns {remaining} network(s) whose hand-off failed; kept off-ring, retry leave"
            )));
        }
        self.state.lock().unwrap().backends.remove(id);
        Ok(())
    }

    /// Declare a backend dead *now*: off the ring, failover its networks
    /// to survivors (no `EVICT` — nobody is listening), keep probing it
    /// with backoff so a revival rejoins automatically. Normally driven by
    /// the prober or a verified session report, public for operators.
    pub fn mark_dead(&self, id: &str) {
        let _ctl = self.control.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            let Some(b) = st.backends.get_mut(id) else { return };
            if !b.alive {
                return;
            }
            b.alive = false;
            b.consecutive_failures = 0;
            b.backoff = self.cfg.probe_interval;
            b.next_probe = Instant::now() + b.backoff;
            st.ring.remove(id);
        }
        self.rebalance(false);
    }

    fn set_alive(st: &mut State, id: &str) {
        if let Some(b) = st.backends.get_mut(id) {
            b.alive = true;
            b.consecutive_failures = 0;
            b.next_probe = Instant::now();
        }
        st.ring.add(id);
    }

    fn revive(&self, id: &str) {
        let _ctl = self.control.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            let Some(b) = st.backends.get(id) else { return };
            if b.alive {
                return;
            }
            Self::set_alive(&mut st, id);
        }
        // a revived process may hold residents it no longer owns; that is
        // only wasted backend memory — routing follows the directory
        self.rebalance(true);
    }

    /// A session hit a connection error on `id`. Verify with one probe —
    /// a transient hiccup must not evict a healthy backend — and only a
    /// confirmed failure triggers death + failover (synchronously, so the
    /// caller's error reply already reflects the reroute).
    pub fn report_failure(&self, id: &str) {
        let addr = {
            let st = self.state.lock().unwrap();
            st.backends.get(id).filter(|b| b.alive).map(|b| b.addr)
        };
        let Some(addr) = addr else { return };
        if self.ping_addr(addr) {
            return;
        }
        self.mark_dead(id);
    }

    // ---- ownership ------------------------------------------------------

    /// Load `spec` onto its ring owner and record it in the directory.
    /// Returns the full protocol reply line (`OK loaded … backend=<id>`
    /// or `ERR …`) — the session passes it straight through.
    pub fn load(&self, spec: &str) -> String {
        // resolve the *name* locally first: routing needs the network's
        // name (a path spec and its net name must land on the same
        // owner), and a bad spec should fail here, not on a backend. A
        // `learn:` spec carries its name in the spec itself, so the
        // (expensive, backend-side) learning never runs on the front.
        let name = if crate::learn::is_learn_spec(spec) {
            match crate::learn::LearnSpec::parse(spec) {
                Ok(parsed) => parsed.name,
                Err(e) => return format!("ERR {e}"),
            }
        } else {
            match crate::bn::resolve_spec(spec) {
                Ok(net) => net.name,
                Err(e) => return format!("ERR {e}"),
            }
        };
        self.register_on_owner(&name, spec, &format!("LOAD {spec}"), "LOAD")
    }

    /// `LEARN` passthrough: route the verb to the ring owner of `name`
    /// (which runs the sample→learn pipeline and registers the result)
    /// and record the equivalent deterministic `learn:` spec in the
    /// directory — a later hand-off re-`LOAD`s that spec on the new
    /// owner, re-learning the **bit-identical** network there.
    pub fn learn(&self, name: &str, learn_spec: &str, line: &str) -> String {
        self.register_on_owner(name, learn_spec, line, "LEARN")
    }

    /// Shared LOAD/LEARN routing: send `line` to `name`'s ring owner,
    /// record `spec` in the directory on success, evict a stale previous
    /// owner, and annotate the reply with `backend=<id>`.
    ///
    /// Ordinary specs run under the `control` mutex like every transition
    /// (the RPC is one tree compile, bounded by `io_timeout`). A
    /// **learn** spec's RPC runs the whole sampling + PC + MLE pipeline
    /// on the backend under `learn_timeout` — minutes, not seconds — so
    /// it executes *outside* `control` and only the directory commit
    /// re-takes the lock: a slow learn must not stall failover, probing,
    /// and every other session's LOAD behind the control mutex. The
    /// commit records the backend that actually ran the learn if it is
    /// still alive (ring drift is fine — sessions follow the directory,
    /// and the next rebalance re-homes the net); an executor that *died*
    /// between finishing and the commit is re-homed immediately instead
    /// of being recorded as a dead owner nobody would ever re-route.
    fn register_on_owner(&self, name: &str, spec: &str, line: &str, verb: &str) -> String {
        let ctl = if crate::learn::is_learn_spec(spec) { None } else { Some(self.control.lock().unwrap()) };
        let Some((id, addr)) = self.place(name) else {
            return format!("ERR no live backends to host {name:?}");
        };
        match self.remote_line_bounded(addr, line, self.control_timeout(spec)) {
            Ok(reply) if reply.starts_with("OK") => {
                let _ctl = ctl.unwrap_or_else(|| self.control.lock().unwrap());
                // only reachable on the lockless learn path: the executor
                // may have been declared dead while it was learning
                let executor_alive = {
                    let st = self.state.lock().unwrap();
                    st.backends.get(&id).map(|b| b.alive).unwrap_or(false)
                };
                let owner = executor_alive.then(|| id.clone());
                let prev = {
                    let mut st = self.state.lock().unwrap();
                    st.directory
                        .insert(name.to_string(), NetEntry { spec: spec.to_string(), owner })
                        .and_then(|e| e.owner)
                };
                if executor_alive {
                    // a re-LOAD that lands on a new owner (ring changed
                    // while the net was orphaned, say) evicts the stale
                    // resident
                    self.evict_stale(name, prev.as_deref(), &id);
                    return format!("{reply} backend={id}");
                }
                // control is held, so re-home right now — a learn spec
                // re-learns deterministically on the new owner
                self.rebalance(false);
                match self.owner(name) {
                    Some(new_owner) => format!("{reply} backend={new_owner}"),
                    None => format!("ERR backend {id} was lost after {verb}; {name:?} has no live backend to re-home onto"),
                }
            }
            Ok(reply) => reply,
            Err(e) => {
                drop(ctl); // report_failure takes `control` via mark_dead
                self.report_failure(&id);
                format!("ERR backend {id} unreachable during {verb}: {e}")
            }
        }
    }

    /// Resolve a network to its owning backend.
    pub fn lookup(&self, net: &str) -> Lookup {
        let st = self.state.lock().unwrap();
        let Some(entry) = st.directory.get(net) else { return Lookup::Unknown };
        let owned = entry.owner.as_ref().and_then(|id| {
            st.backends.get(id).filter(|b| b.alive).map(|b| (id.clone(), b.addr))
        });
        match owned {
            Some((id, addr)) => Lookup::Owned { id, addr },
            None => Lookup::Orphaned,
        }
    }

    /// Directory owner of `net` (`None` if unknown or orphaned).
    pub fn owner(&self, net: &str) -> Option<String> {
        self.state.lock().unwrap().directory.get(net).and_then(|e| e.owner.clone())
    }

    /// The spec `net` was loaded from.
    pub fn spec_of(&self, net: &str) -> Option<String> {
        self.state.lock().unwrap().directory.get(net).map(|e| e.spec.clone())
    }

    /// Is (net, backend) still the live routing assignment?
    pub fn confirm(&self, net: &str, backend: &str) -> Confirm {
        let st = self.state.lock().unwrap();
        match st.directory.get(net) {
            None => Confirm::Unloaded,
            Some(e) if e.owner.as_deref() == Some(backend) => Confirm::Current,
            Some(_) => Confirm::Moved,
        }
    }

    /// Per-backend status, sorted by id.
    pub fn backends(&self) -> Vec<BackendStatus> {
        let st = self.state.lock().unwrap();
        st.backends
            .iter()
            .map(|(id, b)| BackendStatus {
                id: id.clone(),
                addr: b.addr,
                alive: b.alive,
                owned_nets: st.directory.values().filter(|e| e.owner.as_deref() == Some(id.as_str())).count(),
            })
            .collect()
    }

    /// Directory view: network → owning backend id, sorted by name.
    pub fn directory(&self) -> Vec<(String, Option<String>)> {
        let st = self.state.lock().unwrap();
        st.directory.iter().map(|(n, e)| (n.clone(), e.owner.clone())).collect()
    }

    fn alive_counts(&self) -> (usize, usize, usize) {
        let st = self.state.lock().unwrap();
        (st.backends.len(), st.backends.values().filter(|b| b.alive).count(), st.directory.len())
    }

    /// Ring owner of `name` among live backends, with its address.
    fn place(&self, name: &str) -> Option<(String, SocketAddr)> {
        let st = self.state.lock().unwrap();
        let id = st.ring.owner(name)?;
        let addr = st.backends.get(&id).map(|b| b.addr)?;
        Some((id, addr))
    }

    fn addr_if_alive(&self, id: &str) -> Option<SocketAddr> {
        let st = self.state.lock().unwrap();
        st.backends.get(id).filter(|b| b.alive).map(|b| b.addr)
    }

    /// Post-hand-off cleanup: `EVICT` `name` from a previous owner that
    /// is not the new one and is still alive (a dead one has nothing to
    /// free; a revival's stale residents are routed around anyway).
    fn evict_stale(&self, name: &str, prev: Option<&str>, new_owner: &str) {
        let Some(prev_id) = prev.filter(|p| *p != new_owner) else { return };
        if let Some(addr) = self.addr_if_alive(prev_id) {
            let _ = self.remote_line(addr, &format!("EVICT {name}"));
        }
    }

    /// Re-home every network whose directory owner disagrees with the
    /// ring: `LOAD` on the desired owner, then (when `evict_old` — join
    /// and graceful leave, where the previous owner is still listening)
    /// `EVICT` on the previous one. Orphans re-home too. A failed
    /// hand-off `LOAD` keeps a still-alive previous owner routing (it
    /// still holds the tree) rather than orphaning a working network;
    /// the next rebalance retries the move. Caller holds `control`;
    /// state is locked only around reads/commits, never I/O.
    fn rebalance(&self, evict_old: bool) {
        let nets: Vec<(String, String, Option<String>)> = {
            let st = self.state.lock().unwrap();
            st.directory.iter().map(|(n, e)| (n.clone(), e.spec.clone(), e.owner.clone())).collect()
        };
        for (name, spec, prev) in nets {
            let Some((id, addr)) = self.place(&name) else {
                let mut st = self.state.lock().unwrap();
                if let Some(e) = st.directory.get_mut(&name) {
                    e.owner = None;
                }
                continue;
            };
            if prev.as_deref() == Some(id.as_str()) {
                continue;
            }
            // hand-off re-learning of a learn: spec gets the learn budget
            let timeout = self.control_timeout(&spec);
            let reply = self.remote_line_bounded(addr, &format!("LOAD {spec}"), timeout);
            let mut ok = matches!(&reply, Ok(r) if r.starts_with("OK"));
            if !ok && crate::learn::is_learn_spec(&spec) {
                if let Ok(r) = &reply {
                    if r.contains("already resident") {
                        // the target holds a stale resident of different
                        // provenance under this name (a revival that kept
                        // residents it no longer owns): evict it there and
                        // retry once — the directory's spec is the truth
                        let _ = self.remote_line(addr, &format!("EVICT {name}"));
                        let retry = self.remote_line_bounded(addr, &format!("LOAD {spec}"), timeout);
                        ok = matches!(retry, Ok(r) if r.starts_with("OK"));
                    }
                }
            }
            {
                let mut st = self.state.lock().unwrap();
                let prev_alive =
                    prev.as_ref().map(|p| st.backends.get(p).map(|b| b.alive).unwrap_or(false)).unwrap_or(false);
                if let Some(e) = st.directory.get_mut(&name) {
                    e.owner = if ok {
                        Some(id.clone())
                    } else if prev_alive {
                        prev.clone()
                    } else {
                        None
                    };
                }
            }
            if ok && evict_old {
                self.evict_stale(&name, prev.as_deref(), &id);
            }
        }
    }

    // ---- probing --------------------------------------------------------

    fn probe_tick(&self) {
        let now = Instant::now();
        let due: Vec<(String, SocketAddr)> = {
            let st = self.state.lock().unwrap();
            st.backends.iter().filter(|(_, b)| now >= b.next_probe).map(|(id, b)| (id.clone(), b.addr)).collect()
        };
        for (id, addr) in due {
            let ok = self.ping_addr(addr);
            self.apply_probe(&id, ok);
        }
    }

    fn apply_probe(&self, id: &str, ok: bool) {
        let action = {
            let mut st = self.state.lock().unwrap();
            let Some(b) = st.backends.get_mut(id) else { return };
            let now = Instant::now();
            if b.alive {
                if ok {
                    b.consecutive_failures = 0;
                    b.next_probe = now + self.cfg.probe_interval;
                    ProbeAction::None
                } else {
                    b.consecutive_failures += 1;
                    if b.consecutive_failures >= self.cfg.fail_threshold {
                        ProbeAction::Died
                    } else {
                        b.next_probe = now; // recheck on the next tick
                        ProbeAction::None
                    }
                }
            } else if ok {
                ProbeAction::Revived
            } else {
                b.backoff = (b.backoff * 2).min(self.cfg.probe_backoff_max);
                b.next_probe = now + b.backoff;
                ProbeAction::None
            }
        };
        match action {
            ProbeAction::Died => self.mark_dead(id),
            ProbeAction::Revived => self.revive(id),
            ProbeAction::None => {}
        }
    }

    fn ping_addr(&self, addr: SocketAddr) -> bool {
        let connect = self.cfg.connect_timeout.min(self.cfg.probe_timeout);
        match BackendConn::connect(addr, connect, self.cfg.probe_timeout) {
            Ok(mut conn) => matches!(conn.request("PING"), Ok(r) if r.starts_with("OK")),
            Err(_) => false,
        }
    }

    // ---- protocol surfaces ---------------------------------------------

    /// Open a data-plane connection to a backend.
    pub fn connect(&self, addr: SocketAddr) -> std::io::Result<BackendConn> {
        BackendConn::connect(addr, self.cfg.connect_timeout, self.cfg.io_timeout)
    }

    fn remote_line(&self, addr: SocketAddr, line: &str) -> std::io::Result<String> {
        self.connect(addr)?.request(line)
    }

    /// One counted-block request/reply (the `METRICS` shape) on a
    /// short-lived control connection.
    fn remote_block(&self, addr: SocketAddr, line: &str) -> std::io::Result<(String, Vec<String>)> {
        self.connect(addr)?.request_block(line)
    }

    /// `remote_line` with an explicit read bound (learn-spec control
    /// lines outlive the ordinary `io_timeout` by design).
    fn remote_line_bounded(&self, addr: SocketAddr, line: &str, read_timeout: Duration) -> std::io::Result<String> {
        BackendConn::connect(addr, self.cfg.connect_timeout, read_timeout)?.request(line)
    }

    /// Read bound for a control-plane line that registers `spec`: a
    /// `learn:` spec runs the whole sampling + PC + MLE pipeline on the
    /// backend, so it gets `learn_timeout` instead of `io_timeout`.
    fn control_timeout(&self, spec: &str) -> Duration {
        if crate::learn::is_learn_spec(spec) {
            self.cfg.io_timeout.max(self.cfg.learn_timeout)
        } else {
            self.cfg.io_timeout
        }
    }

    /// `PING` reply: front-tier liveness + topology counts.
    pub fn ping_line(&self) -> String {
        let (backends, alive, nets) = self.alive_counts();
        format!("OK pong backends={backends} alive={alive} nets={nets}")
    }

    /// `TOPO` reply: per-backend address, health, and ownership.
    pub fn topo_line(&self) -> String {
        let statuses = self.backends();
        let mut out = format!("OK backends={}", statuses.len());
        for s in &statuses {
            out.push_str(&format!(" {}[addr={} alive={} nets={}]", s.id, s.addr, s.alive, s.owned_nets));
        }
        out
    }

    /// Cluster-wide `NETS`: every alive backend's residents, filtered to
    /// directory-owned networks and annotated `@backend`.
    pub fn nets_line(&self) -> String {
        let owners: BTreeMap<String, String> = {
            let st = self.state.lock().unwrap();
            st.directory.iter().filter_map(|(n, e)| e.owner.clone().map(|o| (n.clone(), o))).collect()
        };
        let targets: Vec<(String, SocketAddr)> = {
            let st = self.state.lock().unwrap();
            st.backends.iter().filter(|(_, b)| b.alive).map(|(id, b)| (id.clone(), b.addr)).collect()
        };
        let mut blocks: BTreeMap<String, String> = BTreeMap::new();
        for (id, addr) in &targets {
            let Ok(reply) = self.remote_line(*addr, "NETS") else { continue };
            for raw in reply.split(']') {
                let Some((head, attrs)) = raw.split_once('[') else { continue };
                let Some(name) = head.split_whitespace().last() else { continue };
                if owners.get(name) == Some(id) {
                    blocks.insert(name.to_string(), format!("{name}[{attrs}]@{id}"));
                }
            }
        }
        let mut out = format!("OK nets={}", blocks.len());
        for block in blocks.values() {
            out.push(' ');
            out.push_str(block);
        }
        out
    }

    /// Cluster-wide `STATS`: per-network lines gathered from the owning
    /// backends plus aggregate totals. Headline percentiles prefer the
    /// bucket-wise merge of every backend's latency histograms (scraped
    /// via `METRICS` — exact up to bucket resolution, since log2 bucket
    /// counts add losslessly across backends); only when no backend
    /// exposes histograms do they fall back to the count-weighted
    /// [`LatencySummary::merge`], which is biased under skewed
    /// per-backend distributions.
    pub fn stats_line(&self) -> String {
        let targets: Vec<(String, SocketAddr)> = {
            let st = self.state.lock().unwrap();
            st.backends.iter().filter(|(_, b)| b.alive).map(|(id, b)| (id.clone(), b.addr)).collect()
        };
        let owners: BTreeMap<String, Option<String>> = self.directory().into_iter().collect();
        // net name → (backend id, parsed per-net segment)
        let mut per_net: BTreeMap<String, (String, NetStat)> = BTreeMap::new();
        let mut scrapes: Vec<crate::obs::scrape::Scrape> = Vec::new();
        for (id, addr) in &targets {
            let Ok(reply) = self.remote_line(*addr, "STATS") else { continue };
            for stat in parse_backend_stats(&reply) {
                if owners.get(&stat.net).map(|o| o.as_deref() == Some(id.as_str())).unwrap_or(false) {
                    per_net.insert(stat.net.clone(), (id.clone(), stat));
                }
            }
            if let Ok((header, body)) = self.remote_block(*addr, "METRICS") {
                if header.starts_with("OK metrics") {
                    scrapes.push(crate::obs::scrape::Scrape::parse(&body.join("\n")));
                }
            }
        }
        let (backends, alive, nets) = self.alive_counts();
        let scrape_refs: Vec<&crate::obs::scrape::Scrape> = scrapes.iter().collect();
        let (p50_us, p99_us) = match crate::obs::scrape::merged_percentiles(
            &scrape_refs,
            "fastbn_query_latency_us",
            &[0.5, 0.99],
        ) {
            Some(ps) => (ps[0], ps[1]),
            None => {
                let parts: Vec<LatencySummary> = per_net.values().map(|(_, s)| s.as_summary()).collect();
                let merged = LatencySummary::merge(&parts);
                (merged.p50.as_micros() as u64, merged.p99.as_micros() as u64)
            }
        };
        let queries: u64 = per_net.values().map(|(_, s)| s.queries).sum();
        let errors: u64 = per_net.values().map(|(_, s)| s.errors).sum();
        let mut out = format!(
            "STATS cluster uptime_ms={} backends={backends} alive={alive} nets={nets} queries={queries} errors={errors} p50_us={p50_us} p99_us={p99_us}",
            self.started.elapsed().as_millis(),
        );
        for (net, (id, s)) in &per_net {
            out.push_str(&format!(
                " | {net} backend={id} queries={} errors={} qps={:.2} p50_us={} p99_us={}",
                s.queries, s.errors, s.qps, s.p50_us, s.p99_us
            ));
        }
        for (net, owner) in &owners {
            if owner.is_none() {
                out.push_str(&format!(" | {net} backend=none orphaned=true"));
            }
        }
        out
    }

    /// Cluster-wide `METRICS`: scrape every alive backend's exposition
    /// and merge — counters and histogram buckets summed into aggregate
    /// series, plus every backend's series re-labeled `backend="id"` so
    /// outliers stay attributable. Same counted-block reply shape as the
    /// backend verb: `OK metrics backends=<scraped> lines=<n>` then n
    /// lines. Backends that fail to answer are simply absent from the
    /// scrape (and from `backends=`).
    pub fn metrics_line(&self) -> String {
        let targets: Vec<(String, SocketAddr)> = {
            let st = self.state.lock().unwrap();
            st.backends.iter().filter(|(_, b)| b.alive).map(|(id, b)| (id.clone(), b.addr)).collect()
        };
        let mut parts: Vec<(String, String)> = Vec::new();
        for (id, addr) in &targets {
            let Ok((header, body)) = self.remote_block(*addr, "METRICS") else { continue };
            if header.starts_with("OK metrics") {
                parts.push((id.clone(), body.join("\n")));
            }
        }
        let merged = crate::obs::scrape::merge_exposition(&parts);
        if merged.is_empty() {
            return format!("OK metrics backends={} lines=0", parts.len());
        }
        format!("OK metrics backends={} lines={}\n{merged}", parts.len(), merged.lines().count())
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One per-network segment parsed from a backend `STATS` line.
struct NetStat {
    net: String,
    queries: u64,
    errors: u64,
    qps: f64,
    p50_us: u64,
    p99_us: u64,
}

impl NetStat {
    /// Synthetic summary for cross-backend merging. Only count/p50/p99
    /// survive the wire, so the other fields are filled from those —
    /// good enough for a cluster-total headline, documented approximate.
    fn as_summary(&self) -> LatencySummary {
        let (p50, p99) = (Duration::from_micros(self.p50_us), Duration::from_micros(self.p99_us));
        LatencySummary {
            count: self.queries as usize,
            total: p50 * (self.queries.min(u64::from(u32::MAX)) as u32),
            mean: p50,
            min: p50,
            max: p99,
            p50,
            p95: p99,
            p99,
        }
    }
}

/// Parse a fleet `STATS` reply (`STATS uptime_ms=… nets=N | <net>
/// queries=… errors=… qps=… p50_us=… p99_us=… | …`) into per-net stats.
/// Unknown fields are ignored so the formats can evolve independently.
fn parse_backend_stats(reply: &str) -> Vec<NetStat> {
    let mut out = Vec::new();
    for segment in reply.split(" | ").skip(1) {
        let mut tokens = segment.split_whitespace();
        let Some(net) = tokens.next() else { continue };
        let mut stat = NetStat { net: net.to_string(), queries: 0, errors: 0, qps: 0.0, p50_us: 0, p99_us: 0 };
        for token in tokens {
            let Some((key, value)) = token.split_once('=') else { continue };
            match key {
                "queries" => stat.queries = value.parse().unwrap_or(0),
                "errors" => stat.errors = value.parse().unwrap_or(0),
                "qps" => stat.qps = value.parse().unwrap_or(0.0),
                "p50_us" => stat.p50_us = value.parse().unwrap_or(0),
                "p99_us" => stat.p99_us = value.parse().unwrap_or(0),
                _ => {}
            }
        }
        out.push(stat);
    }
    out
}

// ---- the per-connection proxy session ----------------------------------

struct Active {
    net: String,
    backend: String,
    conn: BackendConn,
}

/// One client's front-tier session: routes control verbs to the cluster
/// and pins data-plane verbs to the owning backend's connection (where
/// the backend-side session holds the streamed-evidence state).
///
/// `BATCH` passthrough: the front mirrors the backend's batch counting —
/// it remembers `n` from a successful `BATCH <n> <target>` forward, lets
/// the first `n-1` `CASE` lines round-trip one-for-one, and reads **n**
/// reply lines for the final `CASE` (the backend answers the whole batch
/// at once). Verbs the front answers locally (NETS/STATS/PING/TOPO/LOAD)
/// never reach the pinned conn, so they leave both sides' batch state
/// untouched; any *forwarded* non-CASE verb aborts the batch on both
/// sides at once (the backend on seeing the verb, the front here).
pub struct ClusterSession {
    cluster: Arc<Cluster>,
    active: Option<Active>,
    /// (cases remaining, total) of an in-progress forwarded batch.
    batch: Option<(usize, usize)>,
}

impl ClusterSession {
    /// New session; nothing selected.
    pub fn new(cluster: Arc<Cluster>) -> Self {
        ClusterSession { cluster, active: None, batch: None }
    }

    /// Network the session is pinned to, if any.
    pub fn current_net(&self) -> Option<&str> {
        self.active.as_ref().map(|a| a.net.as_str())
    }

    /// Handle one protocol line, producing one reply.
    pub fn handle(&mut self, line: &str) -> SessionReply {
        let line = line.trim();
        if line.is_empty() {
            return SessionReply::Line("ERR empty request".into());
        }
        let mut parts = line.splitn(2, ' ');
        let verb = parts.next().unwrap_or("");
        let rest = parts.next().unwrap_or("").trim();
        let verb = verb.to_ascii_uppercase();
        let reply = match verb.as_str() {
            "QUIT" => return SessionReply::Quit,
            "LOAD" => {
                if rest.is_empty() {
                    "ERR usage: LOAD <net>".into()
                } else {
                    self.cluster.load(rest)
                }
            }
            "LEARN" => self.cmd_learn(rest),
            "USE" => self.cmd_use(rest),
            "NETS" => self.cluster.nets_line(),
            "STATS" => self.cluster.stats_line(),
            "METRICS" => self.cluster.metrics_line(),
            "PING" => self.cluster.ping_line(),
            "TOPO" => self.cluster.topo_line(),
            // a forwarded data verb reaches the pinned backend session (or
            // tears the pin down), and either way its batch collection is
            // over — mirror that here. Verbs the front answers locally
            // (LOAD/NETS/STATS/METRICS/PING/TOPO, unknown) never touch the
            // conn and must leave the mirrored count alone. TRACE forwards:
            // the ring lives where the engines run, on the backend.
            "OBSERVE" | "RETRACT" | "COMMIT" | "QUERY" | "TRACE" => {
                self.batch = None;
                self.forward(line)
            }
            "BATCH" => self.cmd_batch(line, rest),
            "CASE" => self.cmd_case(line),
            other => format!("ERR unknown verb {other:?}"),
        };
        SessionReply::Line(reply)
    }

    /// Forward `BATCH <n> <target>`; on an `OK` reply start mirroring the
    /// backend's case countdown so the final `CASE` reads n lines.
    fn cmd_batch(&mut self, line: &str, rest: &str) -> String {
        // whatever happens next, the previous collection is over on both
        // sides: the backend aborts it on seeing the BATCH verb, and a
        // failed forward tears the pin (and its session) down
        self.batch = None;
        let n: Option<usize> = rest.split_whitespace().next().and_then(|t| t.parse().ok());
        let reply = self.forward(line);
        if reply.starts_with("OK") {
            // the backend accepted, so the count parsed there too
            if let Some(n) = n {
                self.batch = Some((n, n));
            }
        }
        reply
    }

    /// Forward one `CASE` line. Mid-batch cases round-trip one-for-one;
    /// the final one comes back as the batch's n result lines.
    fn cmd_case(&mut self, line: &str) -> String {
        match self.batch {
            None => self.forward(line), // backend answers "no batch in progress"
            Some((remaining, total)) if remaining > 1 => {
                let reply = self.forward(line);
                // the backend acks every staged case; an ERR mid-batch
                // means it aborted its collection (tree evicted, conn
                // rerouted) — mirror that. A transport error also drops
                // the pin, and the batch with it.
                if self.active.is_some() && !reply.starts_with("ERR") {
                    self.batch = Some((remaining - 1, total));
                } else {
                    self.batch = None;
                }
                reply
            }
            Some((_, total)) => {
                self.batch = None;
                self.forward_multi(line, total)
            }
        }
    }

    /// `LEARN <name> <spec> <samples> <seed>`: validated on the front,
    /// executed on the ring owner of `<name>` via a control-plane
    /// connection (like `LOAD` — the session's pinned data conn, and any
    /// open batch on it, is untouched).
    fn cmd_learn(&mut self, rest: &str) -> String {
        // same grammar as the backend session (one definition, on
        // LearnSpec) — a malformed verb never costs a backend round trip
        let parsed = match crate::learn::LearnSpec::from_verb_args(rest) {
            Ok(parsed) => parsed,
            Err(e) => return format!("ERR {e}"),
        };
        let line = format!("LEARN {} {} {} {}", parsed.name, parsed.base, parsed.samples, parsed.seed);
        self.cluster.learn(&parsed.name, &parsed.to_spec(), &line)
    }

    fn cmd_use(&mut self, name: &str) -> String {
        if name.is_empty() {
            return "ERR usage: USE <net>".into();
        }
        let (id, addr) = match self.cluster.lookup(name) {
            Lookup::Owned { id, addr } => (id, addr),
            Lookup::Orphaned => return format!("ERR network {name:?} has no live backend; retry once rerouted"),
            Lookup::Unknown => return format!("ERR not loaded: {name:?} (LOAD it first)"),
        };
        // reuse the sticky conn only when staying on the same backend (its
        // session's USE applies the evidence-reset semantics); resuming a
        // *stale* session on another backend could leak old evidence
        let same_backend = self.active.as_ref().map(|a| a.backend == id).unwrap_or(false);
        if same_backend {
            // the pinned backend session sees the USE (or the conn dies);
            // either way its batch collection is over — mirror that
            self.batch = None;
            let mut active = self.active.take().expect("checked above");
            return match self.forward_use(&mut active.conn, name) {
                Ok(reply) => {
                    if reply.starts_with("OK") {
                        active.net = name.to_string();
                    }
                    // an ERR reply left the backend session untouched, so
                    // the existing pin (and its evidence) survives — the
                    // single-fleet failed-USE semantics
                    self.active = Some(active);
                    reply
                }
                Err(e) => {
                    // the conn died and the old pin's state died with it
                    self.cluster.report_failure(&id);
                    format!("ERR backend {id} unreachable: {e}; retry USE once rerouted")
                }
            };
        }
        // different backend: build the new pin first and replace the old
        // one only on success — a failed USE keeps the current selection
        // (and, with it, any open batch on the still-pinned conn: the old
        // backend session never saw this verb)
        let mut conn = match self.cluster.connect(addr) {
            Ok(conn) => conn,
            Err(e) => {
                self.cluster.report_failure(&id);
                return format!("ERR backend {id} ({addr}) unreachable: {e}; retry USE once rerouted");
            }
        };
        match self.forward_use(&mut conn, name) {
            Ok(reply) => {
                if reply.starts_with("OK") {
                    // replacing the pin drops the old conn, and the old
                    // backend session (incl. any open batch) dies with it
                    self.batch = None;
                    self.active = Some(Active { net: name.to_string(), backend: id, conn });
                }
                reply
            }
            Err(e) => {
                self.cluster.report_failure(&id);
                format!("ERR backend {id} unreachable: {e}; retry USE once rerouted")
            }
        }
    }

    /// Forward `USE`, self-healing directory/backend drift: a backend
    /// that answers "not loaded" for a network the directory assigns to
    /// it (say it restarted empty behind its old address) gets a `LOAD`
    /// of the recorded spec and one retry.
    fn forward_use(&self, conn: &mut BackendConn, name: &str) -> std::io::Result<String> {
        let reply = conn.request(&format!("USE {name}"))?;
        if reply.starts_with("ERR not loaded") {
            if let Some(spec) = self.cluster.spec_of(name) {
                let load = conn.request(&format!("LOAD {spec}"))?;
                if load.starts_with("OK") {
                    return conn.request(&format!("USE {name}"));
                }
                return Ok(load);
            }
        }
        Ok(reply)
    }

    /// Forward a data-plane verb over the pinned connection, after
    /// re-checking that the pin still matches the directory — a moved or
    /// unloaded network is a clean error, never a silent reroute that
    /// would drop (or misapply) the backend session's evidence.
    fn forward(&mut self, line: &str) -> String {
        self.forward_multi(line, 1)
    }

    /// Forward expecting `n` reply lines (the final `CASE` of an n-case
    /// batch; every other verb has `n == 1`). The lines come back joined —
    /// the line server writes them out as n wire lines.
    fn forward_multi(&mut self, line: &str, n: usize) -> String {
        let Some(active) = self.active.as_mut() else {
            return "ERR no network selected (USE <net> first)".into();
        };
        match self.cluster.confirm(&active.net, &active.backend) {
            Confirm::Current => {}
            Confirm::Moved => {
                let net = active.net.clone();
                // dropping the pin closes the conn; the backend session
                // (and any open batch) dies with it
                self.active = None;
                self.batch = None;
                return format!("ERR network {net:?} moved to another backend (rebalance or failover); USE it again");
            }
            Confirm::Unloaded => {
                let net = active.net.clone();
                self.active = None;
                self.batch = None;
                return format!("ERR network {net:?} is no longer loaded anywhere; LOAD and USE it again");
            }
        }
        match active.conn.request_lines(line, n) {
            Ok(lines) => lines.join("\n"),
            Err(e) => {
                let (net, id) = (active.net.clone(), active.backend.clone());
                self.active = None;
                self.batch = None;
                // verified report: failover runs before we reply, so the
                // client's very next USE normally lands on the new owner
                self.cluster.report_failure(&id);
                format!("ERR backend {id} for network {net:?} is unreachable ({e}); USE the network again once rerouted")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_cluster() -> Arc<Cluster> {
        Cluster::start(ClusterConfig {
            probe_interval: Duration::from_millis(50),
            probe_timeout: Duration::from_millis(200),
            connect_timeout: Duration::from_millis(200),
            io_timeout: Duration::from_secs(1),
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn empty_cluster_refuses_work_cleanly() {
        let cluster = empty_cluster();
        assert!(cluster.load("asia").starts_with("ERR no live backends"), "{}", cluster.load("asia"));
        assert!(cluster.load("no-such-net").starts_with("ERR unknown network"));
        assert_eq!(cluster.lookup("asia"), Lookup::Unknown);
        assert_eq!(cluster.owner("asia"), None);
        assert!(cluster.ping_line().contains("backends=0 alive=0 nets=0"));
        assert!(cluster.stats_line().starts_with("STATS cluster"), "{}", cluster.stats_line());
        assert_eq!(cluster.nets_line(), "OK nets=0");
        assert_eq!(cluster.topo_line(), "OK backends=0");
        cluster.shutdown();
    }

    #[test]
    fn join_requires_a_live_backend() {
        let cluster = empty_cluster();
        // bind-then-drop: the port is real but nothing listens on it
        let dead = {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            listener.local_addr().unwrap()
        };
        assert!(cluster.join(dead).is_err());
        assert!(cluster.backends().is_empty());
        cluster.shutdown();
    }

    #[test]
    fn session_errors_without_a_selection() {
        let cluster = empty_cluster();
        let mut session = ClusterSession::new(Arc::clone(&cluster));
        let line = |s: &mut ClusterSession, input: &str| match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        };
        assert!(line(&mut session, "QUERY lung").starts_with("ERR no network selected"));
        assert!(line(&mut session, "OBSERVE a=b").starts_with("ERR no network selected"));
        assert!(line(&mut session, "USE asia").starts_with("ERR not loaded"));
        assert!(line(&mut session, "USE").starts_with("ERR usage: USE"));
        assert!(line(&mut session, "LOAD").starts_with("ERR usage: LOAD"));
        assert!(line(&mut session, "FROB x").starts_with("ERR unknown verb"));
        assert!(line(&mut session, "").starts_with("ERR empty request"));
        assert!(line(&mut session, "PING").starts_with("OK pong"));
        assert_eq!(session.current_net(), None);
        assert_eq!(session.handle("quit"), SessionReply::Quit);
        cluster.shutdown();
    }

    #[test]
    fn learn_verb_validates_before_routing() {
        let cluster = empty_cluster();
        let mut session = ClusterSession::new(Arc::clone(&cluster));
        let line = |s: &mut ClusterSession, input: &str| match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        };
        assert!(line(&mut session, "LEARN").starts_with("ERR usage: LEARN"));
        assert!(line(&mut session, "LEARN x asia 10").starts_with("ERR usage: LEARN"));
        assert!(line(&mut session, "LEARN x asia ten 1").starts_with("ERR bad sample count"));
        assert!(line(&mut session, "LEARN x asia 0 1").starts_with("ERR learn spec sample count"));
        // well-formed but nowhere to run: refused at placement, and the
        // (expensive) learning never happened on the front tier
        assert!(line(&mut session, "LEARN x asia 100 1").starts_with("ERR no live backends"));
        // LOAD of a learn: spec also fails fast on parse errors
        assert!(cluster.load("learn:bad").starts_with("ERR learn spec"));
        cluster.shutdown();
    }

    #[test]
    fn backend_stats_lines_parse() {
        let parsed = parse_backend_stats(
            "STATS uptime_ms=12 nets=2 | asia queries=5 errors=1 qps=2.50 p50_us=120 p99_us=900 | cancer queries=0 errors=0 qps=0.00 p50_us=0 p99_us=0",
        );
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].net, "asia");
        assert_eq!(parsed[0].queries, 5);
        assert_eq!(parsed[0].errors, 1);
        assert_eq!(parsed[0].p99_us, 900);
        assert_eq!(parsed[1].net, "cancer");
        assert_eq!(parsed[1].queries, 0);
        assert!(parse_backend_stats("STATS uptime_ms=1 nets=0").is_empty());
    }
}
