//! The front-tier router: membership, ownership, failover, and proxying.
//!
//! One [`Cluster`] owns three pieces of state behind a short-hold lock —
//! the consistent-hash ring (alive backends only), the backend table
//! (addresses + health), and the directory (network → spec + replica
//! owners) — and a `control` mutex that serializes every *transition*
//! (join, leave, death, revival, load) so a hand-off can never interleave
//! with another: all the network I/O a transition performs happens under
//! `control` but never under the state lock, so sessions keep routing
//! while a rebalance is in flight.
//!
//! Each network is placed on the first R distinct ring members clockwise
//! from its hash ([`crate::cluster::ring::Ring::owners`],
//! `ClusterConfig::replicas`). Replicas are byte-identical by
//! construction — same spec, same deterministic compile (`learn:` specs
//! re-learn bit-identically) — so a *clean* session's read-only verbs
//! spread across them and fail over inside the set without an error
//! reply, while evidence-bearing sessions stay pinned to one replica
//! (see [`ClusterSession`]).
//!
//! Failure handling is two-track. A background prober `PING`s every
//! backend (exponential backoff once dead); a session that trips over a
//! dead connection reports it, the report is *verified* with one probe
//! (transient hiccups must not evict a healthy backend), and a confirmed
//! death triggers synchronous failover — by the time the session's error
//! reply reaches the client, the network usually has a surviving replica
//! promoted and a plain `USE` resumes service.

use std::collections::{BTreeMap, VecDeque};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::cluster::backend::BackendConn;
use crate::cluster::ring::Ring;
use crate::cluster::ClusterConfig;
use crate::fleet::SessionReply;
use crate::{Error, Result};

/// Health + ownership snapshot for one backend (diagnostics, `TOPO`).
#[derive(Clone, Debug)]
pub struct BackendStatus {
    /// Stable id (`b0`, `b1`, … in join order).
    pub id: String,
    /// Line-protocol address.
    pub addr: SocketAddr,
    /// False once the prober (or a verified session report) declared it dead.
    pub alive: bool,
    /// Networks the directory currently places a replica of on it.
    pub owned_nets: usize,
}

/// Outcome of resolving a network name to a live replica owner.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lookup {
    /// At least one live replica; the first (primary-most) is returned.
    Owned {
        /// Owning backend id.
        id: String,
        /// Its address.
        addr: SocketAddr,
    },
    /// Known network, but no live backend currently hosts it.
    Orphaned,
    /// Never loaded through this cluster.
    Unknown,
}

/// Is a session's pinned (network, backend) pair still a valid route?
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Confirm {
    /// Yes — the backend is still one of the net's replica owners.
    Current,
    /// Ownership moved off that backend (rebalance or failover) or the
    /// net is orphaned.
    Moved,
    /// The network left the directory entirely.
    Unloaded,
}

struct BackendEntry {
    addr: SocketAddr,
    alive: bool,
    consecutive_failures: u32,
    backoff: Duration,
    next_probe: Instant,
}

struct NetEntry {
    spec: String,
    /// Replica owners, primary first (the ring's successor walk at the
    /// last placement). Empty = orphaned.
    owners: Vec<String>,
}

struct State {
    ring: Ring,
    backends: BTreeMap<String, BackendEntry>,
    directory: BTreeMap<String, NetEntry>,
    next_backend_seq: usize,
}

/// One routed query made while tracing was armed: enough to steer
/// `TRACE <qid>` back to the backend whose ring holds the span tree, and
/// to prepend the front's routing cost to the assembled timeline.
struct RouteRecord {
    qid: String,
    net: String,
    backend: String,
    /// Front-observed wall time for the whole routed round trip.
    route_us: u64,
}

/// Bounded route history (oldest evicted) — sized to comfortably cover
/// the backend trace rings it indexes into.
const ROUTE_CAP: usize = 256;

/// The cluster front tier. See the module docs for the locking story.
pub struct Cluster {
    cfg: ClusterConfig,
    state: Mutex<State>,
    /// Serializes control-plane transitions (join/leave/death/load).
    control: Mutex<()>,
    stop: Arc<AtomicBool>,
    prober: Mutex<Option<std::thread::JoinHandle<()>>>,
    started: Instant,
    /// Cross-tier tracing armed (`TRACE on`): sessions mint a qid per
    /// query and tag the forwarded line with it.
    trace_armed: AtomicBool,
    /// Monotonic qid counter (`q1`, `q2`, …).
    qid_seq: AtomicU64,
    /// Recent tagged-query routes, newest last.
    routes: Mutex<VecDeque<RouteRecord>>,
}

enum ProbeAction {
    None,
    Died,
    Revived,
}

impl Cluster {
    /// Create the front tier and start its health prober.
    pub fn start(cfg: ClusterConfig) -> Result<Arc<Cluster>> {
        let cluster = Arc::new(Cluster {
            state: Mutex::new(State {
                ring: Ring::new(cfg.vnodes),
                backends: BTreeMap::new(),
                directory: BTreeMap::new(),
                next_backend_seq: 0,
            }),
            control: Mutex::new(()),
            stop: Arc::new(AtomicBool::new(false)),
            prober: Mutex::new(None),
            started: Instant::now(),
            trace_armed: AtomicBool::new(false),
            qid_seq: AtomicU64::new(0),
            routes: Mutex::new(VecDeque::new()),
            cfg,
        });
        let weak: Weak<Cluster> = Arc::downgrade(&cluster);
        let stop = Arc::clone(&cluster.stop);
        let step = cluster.cfg.probe_interval.min(Duration::from_millis(50)).max(Duration::from_millis(5));
        let handle = std::thread::Builder::new().name("cluster-probe".into()).spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(step);
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                // Weak: the prober never keeps the cluster alive, so a
                // dropped Cluster ends the thread on its next wake
                let Some(cluster) = weak.upgrade() else { break };
                cluster.probe_tick();
            }
        })?;
        *cluster.prober.lock().unwrap() = Some(handle);
        Ok(cluster)
    }

    /// The configuration in use.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Stop the prober (idempotent; also run on drop).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        let handle = self.prober.lock().unwrap().take();
        if let Some(handle) = handle {
            // drop can run *on the prober*: mid-tick it holds the last Arc
            // upgrade, and joining yourself deadlocks — the stop flag is
            // set, so just let the thread run off its loop end
            if handle.thread().id() != std::thread::current().id() {
                let _ = handle.join();
            }
        }
    }

    // ---- membership -----------------------------------------------------

    /// Add a backend: verify it answers `PING`, put it on the ring, and
    /// rebalance — networks whose desired replica set now includes the
    /// joiner are `LOAD`ed there and `EVICT`ed from owners that fell off
    /// the set. Returns the assigned id (`b0`, `b1`, … in join order). An
    /// address that previously died rejoins under its old id. The backend
    /// can be a child this process spawned or an already-running remote
    /// `fastbn serve --fleet` adopted over TCP (the `JOIN <addr>` verb /
    /// `--join-hosts` path) — the wire protocol is identical.
    pub fn join(&self, addr: SocketAddr) -> Result<String> {
        let _ctl = self.control.lock().unwrap();
        if !self.ping_addr(addr) {
            return Err(Error::msg(format!("backend at {addr} did not answer PING")));
        }
        let id = {
            let mut st = self.state.lock().unwrap();
            let existing = st.backends.iter().find(|(_, b)| b.addr == addr).map(|(id, b)| (id.clone(), b.alive));
            match existing {
                Some((id, true)) => return Err(Error::msg(format!("backend {id} at {addr} already joined"))),
                Some((id, false)) => {
                    Self::set_alive(&mut st, &id);
                    id
                }
                None => {
                    let id = format!("b{}", st.next_backend_seq);
                    st.next_backend_seq += 1;
                    let entry = BackendEntry {
                        addr,
                        alive: true,
                        consecutive_failures: 0,
                        backoff: self.cfg.probe_interval,
                        next_probe: Instant::now() + self.cfg.probe_interval,
                    };
                    st.backends.insert(id.clone(), entry);
                    st.ring.add(&id);
                    id
                }
            }
        };
        self.rebalance(true);
        Ok(id)
    }

    /// Gracefully remove a backend: take it off the ring, hand its
    /// networks to the new replica owners (`LOAD` there, `EVICT` here),
    /// then forget it. If any hand-off `LOAD` fails the backend is kept —
    /// alive but off-ring, still serving what it holds — and an error says
    /// so; retrying `leave` retries the hand-off.
    pub fn leave(&self, id: &str) -> Result<()> {
        let _ctl = self.control.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            if !st.backends.contains_key(id) {
                return Err(Error::msg(format!("no such backend {id:?}")));
            }
            // off the ring but still addressable, so the hand-off can
            // EVICT its residents before the entry disappears
            st.ring.remove(id);
        }
        self.rebalance(true);
        let remaining = {
            let st = self.state.lock().unwrap();
            st.directory.values().filter(|e| e.owners.iter().any(|o| o == id)).count()
        };
        if remaining > 0 {
            return Err(Error::msg(format!(
                "backend {id} still owns {remaining} network(s) whose hand-off failed; kept off-ring, retry leave"
            )));
        }
        self.state.lock().unwrap().backends.remove(id);
        Ok(())
    }

    /// Declare a backend dead *now*: off the ring, failover its networks
    /// to surviving replicas (no `EVICT` — nobody is listening), keep
    /// probing it with backoff so a revival rejoins automatically.
    /// Normally driven by the prober or a verified session report, public
    /// for operators.
    pub fn mark_dead(&self, id: &str) {
        let _ctl = self.control.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            let Some(b) = st.backends.get_mut(id) else { return };
            if !b.alive {
                return;
            }
            b.alive = false;
            b.consecutive_failures = 0;
            b.backoff = self.cfg.probe_interval;
            b.next_probe = Instant::now() + b.backoff;
            st.ring.remove(id);
        }
        self.rebalance(false);
    }

    fn set_alive(st: &mut State, id: &str) {
        if let Some(b) = st.backends.get_mut(id) {
            b.alive = true;
            b.consecutive_failures = 0;
            b.next_probe = Instant::now();
        }
        st.ring.add(id);
    }

    fn revive(&self, id: &str) {
        let _ctl = self.control.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            let Some(b) = st.backends.get(id) else { return };
            if b.alive {
                return;
            }
            Self::set_alive(&mut st, id);
        }
        // a revived process may hold residents it no longer owns; that is
        // only wasted backend memory — routing follows the directory
        self.rebalance(true);
    }

    /// A session hit a connection error on `id`. Verify with one probe —
    /// a transient hiccup must not evict a healthy backend — and only a
    /// confirmed failure triggers death + failover (synchronously, so the
    /// caller's error reply already reflects the reroute).
    pub fn report_failure(&self, id: &str) {
        let addr = {
            let st = self.state.lock().unwrap();
            st.backends.get(id).filter(|b| b.alive).map(|b| b.addr)
        };
        let Some(addr) = addr else { return };
        if self.ping_addr(addr) {
            return;
        }
        self.mark_dead(id);
    }

    // ---- ownership ------------------------------------------------------

    /// Load `spec` onto its R ring owners and record them in the
    /// directory. Returns the full protocol reply line (`OK loaded …
    /// backend=<primary> replicas=<k>` or `ERR …`) — the session passes
    /// it straight through.
    pub fn load(&self, spec: &str) -> String {
        // resolve the *name* locally first: routing needs the network's
        // name (a path spec and its net name must land on the same
        // owners), and a bad spec should fail here, not on a backend. A
        // `learn:` spec carries its name in the spec itself, so the
        // (expensive, backend-side) learning never runs on the front.
        let name = if crate::learn::is_learn_spec(spec) {
            match crate::learn::LearnSpec::parse(spec) {
                Ok(parsed) => parsed.name,
                Err(e) => return format!("ERR {e}"),
            }
        } else {
            match crate::bn::resolve_spec(spec) {
                Ok(net) => net.name,
                Err(e) => return format!("ERR {e}"),
            }
        };
        self.register_on_owner(&name, spec, &format!("LOAD {spec}"), "LOAD")
    }

    /// `LEARN` passthrough: route the verb to the primary ring owner of
    /// `name` (which runs the sample→learn pipeline and registers the
    /// result), replicate the equivalent deterministic `learn:` spec to
    /// the remaining replicas, and record it in the directory — a later
    /// hand-off re-`LOAD`s that spec on the new owner, re-learning the
    /// **bit-identical** network there.
    pub fn learn(&self, name: &str, learn_spec: &str, line: &str) -> String {
        self.register_on_owner(name, learn_spec, line, "LEARN")
    }

    /// Shared LOAD/LEARN routing: send `line` to `name`'s primary ring
    /// owner, replicate the spec to the remaining R−1 desired owners,
    /// record the replica set in the directory on success, evict stale
    /// previous owners, and annotate the reply with
    /// `backend=<primary> replicas=<k>`.
    ///
    /// Ordinary specs run under the `control` mutex like every transition
    /// (the RPCs are tree compiles, bounded by `io_timeout`). A
    /// **learn** spec's RPC runs the whole sampling + PC + MLE pipeline
    /// on the backend under `learn_timeout` — minutes, not seconds — so
    /// it executes *outside* `control` and only the directory commit
    /// re-takes the lock: a slow learn must not stall failover, probing,
    /// and every other session's LOAD behind the control mutex. The
    /// commit records the replicas that ran the verb and are still alive
    /// (ring drift is fine — sessions follow the directory, and the next
    /// rebalance re-homes the net); executors that all *died* between
    /// finishing and the commit are re-homed immediately instead of
    /// being recorded as dead owners nobody would ever route to.
    fn register_on_owner(&self, name: &str, spec: &str, line: &str, verb: &str) -> String {
        let ctl = if crate::learn::is_learn_spec(spec) { None } else { Some(self.control.lock().unwrap()) };
        let desired = self.place_replicas(name);
        let Some((primary_id, primary_addr)) = desired.first().cloned() else {
            return format!("ERR no live backends to host {name:?}");
        };
        match self.remote_line_bounded(primary_addr, line, self.control_timeout(spec)) {
            Ok(reply) if reply.starts_with("OK") => {
                // replicate the spec to the remaining desired owners
                // before the commit — a replica that fails to load simply
                // drops out of the recorded set (the next rebalance
                // retries it)
                let mut loaded = vec![primary_id.clone()];
                for (id, addr) in desired.iter().skip(1) {
                    if self.load_spec_on(*addr, name, spec) {
                        loaded.push(id.clone());
                    }
                }
                let _ctl = ctl.unwrap_or_else(|| self.control.lock().unwrap());
                let (owners, prev) = {
                    let mut st = self.state.lock().unwrap();
                    // only filters on the lockless learn path: an executor
                    // may have been declared dead while it was learning
                    let owners: Vec<String> = loaded
                        .into_iter()
                        .filter(|id| st.backends.get(id).map(|b| b.alive).unwrap_or(false))
                        .collect();
                    let prev = st
                        .directory
                        .insert(name.to_string(), NetEntry { spec: spec.to_string(), owners: owners.clone() })
                        .map(|e| e.owners)
                        .unwrap_or_default();
                    (owners, prev)
                };
                if let Some(primary) = owners.first() {
                    let primary = primary.clone();
                    // a re-LOAD that lands on new owners (ring changed
                    // while the net was orphaned, say) evicts the stale
                    // residents
                    self.evict_stale(name, &prev, &owners);
                    return format!("{reply} backend={primary} replicas={}", owners.len());
                }
                // control is held, so re-home right now — a learn spec
                // re-learns deterministically on the new owners
                self.rebalance(false);
                match self.owner(name) {
                    Some(new_owner) => {
                        format!("{reply} backend={new_owner} replicas={}", self.replicas_of(name).len())
                    }
                    None => format!(
                        "ERR backend {primary_id} was lost after {verb}; {name:?} has no live backend to re-home onto"
                    ),
                }
            }
            Ok(reply) => reply,
            Err(e) => {
                drop(ctl); // report_failure takes `control` via mark_dead
                self.report_failure(&primary_id);
                format!("ERR backend {primary_id} unreachable during {verb}: {e}")
            }
        }
    }

    /// Resolve a network to a live replica owner (the first in placement
    /// order — the primary, or the senior survivor after a failover).
    pub fn lookup(&self, net: &str) -> Lookup {
        let st = self.state.lock().unwrap();
        let Some(entry) = st.directory.get(net) else { return Lookup::Unknown };
        let owned = entry
            .owners
            .iter()
            .find_map(|id| st.backends.get(id).filter(|b| b.alive).map(|b| (id.clone(), b.addr)));
        match owned {
            Some((id, addr)) => Lookup::Owned { id, addr },
            None => Lookup::Orphaned,
        }
    }

    /// Primary directory owner of `net` (`None` if unknown or orphaned).
    pub fn owner(&self, net: &str) -> Option<String> {
        self.state.lock().unwrap().directory.get(net).and_then(|e| e.owners.first().cloned())
    }

    /// Every directory replica owner of `net`, primary first (empty if
    /// unknown or orphaned).
    pub fn replicas_of(&self, net: &str) -> Vec<String> {
        self.state.lock().unwrap().directory.get(net).map(|e| e.owners.clone()).unwrap_or_default()
    }

    /// The *alive* replica owners of `net` with their addresses, primary
    /// first — the targets a clean session's read-only verbs spread over.
    pub fn read_targets(&self, net: &str) -> Vec<(String, SocketAddr)> {
        let st = self.state.lock().unwrap();
        let Some(entry) = st.directory.get(net) else { return Vec::new() };
        entry
            .owners
            .iter()
            .filter_map(|id| st.backends.get(id).filter(|b| b.alive).map(|b| (id.clone(), b.addr)))
            .collect()
    }

    /// The spec `net` was loaded from.
    pub fn spec_of(&self, net: &str) -> Option<String> {
        self.state.lock().unwrap().directory.get(net).map(|e| e.spec.clone())
    }

    /// Is (net, backend) still a live routing assignment? `Current` as
    /// long as the backend remains *one of* the net's replica owners —
    /// a primary change alone never unpins a session.
    pub fn confirm(&self, net: &str, backend: &str) -> Confirm {
        let st = self.state.lock().unwrap();
        match st.directory.get(net) {
            None => Confirm::Unloaded,
            Some(e) if e.owners.iter().any(|o| o == backend) => Confirm::Current,
            Some(_) => Confirm::Moved,
        }
    }

    /// Per-backend status, sorted by id.
    pub fn backends(&self) -> Vec<BackendStatus> {
        let st = self.state.lock().unwrap();
        st.backends
            .iter()
            .map(|(id, b)| BackendStatus {
                id: id.clone(),
                addr: b.addr,
                alive: b.alive,
                owned_nets: st.directory.values().filter(|e| e.owners.iter().any(|o| o == id.as_str())).count(),
            })
            .collect()
    }

    /// Directory view: network → replica owner ids (primary first),
    /// sorted by name.
    pub fn directory(&self) -> Vec<(String, Vec<String>)> {
        let st = self.state.lock().unwrap();
        st.directory.iter().map(|(n, e)| (n.clone(), e.owners.clone())).collect()
    }

    fn alive_counts(&self) -> (usize, usize, usize) {
        let st = self.state.lock().unwrap();
        (st.backends.len(), st.backends.values().filter(|b| b.alive).count(), st.directory.len())
    }

    /// Desired replica owners of `name` among live ring members, primary
    /// first, with addresses.
    fn place_replicas(&self, name: &str) -> Vec<(String, SocketAddr)> {
        let st = self.state.lock().unwrap();
        st.ring
            .owners(name, self.cfg.replicas.max(1))
            .into_iter()
            .filter_map(|id| st.backends.get(&id).map(|b| (id.clone(), b.addr)))
            .collect()
    }

    fn addr_if_alive(&self, id: &str) -> Option<SocketAddr> {
        let st = self.state.lock().unwrap();
        st.backends.get(id).filter(|b| b.alive).map(|b| b.addr)
    }

    /// Every alive backend with its address, sorted by id — the scrape
    /// set for cluster-wide verbs (`NETS`/`STATS`/`METRICS`/`TRACE`/
    /// `PROFILE`).
    fn alive_targets(&self) -> Vec<(String, SocketAddr)> {
        let st = self.state.lock().unwrap();
        st.backends.iter().filter(|(_, b)| b.alive).map(|(id, b)| (id.clone(), b.addr)).collect()
    }

    /// Post-hand-off cleanup: `EVICT` `name` from previous owners that
    /// are not in the new replica set and are still alive (a dead one has
    /// nothing to free; a revival's stale residents are routed around
    /// anyway).
    fn evict_stale(&self, name: &str, prev: &[String], keep: &[String]) {
        for prev_id in prev {
            if keep.iter().any(|k| k == prev_id) {
                continue;
            }
            if let Some(addr) = self.addr_if_alive(prev_id) {
                let _ = self.remote_line(addr, &format!("EVICT {name}"));
            }
        }
    }

    /// `LOAD` the recorded spec onto one backend, self-healing the
    /// learn-spec "already resident of different provenance" case (a
    /// revival that kept residents it no longer owns): evict there and
    /// retry once — the directory's spec is the truth.
    fn load_spec_on(&self, addr: SocketAddr, name: &str, spec: &str) -> bool {
        let timeout = self.control_timeout(spec);
        let reply = self.remote_line_bounded(addr, &format!("LOAD {spec}"), timeout);
        let ok = matches!(&reply, Ok(r) if r.starts_with("OK"));
        if ok || !crate::learn::is_learn_spec(spec) {
            return ok;
        }
        match &reply {
            Ok(r) if r.contains("already resident") => {
                let _ = self.remote_line(addr, &format!("EVICT {name}"));
                let retry = self.remote_line_bounded(addr, &format!("LOAD {spec}"), timeout);
                matches!(retry, Ok(r) if r.starts_with("OK"))
            }
            _ => false,
        }
    }

    /// Re-home every network whose directory owners disagree with the
    /// ring's desired replica set: `LOAD` on the new members of the set,
    /// then (when `evict_old` — join and graceful leave, where the
    /// previous owners are still listening) `EVICT` on members that fell
    /// off it. Orphans re-home too. If *no* desired replica can load the
    /// net, still-alive previous owners keep routing (they hold the tree)
    /// rather than orphaning a working network; the next rebalance
    /// retries the move. Caller holds `control`; state is locked only
    /// around reads/commits, never I/O.
    fn rebalance(&self, evict_old: bool) {
        let nets: Vec<(String, String, Vec<String>)> = {
            let st = self.state.lock().unwrap();
            st.directory.iter().map(|(n, e)| (n.clone(), e.spec.clone(), e.owners.clone())).collect()
        };
        for (name, spec, prev) in nets {
            let desired = self.place_replicas(&name);
            if desired.is_empty() {
                let mut st = self.state.lock().unwrap();
                if let Some(e) = st.directory.get_mut(&name) {
                    e.owners.clear();
                }
                continue;
            }
            if desired.len() == prev.len() && desired.iter().map(|(id, _)| id).eq(prev.iter()) {
                continue;
            }
            // keep replicas already holding the net (desired ids come off
            // the ring, so they are alive); LOAD it on the new ones. A
            // hand-off re-learning of a learn: spec runs under the learn
            // budget inside load_spec_on.
            let mut next: Vec<String> = Vec::with_capacity(desired.len());
            for (id, addr) in &desired {
                if prev.iter().any(|p| p == id) || self.load_spec_on(*addr, &name, &spec) {
                    next.push(id.clone());
                }
            }
            let moved = !next.is_empty();
            let committed = {
                let mut st = self.state.lock().unwrap();
                let next = if moved {
                    next
                } else {
                    prev.iter()
                        .filter(|p| st.backends.get(*p).map(|b| b.alive).unwrap_or(false))
                        .cloned()
                        .collect()
                };
                if let Some(e) = st.directory.get_mut(&name) {
                    e.owners = next.clone();
                }
                next
            };
            if moved && evict_old {
                self.evict_stale(&name, &prev, &committed);
            }
        }
    }

    // ---- probing --------------------------------------------------------

    fn probe_tick(&self) {
        let now = Instant::now();
        let due: Vec<(String, SocketAddr)> = {
            let st = self.state.lock().unwrap();
            st.backends.iter().filter(|(_, b)| now >= b.next_probe).map(|(id, b)| (id.clone(), b.addr)).collect()
        };
        for (id, addr) in due {
            let ok = self.ping_addr(addr);
            self.apply_probe(&id, ok);
        }
    }

    fn apply_probe(&self, id: &str, ok: bool) {
        let action = {
            let mut st = self.state.lock().unwrap();
            let Some(b) = st.backends.get_mut(id) else { return };
            let now = Instant::now();
            if b.alive {
                if ok {
                    b.consecutive_failures = 0;
                    b.next_probe = now + self.cfg.probe_interval;
                    ProbeAction::None
                } else {
                    b.consecutive_failures += 1;
                    if b.consecutive_failures >= self.cfg.fail_threshold {
                        ProbeAction::Died
                    } else {
                        b.next_probe = now; // recheck on the next tick
                        ProbeAction::None
                    }
                }
            } else if ok {
                ProbeAction::Revived
            } else {
                b.backoff = (b.backoff * 2).min(self.cfg.probe_backoff_max);
                b.next_probe = now + b.backoff;
                ProbeAction::None
            }
        };
        match action {
            ProbeAction::Died => self.mark_dead(id),
            ProbeAction::Revived => self.revive(id),
            ProbeAction::None => {}
        }
    }

    fn ping_addr(&self, addr: SocketAddr) -> bool {
        let connect = self.cfg.connect_timeout.min(self.cfg.probe_timeout);
        match BackendConn::connect(addr, connect, self.cfg.probe_timeout) {
            Ok(mut conn) => matches!(conn.request("PING"), Ok(r) if r.starts_with("OK")),
            Err(_) => false,
        }
    }

    // ---- protocol surfaces ---------------------------------------------

    /// Open a data-plane connection to a backend.
    pub fn connect(&self, addr: SocketAddr) -> std::io::Result<BackendConn> {
        BackendConn::connect(addr, self.cfg.connect_timeout, self.cfg.io_timeout)
    }

    fn remote_line(&self, addr: SocketAddr, line: &str) -> std::io::Result<String> {
        self.connect(addr)?.request(line)
    }

    /// One counted-block request/reply (the `METRICS` shape) on a
    /// short-lived control connection.
    fn remote_block(&self, addr: SocketAddr, line: &str) -> std::io::Result<(String, Vec<String>)> {
        self.connect(addr)?.request_block(line)
    }

    /// `remote_line` with an explicit read bound (learn-spec control
    /// lines outlive the ordinary `io_timeout` by design).
    fn remote_line_bounded(&self, addr: SocketAddr, line: &str, read_timeout: Duration) -> std::io::Result<String> {
        BackendConn::connect(addr, self.cfg.connect_timeout, read_timeout)?.request(line)
    }

    /// Read bound for a control-plane line that registers `spec`: a
    /// `learn:` spec runs the whole sampling + PC + MLE pipeline on the
    /// backend, so it gets `learn_timeout` instead of `io_timeout`.
    fn control_timeout(&self, spec: &str) -> Duration {
        if crate::learn::is_learn_spec(spec) {
            self.cfg.io_timeout.max(self.cfg.learn_timeout)
        } else {
            self.cfg.io_timeout
        }
    }

    /// `PING` reply: front-tier liveness + topology counts.
    pub fn ping_line(&self) -> String {
        let (backends, alive, nets) = self.alive_counts();
        format!("OK pong backends={backends} alive={alive} nets={nets}")
    }

    /// `TOPO` reply: per-backend address, health, and ownership.
    pub fn topo_line(&self) -> String {
        let statuses = self.backends();
        let mut out = format!("OK backends={}", statuses.len());
        for s in &statuses {
            out.push_str(&format!(" {}[addr={} alive={} nets={}]", s.id, s.addr, s.alive, s.owned_nets));
        }
        out
    }

    /// Cluster-wide `NETS`: every alive backend's residents, filtered to
    /// directory-owned networks and annotated `@<primary>`. Any replica's
    /// listing can fill a network's block (replicas are byte-identical,
    /// so the attributes agree); the label is always the primary so the
    /// output is deterministic.
    pub fn nets_line(&self) -> String {
        let owners: BTreeMap<String, Vec<String>> = self.directory().into_iter().collect();
        let targets = self.alive_targets();
        let mut blocks: BTreeMap<String, String> = BTreeMap::new();
        for (id, addr) in &targets {
            let Ok(reply) = self.remote_line(*addr, "NETS") else { continue };
            for raw in reply.split(']') {
                let Some((head, attrs)) = raw.split_once('[') else { continue };
                let Some(name) = head.split_whitespace().last() else { continue };
                let Some(owns) = owners.get(name) else { continue };
                if owns.iter().any(|o| o == id) {
                    let primary = owns.first().cloned().unwrap_or_default();
                    blocks.insert(name.to_string(), format!("{name}[{attrs}]@{primary}"));
                }
            }
        }
        let mut out = format!("OK nets={}", blocks.len());
        for block in blocks.values() {
            out.push(' ');
            out.push_str(block);
        }
        out
    }

    /// Cluster-wide `STATS`: per-network lines aggregated across each
    /// network's replica owners plus cluster totals. Headline percentiles
    /// come from the bucket-wise merge of every backend's latency
    /// histograms (scraped via `METRICS` — exact up to bucket resolution,
    /// since log2 bucket counts add losslessly across backends). There is
    /// deliberately no count-weighted-percentile fallback: a backend that
    /// fails its scrape — or exposes no histograms while queries were
    /// served — is *reported* by marking the line `stats=partial` instead
    /// of silently blending a biased estimate into the headline.
    pub fn stats_line(&self) -> String {
        let targets = self.alive_targets();
        let owners: BTreeMap<String, Vec<String>> = self.directory().into_iter().collect();
        let mut per_net: BTreeMap<String, NetAgg> = BTreeMap::new();
        let mut scrapes: Vec<crate::obs::scrape::Scrape> = Vec::new();
        let mut responded = 0usize;
        for (id, addr) in &targets {
            let stats_reply = self.remote_line(*addr, "STATS");
            let metrics_reply = self.remote_block(*addr, "METRICS");
            let metrics_ok = matches!(&metrics_reply, Ok((h, _)) if h.starts_with("OK metrics"));
            if stats_reply.is_ok() && metrics_ok {
                responded += 1;
            }
            if let Ok(reply) = &stats_reply {
                for stat in parse_backend_stats(reply) {
                    let Some(owns) = owners.get(&stat.net) else { continue };
                    if !owns.iter().any(|o| o == id) {
                        continue;
                    }
                    let agg = per_net.entry(stat.net.clone()).or_insert_with(|| NetAgg::new(owns));
                    agg.add(&stat, owns.first().map(|p| p == id).unwrap_or(false));
                }
            }
            if metrics_ok {
                if let Ok((_, body)) = metrics_reply {
                    scrapes.push(crate::obs::scrape::parse(&body.join("\n")));
                }
            }
        }
        let (backends, alive, nets) = self.alive_counts();
        let scrape_refs: Vec<&crate::obs::scrape::Scrape> = scrapes.iter().collect();
        let merged =
            crate::obs::scrape::merged_percentiles(&scrape_refs, "fastbn_query_latency_us", &[0.5, 0.99]);
        let queries: u64 = per_net.values().map(|a| a.queries).sum();
        let errors: u64 = per_net.values().map(|a| a.errors).sum();
        let (p50_us, p99_us) = merged.as_ref().map(|ps| (ps[0], ps[1])).unwrap_or((0, 0));
        // partial: some alive backend failed its STATS/METRICS scrape, or
        // queries were served with no histogram anywhere to merge
        let partial = responded < targets.len() || (merged.is_none() && queries > 0);
        let mut out = format!(
            "STATS cluster uptime_ms={} backends={backends} alive={alive} nets={nets} queries={queries} errors={errors} p50_us={p50_us} p99_us={p99_us}",
            self.started.elapsed().as_millis(),
        );
        if partial {
            out.push_str(" stats=partial");
        }
        for (net, agg) in &per_net {
            out.push_str(&format!(
                " | {net} backend={} replicas={}/{} queries={} errors={} qps={:.2} p50_us={} p99_us={}",
                agg.primary, agg.seen, agg.total, agg.queries, agg.errors, agg.qps, agg.p50_us, agg.p99_us
            ));
        }
        for (net, owns) in &owners {
            if owns.is_empty() {
                out.push_str(&format!(" | {net} backend=none orphaned=true"));
            }
        }
        out
    }

    /// Cluster-wide `METRICS`: scrape every alive backend's exposition
    /// and merge — counters and histogram buckets summed into aggregate
    /// series, plus every backend's series re-labeled `backend="id"` so
    /// outliers stay attributable. Same counted-block reply shape as the
    /// backend verb: `OK metrics backends=<scraped> lines=<n>` then n
    /// lines. Backends that fail to answer are simply absent from the
    /// scrape (and from `backends=`).
    pub fn metrics_line(&self) -> String {
        let targets = self.alive_targets();
        let mut parts: Vec<(String, String)> = Vec::new();
        for (id, addr) in &targets {
            let Ok((header, body)) = self.remote_block(*addr, "METRICS") else { continue };
            if header.starts_with("OK metrics") {
                parts.push((id.clone(), body.join("\n")));
            }
        }
        let merged = crate::obs::scrape::merge_exposition(&parts);
        if merged.is_empty() {
            return format!("OK metrics backends={} lines=0", parts.len());
        }
        format!("OK metrics backends={} lines={}\n{merged}", parts.len(), merged.lines().count())
    }

    // ---- cross-tier tracing and profiling -------------------------------

    /// Is cross-tier query tracing armed? (flipped by `TRACE on|off`.)
    pub fn trace_armed(&self) -> bool {
        self.trace_armed.load(Ordering::Relaxed)
    }

    /// Mint the next query id (`q1`, `q2`, …) when tracing is armed.
    /// `None` when disarmed — the caller forwards the line untouched, so
    /// disarmed replies stay byte-identical to an untraced cluster's.
    pub fn mint_qid(&self) -> Option<String> {
        if !self.trace_armed() {
            return None;
        }
        Some(format!("q{}", self.qid_seq.fetch_add(1, Ordering::Relaxed) + 1))
    }

    /// Record where a tagged query ran (bounded history, oldest evicted).
    pub fn record_route(&self, qid: &str, net: &str, backend: &str, route: Duration) {
        let mut routes = self.routes.lock().unwrap();
        if routes.len() >= ROUTE_CAP {
            routes.pop_front();
        }
        routes.push_back(RouteRecord {
            qid: qid.to_string(),
            net: net.to_string(),
            backend: backend.to_string(),
            route_us: route.as_micros() as u64,
        });
    }

    fn route_of(&self, qid: &str) -> Option<(String, String, u64)> {
        let routes = self.routes.lock().unwrap();
        routes.iter().rev().find(|r| r.qid == qid).map(|r| (r.net.clone(), r.backend.clone(), r.route_us))
    }

    /// The cluster `TRACE` verb, answered by the front. `on`/`off`
    /// broadcast the recorder toggle to every alive backend (spans are
    /// captured where the engines run) and arm/disarm front-side qid
    /// minting; `last` scrapes every alive backend and returns the
    /// freshest trace tagged `backend="id"` — spread reads mean the most
    /// recent query may have run on *any* replica, so asking one owner is
    /// not enough; `q<digits>` assembles the cross-tier timeline of one
    /// tagged query (front route → owning backend → its span tree).
    pub fn trace_line(&self, arg: &str) -> String {
        match arg.to_ascii_lowercase().as_str() {
            "on" => self.trace_toggle(true),
            "off" => self.trace_toggle(false),
            "last" => self.trace_last(),
            qid if qid.len() > 1 && qid.starts_with('q') && qid[1..].bytes().all(|b| b.is_ascii_digit()) => {
                self.trace_qid(qid)
            }
            _ => "ERR usage: TRACE <on|off|last|q<n>>".into(),
        }
    }

    fn trace_toggle(&self, on: bool) -> String {
        let word = if on { "on" } else { "off" };
        let verb = format!("TRACE {word}");
        let mut acked = 0;
        for (_, addr) in self.alive_targets() {
            if matches!(self.remote_line(addr, &verb), Ok(r) if r.starts_with("OK")) {
                acked += 1;
            }
        }
        self.trace_armed.store(on, Ordering::Relaxed);
        format!("OK trace {word} backends={acked}")
    }

    /// Scrape-all `TRACE last`: pick the freshest root span across the
    /// alive backends by the `at=` publication stamp and tag the line
    /// with the backend it came from. The tag goes at the END so the
    /// `OK trace total_us=` reply prefix stays what single-fleet clients
    /// already parse.
    fn trace_last(&self) -> String {
        let mut best: Option<(u64, String, String)> = None;
        for (id, addr) in self.alive_targets() {
            let Ok(reply) = self.remote_line(addr, "TRACE last") else { continue };
            let Some(body) = reply.strip_prefix("OK trace ") else { continue };
            let at = body
                .split_whitespace()
                .rev()
                .find_map(|t| t.strip_prefix("at=").and_then(|v| v.parse::<u64>().ok()))
                .unwrap_or(0);
            if best.as_ref().map(|(b, _, _)| at > *b).unwrap_or(true) {
                best = Some((at, id, body.to_string()));
            }
        }
        match best {
            Some((_, id, body)) => format!("OK trace {body} backend=\"{id}\""),
            None => "ERR no trace recorded on any backend (TRACE on, then QUERY)".into(),
        }
    }

    /// Assemble one tagged query's timeline: the route record names the
    /// backend that served it (asked first; the full alive set is the
    /// fallback — failover may have moved things since), and the reply
    /// merges the front's routing view with the backend's span tree into
    /// a single line.
    fn trace_qid(&self, qid: &str) -> String {
        let route = self.route_of(qid);
        let mut targets = self.alive_targets();
        if let Some((_, backend, _)) = &route {
            targets.sort_by_key(|(id, _)| id != backend);
        }
        for (id, addr) in targets {
            let Ok(reply) = self.remote_line(addr, &format!("TRACE {qid}")) else { continue };
            let Some(body) = reply.strip_prefix("OK trace ") else { continue };
            let (net, route_us) = match &route {
                Some((net, _, us)) => (net.as_str(), *us),
                None => ("?", 0),
            };
            return format!("OK trace qid={qid} net={net} backend=\"{id}\" route_us={route_us} {body}");
        }
        format!("ERR no trace recorded for qid {qid:?} on any backend")
    }

    /// The cluster `PROFILE` verb: `on`/`off` broadcast the pool-profiler
    /// toggle to every alive backend; bare `PROFILE` scrapes each
    /// backend's per-region report and returns one counted block with
    /// every line prefixed `backend="id"`, so per-worker lanes stay
    /// attributable to the process that ran them.
    pub fn profile_line(&self, arg: &str) -> String {
        match arg.to_ascii_lowercase().as_str() {
            word @ ("on" | "off") => {
                let verb = format!("PROFILE {word}");
                let mut acked = 0;
                for (_, addr) in self.alive_targets() {
                    if matches!(self.remote_line(addr, &verb), Ok(r) if r.starts_with("OK")) {
                        acked += 1;
                    }
                }
                format!("OK profile {word} backends={acked}")
            }
            "" => {
                let mut lines: Vec<String> = Vec::new();
                let mut scraped = 0;
                for (id, addr) in self.alive_targets() {
                    let Ok((header, body)) = self.remote_block(addr, "PROFILE") else { continue };
                    if !header.starts_with("OK profile") {
                        continue;
                    }
                    scraped += 1;
                    for l in body {
                        lines.push(format!("backend=\"{id}\" {l}"));
                    }
                }
                if lines.is_empty() {
                    return format!("OK profile backends={scraped} lines=0");
                }
                format!("OK profile backends={scraped} lines={}\n{}", lines.len(), lines.join("\n"))
            }
            _ => "ERR usage: PROFILE [on|off]".into(),
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One per-network segment parsed from a backend `STATS` line.
struct NetStat {
    net: String,
    queries: u64,
    errors: u64,
    qps: f64,
    p50_us: u64,
    p99_us: u64,
}

/// One network's stats aggregated across its replica owners: counts and
/// qps sum (each replica counts only the queries it served); percentiles
/// are taken from the primary's snapshot (first responder as a fallback)
/// rather than averaged — per-replica percentiles don't compose, and the
/// *cluster* headline already has the exact bucket merge.
struct NetAgg {
    primary: String,
    total: usize,
    seen: usize,
    queries: u64,
    errors: u64,
    qps: f64,
    p50_us: u64,
    p99_us: u64,
    primary_seen: bool,
}

impl NetAgg {
    fn new(owners: &[String]) -> Self {
        NetAgg {
            primary: owners.first().cloned().unwrap_or_default(),
            total: owners.len(),
            seen: 0,
            queries: 0,
            errors: 0,
            qps: 0.0,
            p50_us: 0,
            p99_us: 0,
            primary_seen: false,
        }
    }

    fn add(&mut self, stat: &NetStat, is_primary: bool) {
        self.queries += stat.queries;
        self.errors += stat.errors;
        self.qps += stat.qps;
        if is_primary || !self.primary_seen && self.seen == 0 {
            self.p50_us = stat.p50_us;
            self.p99_us = stat.p99_us;
        }
        self.primary_seen |= is_primary;
        self.seen += 1;
    }
}

/// Parse a fleet `STATS` reply (`STATS uptime_ms=… nets=N | <net>
/// queries=… errors=… qps=… p50_us=… p99_us=… | …`) into per-net stats.
/// Unknown fields are ignored so the formats can evolve independently.
fn parse_backend_stats(reply: &str) -> Vec<NetStat> {
    let mut out = Vec::new();
    for segment in reply.split(" | ").skip(1) {
        let mut tokens = segment.split_whitespace();
        let Some(net) = tokens.next() else { continue };
        let mut stat = NetStat { net: net.to_string(), queries: 0, errors: 0, qps: 0.0, p50_us: 0, p99_us: 0 };
        for token in tokens {
            let Some((key, value)) = token.split_once('=') else { continue };
            match key {
                "queries" => stat.queries = value.parse().unwrap_or(0),
                "errors" => stat.errors = value.parse().unwrap_or(0),
                "qps" => stat.qps = value.parse().unwrap_or(0.0),
                "p50_us" => stat.p50_us = value.parse().unwrap_or(0),
                "p99_us" => stat.p99_us = value.parse().unwrap_or(0),
                _ => {}
            }
        }
        out.push(stat);
    }
    out
}

// ---- the per-connection proxy session ----------------------------------

struct Active {
    net: String,
    backend: String,
    conn: BackendConn,
}

/// One pooled read connection: a backend-side session used only for
/// read-only verbs (`QUERY`, `BATCH`/`CASE`) of a clean front session.
/// It never carries evidence, so re-`USE`ing it (to switch nets, or
/// after a reconnect) is always safe.
struct ReadConn {
    backend: String,
    /// Net its backend-side session currently has selected (empty until
    /// the first `USE` on it succeeds).
    net: String,
    conn: BackendConn,
}

enum ReadOutcome {
    /// The replica answered (the reply may still be a protocol `ERR`).
    Reply(String),
    /// Transport failure — the conn is dropped; report and try another.
    Dead,
    /// The replica is reachable but can't serve this net right now.
    Skip,
}

/// One client's front-tier session: routes control verbs to the cluster,
/// pins evidence-bearing data-plane verbs to one owning backend's
/// connection (where the backend-side session holds the streamed-evidence
/// state), and spreads a **clean** session's read-only verbs across the
/// network's replicas.
///
/// The front keeps a mirror of the evidence the client staged/committed
/// through this session (maintained from the `OK` replies of forwarded
/// `OBSERVE`/`RETRACT`/`COMMIT`). The mirror is what makes the rest safe:
/// a session is *clean* iff the mirror is empty, and only clean sessions'
/// `QUERY`/`BATCH` round-robin across replicas — every replica is
/// byte-identical by construction, so a clean read can hop replicas (and
/// transparently fail over when one dies) without any risk of misapplying
/// evidence. Evidence-bearing sessions keep the original sticky contract:
/// when their pinned backend dies or loses the net, the next verb gets a
/// clean `ERR … USE it again`, never a silent reroute. The mirror also
/// backs the `HANDOFF` verb: it exports the committed evidence so a peer
/// router can replay it (`USE` + one atomic `OBSERVE` + `COMMIT`) and
/// resume the session with identical state — any replay failure drops the
/// pin entirely, so a half-applied hand-off can never answer queries.
///
/// `BATCH` passthrough: the front mirrors the backend's batch counting —
/// it remembers `n` from a successful `BATCH <n> <target>` forward, lets
/// the first `n-1` `CASE` lines round-trip one-for-one, and reads **n**
/// reply lines for the final `CASE` (the backend answers the whole batch
/// at once). A clean session's batch runs on a replica read conn with
/// every line buffered: backend acks are deterministic (`OK batch …`,
/// `OK case i/n`), so if the replica dies mid-collection the front
/// replays the buffered prefix on a survivor and the client never sees
/// the failure. Verbs the front answers locally (NETS/STATS/PING/TOPO/
/// LOAD/JOIN) never reach a backend conn, so they leave both sides'
/// batch state untouched; any *forwarded* non-CASE verb aborts the batch
/// on both tiers at once.
pub struct ClusterSession {
    cluster: Arc<Cluster>,
    active: Option<Active>,
    /// (cases remaining, total) of an in-progress forwarded batch.
    batch: Option<(usize, usize)>,
    /// Front-side mirror of evidence committed through this session:
    /// var → state, as the client spelled them.
    committed: BTreeMap<String, String>,
    /// Mirror of staged-but-uncommitted deltas, in order (`None` =
    /// retract). Non-empty pending also pins reads: the safe default.
    pending: Vec<(String, Option<String>)>,
    /// Pooled read conns, one per backend this session has read from.
    read_conns: Vec<ReadConn>,
    /// Round-robin cursor over a net's read targets.
    read_rr: usize,
    /// Backend that answered the most recent spread read.
    last_read: Option<String>,
    /// Replica a clean-session batch collection lives on…
    batch_backend: Option<String>,
    /// …and the verbatim `BATCH` + `CASE` lines to replay if it dies.
    batch_lines: Vec<String>,
}

impl ClusterSession {
    /// New session; nothing selected.
    pub fn new(cluster: Arc<Cluster>) -> Self {
        ClusterSession {
            cluster,
            active: None,
            batch: None,
            committed: BTreeMap::new(),
            pending: Vec::new(),
            read_conns: Vec::new(),
            read_rr: 0,
            last_read: None,
            batch_backend: None,
            batch_lines: Vec::new(),
        }
    }

    /// Network the session is pinned to, if any.
    pub fn current_net(&self) -> Option<&str> {
        self.active.as_ref().map(|a| a.net.as_str())
    }

    /// No evidence staged or committed — reads may spread over replicas.
    fn session_clean(&self) -> bool {
        self.committed.is_empty() && self.pending.is_empty()
    }

    /// Forget an in-progress batch (front side only).
    fn abort_batch(&mut self) {
        self.batch = None;
        self.batch_backend = None;
        self.batch_lines.clear();
    }

    /// Tear the whole pin down: selection, batch, and evidence mirror.
    fn drop_pin(&mut self) {
        self.active = None;
        self.abort_batch();
        self.committed.clear();
        self.pending.clear();
    }

    /// Keep the evidence mirror in sync with the backend session's
    /// accounting, from the `OK` reply of a forwarded evidence verb.
    fn mirror(&mut self, verb: &str, rest: &str, reply: &str) {
        if !reply.starts_with("OK") {
            return;
        }
        match verb {
            "OBSERVE" => {
                for tok in rest.split_whitespace() {
                    if let Some((var, state)) = tok.split_once('=') {
                        self.pending.push((var.to_string(), Some(state.to_string())));
                    }
                }
            }
            "RETRACT" => {
                for var in rest.split_whitespace() {
                    self.pending.push((var.to_string(), None));
                }
            }
            "COMMIT" => {
                for (var, state) in std::mem::take(&mut self.pending) {
                    match state {
                        Some(s) => {
                            self.committed.insert(var, s);
                        }
                        None => {
                            self.committed.remove(&var);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    /// Handle one protocol line, producing one reply.
    pub fn handle(&mut self, line: &str) -> SessionReply {
        let line = line.trim();
        if line.is_empty() {
            return SessionReply::Line("ERR empty request".into());
        }
        let mut parts = line.splitn(2, ' ');
        let verb = parts.next().unwrap_or("");
        let rest = parts.next().unwrap_or("").trim();
        let verb = verb.to_ascii_uppercase();
        let reply = match verb.as_str() {
            "QUIT" => return SessionReply::Quit,
            "LOAD" => {
                if rest.is_empty() {
                    "ERR usage: LOAD <net>".into()
                } else {
                    self.cluster.load(rest)
                }
            }
            "LEARN" => self.cmd_learn(rest),
            "USE" => self.cmd_use(rest),
            "JOIN" => self.cmd_join(rest),
            "HANDOFF" => self.cmd_handoff(rest),
            "NETS" => self.cluster.nets_line(),
            "STATS" => self.cluster.stats_line(),
            "METRICS" => self.cluster.metrics_line(),
            "PING" => self.cluster.ping_line(),
            "TOPO" => self.cluster.topo_line(),
            // TRACE and PROFILE are answered by the front over short-lived
            // control connections (broadcast toggles, scrape-all reads) —
            // like METRICS/STATS they never touch the pinned conn, so both
            // sides' batch state is left alone.
            "TRACE" => self.cluster.trace_line(rest),
            "PROFILE" => self.cluster.profile_line(rest),
            // a forwarded data verb reaches a backend session (or tears
            // the pin down), and either way any batch collection is over —
            // mirror that here. Verbs the front answers locally
            // (LOAD/NETS/STATS/METRICS/PING/TOPO/JOIN, unknown) never
            // touch a conn and must leave the mirrored count alone.
            // Evidence verbs also update the evidence mirror.
            "OBSERVE" | "RETRACT" | "COMMIT" => {
                self.abort_batch();
                let reply = self.forward(line);
                self.mirror(&verb, rest, &reply);
                reply
            }
            "QUERY" | "MPE" => {
                self.abort_batch();
                self.cmd_query(line)
            }
            "BATCH" => self.cmd_batch(line, rest),
            "CASE" => self.cmd_case(line),
            other => format!("ERR unknown verb {other:?}"),
        };
        SessionReply::Line(reply)
    }

    /// `QUERY` (and `MPE`, same routing): a clean session spreads over
    /// replicas; an evidence-bearing one forwards on the pinned conn
    /// (where the evidence lives).
    ///
    /// While tracing is armed (`TRACE on`) the front mints a qid for the
    /// query, appends it as a trailing `#<qid>` token on the forwarded
    /// line (the backend session strips it and tags its trace root),
    /// records which backend served it, and appends ` qid=<qid>` to the
    /// `OK` reply so the client can `TRACE <qid>` the cross-tier
    /// timeline. Disarmed, the line and the reply are byte-identical to
    /// an untraced cluster's.
    fn cmd_query(&mut self, line: &str) -> String {
        let qid = self.cluster.mint_qid();
        let sent = match &qid {
            Some(q) => format!("{line} #{q}"),
            None => line.to_string(),
        };
        let t0 = Instant::now();
        let (reply, backend) = match self.active.as_ref().map(|a| a.net.clone()) {
            Some(net) if self.session_clean() => {
                let reply = self.spread_read(&net, &sent);
                (reply, self.last_read.clone())
            }
            _ => {
                let backend = self.active.as_ref().map(|a| a.backend.clone());
                (self.forward(&sent), backend)
            }
        };
        if let Some(q) = qid {
            if reply.starts_with("OK") {
                let net = self.active.as_ref().map(|a| a.net.clone()).unwrap_or_default();
                self.cluster.record_route(&q, &net, backend.as_deref().unwrap_or("?"), t0.elapsed());
                return format!("{reply} qid={q}");
            }
        }
        reply
    }

    /// Route one read-only line for a clean session: round-robin across
    /// `net`'s alive replicas, hopping to the next on a dead conn —
    /// replicas are byte-identical, so the client sees no error, just the
    /// answer. Per-replica registry drift (`ERR network …` teardown) also
    /// hops; a deterministic protocol `ERR` (bad variable, bad count)
    /// returns as-is.
    fn spread_read(&mut self, net: &str, line: &str) -> String {
        let targets = self.cluster.read_targets(net);
        if targets.is_empty() {
            return match self.cluster.lookup(net) {
                Lookup::Unknown => {
                    self.drop_pin();
                    format!("ERR network {net:?} is no longer loaded anywhere; LOAD and USE it again")
                }
                _ => format!("ERR network {net:?} has no live backend; retry once rerouted"),
            };
        }
        let len = targets.len();
        let mut teardown: Option<String> = None;
        for i in 0..len {
            let (id, addr) = targets[(self.read_rr + i) % len].clone();
            match self.read_request(&id, addr, net, line, 1) {
                ReadOutcome::Reply(reply) => {
                    if reply.starts_with("ERR network") {
                        // that replica's resident was evicted/reloaded
                        // mid-verb; another replica may still answer
                        teardown = Some(reply);
                        continue;
                    }
                    self.read_rr = (self.read_rr + i + 1) % len;
                    self.last_read = Some(id);
                    return reply;
                }
                ReadOutcome::Dead => self.cluster.report_failure(&id),
                ReadOutcome::Skip => {}
            }
        }
        teardown.unwrap_or_else(|| format!("ERR no replica of {net:?} is reachable; retry once rerouted"))
    }

    /// One request on the pooled read conn for `id`, opening (and
    /// `USE`-selecting) it as needed. The conn is taken out of the pool
    /// for the call and returned on success; a transport error drops it.
    fn read_request(&mut self, id: &str, addr: SocketAddr, net: &str, line: &str, n: usize) -> ReadOutcome {
        let mut rc = match self.read_conns.iter().position(|c| c.backend == id) {
            Some(i) => self.read_conns.swap_remove(i),
            None => match self.cluster.connect(addr) {
                Ok(conn) => ReadConn { backend: id.to_string(), net: String::new(), conn },
                Err(_) => return ReadOutcome::Dead,
            },
        };
        if rc.net != net {
            // select the net on the backend-side read session, with the
            // same restart self-heal as the pinned path
            match self.forward_use(&mut rc.conn, net) {
                Ok(reply) if reply.starts_with("OK") => rc.net = net.to_string(),
                Ok(_) => {
                    // conn healthy, replica can't serve this net right now
                    self.read_conns.push(rc);
                    return ReadOutcome::Skip;
                }
                Err(_) => return ReadOutcome::Dead,
            }
        }
        match rc.conn.request_lines(line, n) {
            Ok(lines) => {
                let reply = lines.join("\n");
                if reply.starts_with("ERR network") {
                    // backend-side teardown dropped the selection
                    rc.net.clear();
                }
                self.read_conns.push(rc);
                ReadOutcome::Reply(reply)
            }
            Err(_) => ReadOutcome::Dead,
        }
    }

    /// Forward `BATCH <n> <target>`; on an `OK` reply start mirroring the
    /// backend's case countdown so the final `CASE` reads n lines. A
    /// clean session's batch runs on a replica read conn with its lines
    /// buffered for mid-collection failover.
    fn cmd_batch(&mut self, line: &str, rest: &str) -> String {
        // whatever happens next, the previous collection is over on both
        // sides: the backend aborts it on seeing the BATCH verb, and a
        // failed forward tears the pin (and its session) down
        self.abort_batch();
        let n: Option<usize> = rest.split_whitespace().next().and_then(|t| t.parse().ok());
        let clean_net = self.active.as_ref().map(|a| a.net.clone()).filter(|_| self.session_clean());
        if let Some(net) = clean_net {
            let reply = self.spread_read(&net, line);
            if reply.starts_with("OK") {
                // the backend accepted, so the count parsed there too
                if let Some(n) = n {
                    self.batch = Some((n, n));
                    self.batch_backend = self.last_read.clone();
                    self.batch_lines = vec![line.to_string()];
                }
            }
            return reply;
        }
        let reply = self.forward(line);
        if reply.starts_with("OK") {
            if let Some(n) = n {
                self.batch = Some((n, n));
            }
        }
        reply
    }

    /// Forward one `CASE` line. Mid-batch cases round-trip one-for-one;
    /// the final one comes back as the batch's n result lines.
    fn cmd_case(&mut self, line: &str) -> String {
        let Some((remaining, total)) = self.batch else {
            // no open batch on this session: the pinned backend session
            // answers "no batch in progress" itself
            return self.forward(line);
        };
        if self.batch_backend.is_some() {
            return self.cmd_case_read(line, remaining, total);
        }
        // pinned-path batch (evidence-bearing session): the collection
        // lives and dies with the pinned conn
        if remaining > 1 {
            let reply = self.forward(line);
            // the backend acks every staged case; an ERR mid-batch means
            // it aborted its collection (tree evicted, conn rerouted) —
            // mirror that. A transport error also drops the pin, and the
            // batch with it.
            if self.active.is_some() && !reply.starts_with("ERR") {
                self.batch = Some((remaining - 1, total));
            } else {
                self.batch = None;
            }
            reply
        } else {
            self.batch = None;
            self.forward_multi(line, total)
        }
    }

    /// One `CASE` of a clean-session batch living on a replica read conn.
    fn cmd_case_read(&mut self, line: &str, remaining: usize, total: usize) -> String {
        let Some(net) = self.active.as_ref().map(|a| a.net.clone()) else {
            self.abort_batch();
            return "ERR no network selected (USE <net> first)".into();
        };
        let id = self.batch_backend.clone().expect("read-path batch has a backend");
        let n = if remaining <= 1 { total } else { 1 };
        let target = self.cluster.read_targets(&net).into_iter().find(|(tid, _)| *tid == id);
        let outcome = match target {
            Some((_, addr)) => self.read_request(&id, addr, &net, line, n),
            // the collection's replica no longer serves the net (failover
            // or rebalance): replay the batch on a current replica
            None => ReadOutcome::Skip,
        };
        match outcome {
            ReadOutcome::Reply(reply) => self.settle_case(reply, line, remaining, total),
            ReadOutcome::Dead => {
                self.cluster.report_failure(&id);
                self.replay_batch(&net, line, remaining, total, &id)
            }
            ReadOutcome::Skip => self.replay_batch(&net, line, remaining, total, &id),
        }
    }

    /// Account one read-path `CASE` reply against the mirrored countdown.
    fn settle_case(&mut self, reply: String, line: &str, remaining: usize, total: usize) -> String {
        if remaining <= 1 || reply.starts_with("ERR") {
            // final case answered, or the replica aborted its collection
            // deterministically (a replay would abort identically)
            self.abort_batch();
        } else {
            self.batch = Some((remaining - 1, total));
            self.batch_lines.push(line.to_string());
        }
        reply
    }

    /// A clean-session batch lost its replica mid-collection: replay the
    /// buffered `BATCH` + `CASE` prefix on another replica. Backend acks
    /// are deterministic (`OK batch …`, `OK case i/n` — see
    /// [`crate::fleet::Session`]), so on success the client never
    /// observes the failure, fulfilling the replica-failover contract for
    /// batches too.
    fn replay_batch(&mut self, net: &str, line: &str, remaining: usize, total: usize, failed: &str) -> String {
        let targets: Vec<(String, SocketAddr)> =
            self.cluster.read_targets(net).into_iter().filter(|(id, _)| id != failed).collect();
        let prefix = self.batch_lines.clone();
        'replica: for (id, addr) in targets {
            for prev in &prefix {
                match self.read_request(&id, addr, net, prev, 1) {
                    ReadOutcome::Reply(r) if r.starts_with("OK") => {}
                    ReadOutcome::Dead => {
                        self.cluster.report_failure(&id);
                        continue 'replica;
                    }
                    _ => continue 'replica,
                }
            }
            let n = if remaining <= 1 { total } else { 1 };
            match self.read_request(&id, addr, net, line, n) {
                ReadOutcome::Reply(reply) => {
                    self.batch_backend = Some(id);
                    return self.settle_case(reply, line, remaining, total);
                }
                ReadOutcome::Dead => {
                    self.cluster.report_failure(&id);
                    continue 'replica;
                }
                ReadOutcome::Skip => continue 'replica,
            }
        }
        self.abort_batch();
        format!("ERR no replica of {net:?} can continue the batch; BATCH again once rerouted")
    }

    /// `JOIN <host:port>`: adopt an already-running `fastbn serve --fleet`
    /// process as a backend. Control-plane; answered by the front.
    fn cmd_join(&mut self, rest: &str) -> String {
        let Ok(addr) = rest.parse::<SocketAddr>() else {
            return "ERR usage: JOIN <host:port>".into();
        };
        match self.cluster.join(addr) {
            Ok(id) => format!("OK joined {id} addr={addr}"),
            Err(e) => format!("ERR {e}"),
        }
    }

    /// `HANDOFF` (no args): export this session's committed evidence as
    /// one line a peer router can replay. `HANDOFF <net> [var=state …]`:
    /// import — re-pin `<net>` on this router and replay the evidence as
    /// `USE` + one atomic `OBSERVE` + `COMMIT`. Every replay step is
    /// checked; any failure drops the pin entirely (the backend session
    /// and any staged evidence die with the conn), so a half-applied
    /// hand-off can never answer queries with partial evidence.
    fn cmd_handoff(&mut self, rest: &str) -> String {
        if rest.is_empty() {
            let Some(active) = self.active.as_ref() else {
                return "ERR no network selected (USE <net> first)".into();
            };
            let mut out = format!("OK handoff net={} evidence={}", active.net, self.committed.len());
            for (var, state) in &self.committed {
                out.push_str(&format!(" {var}={state}"));
            }
            return out;
        }
        let mut tokens = rest.split_whitespace();
        let net = tokens.next().unwrap_or("").to_string();
        let pairs: Vec<&str> = tokens.collect();
        if net.is_empty() || pairs.iter().any(|t| !t.contains('=')) {
            return "ERR usage: HANDOFF [<net> var=state ...]".into();
        }
        let use_reply = self.cmd_use(&net);
        if !use_reply.starts_with("OK") {
            return format!("ERR handoff replay failed at USE: {use_reply}");
        }
        if pairs.is_empty() {
            return format!("OK handoff applied net={net} evidence=0");
        }
        let pair_text = pairs.join(" ");
        // one OBSERVE line — the backend validates every token before
        // staging any, so a bad pair can never half-apply
        let observe = self.forward(&format!("OBSERVE {pair_text}"));
        self.mirror("OBSERVE", &pair_text, &observe);
        if !observe.starts_with("OK") {
            self.drop_pin();
            return format!("ERR handoff replay failed at OBSERVE: {observe}");
        }
        let commit = self.forward("COMMIT");
        self.mirror("COMMIT", "", &commit);
        if !commit.starts_with("OK") {
            self.drop_pin();
            return format!("ERR handoff replay failed at COMMIT: {commit}");
        }
        format!("OK handoff applied net={net} evidence={}", self.committed.len())
    }

    /// `LEARN <name> <spec> <samples> <seed>`: validated on the front,
    /// executed on the ring owners of `<name>` via control-plane
    /// connections (like `LOAD` — the session's pinned data conn, and any
    /// open batch on it, is untouched).
    fn cmd_learn(&mut self, rest: &str) -> String {
        // same grammar as the backend session (one definition, on
        // LearnSpec) — a malformed verb never costs a backend round trip
        let parsed = match crate::learn::LearnSpec::from_verb_args(rest) {
            Ok(parsed) => parsed,
            Err(e) => return format!("ERR {e}"),
        };
        let line = format!("LEARN {} {} {} {}", parsed.name, parsed.base, parsed.samples, parsed.seed);
        self.cluster.learn(&parsed.name, &parsed.to_spec(), &line)
    }

    fn cmd_use(&mut self, name: &str) -> String {
        if name.is_empty() {
            return "ERR usage: USE <net>".into();
        }
        // prefer the already-pinned backend when it is still a live
        // replica owner — a primary change alone must not hop an
        // evidence-bearing session — else pin to the first live replica
        let targets = self.cluster.read_targets(name);
        let pinned = self.active.as_ref().map(|a| a.backend.clone());
        let chosen = targets
            .iter()
            .find(|(tid, _)| pinned.as_deref() == Some(tid.as_str()))
            .or_else(|| targets.first())
            .cloned();
        let Some((id, addr)) = chosen else {
            return match self.cluster.lookup(name) {
                Lookup::Unknown => format!("ERR not loaded: {name:?} (LOAD it first)"),
                _ => format!("ERR network {name:?} has no live backend; retry once rerouted"),
            };
        };
        let same_backend = pinned.as_deref() == Some(id.as_str());
        if same_backend {
            // the pinned backend session sees the USE (or the conn dies);
            // either way its batch collection is over — mirror that
            self.abort_batch();
            let mut active = self.active.take().expect("checked above");
            let same_net = active.net == name;
            return match self.forward_use(&mut active.conn, name) {
                Ok(reply) => {
                    if reply.starts_with("OK") {
                        active.net = name.to_string();
                        // the backend keeps evidence only on a re-USE of
                        // the same net (same-model defensive re-USE);
                        // switching nets resets it — mirror both
                        if !same_net {
                            self.committed.clear();
                            self.pending.clear();
                        }
                    }
                    // an ERR reply left the backend session untouched, so
                    // the existing pin (and its evidence) survives — the
                    // single-fleet failed-USE semantics
                    self.active = Some(active);
                    reply
                }
                Err(e) => {
                    // the conn died and the old pin's state died with it
                    self.committed.clear();
                    self.pending.clear();
                    self.cluster.report_failure(&id);
                    format!("ERR backend {id} unreachable: {e}; retry USE once rerouted")
                }
            };
        }
        // different backend: build the new pin first and replace the old
        // one only on success — a failed USE keeps the current selection
        // (and, with it, any open batch on the still-pinned conn: the old
        // backend session never saw this verb)
        let mut conn = match self.cluster.connect(addr) {
            Ok(conn) => conn,
            Err(e) => {
                self.cluster.report_failure(&id);
                return format!("ERR backend {id} ({addr}) unreachable: {e}; retry USE once rerouted");
            }
        };
        match self.forward_use(&mut conn, name) {
            Ok(reply) => {
                if reply.starts_with("OK") {
                    // replacing the pin drops the old conn, and the old
                    // backend session (evidence, any open batch) dies with
                    // it — the fresh pin starts clean on both tiers
                    self.drop_pin();
                    self.active = Some(Active { net: name.to_string(), backend: id, conn });
                }
                reply
            }
            Err(e) => {
                self.cluster.report_failure(&id);
                format!("ERR backend {id} unreachable: {e}; retry USE once rerouted")
            }
        }
    }

    /// Forward `USE`, self-healing directory/backend drift: a backend
    /// that answers "not loaded" for a network the directory assigns to
    /// it (say it restarted empty behind its old address) gets a `LOAD`
    /// of the recorded spec and one retry.
    fn forward_use(&self, conn: &mut BackendConn, name: &str) -> std::io::Result<String> {
        let reply = conn.request(&format!("USE {name}"))?;
        if reply.starts_with("ERR not loaded") {
            if let Some(spec) = self.cluster.spec_of(name) {
                let load = conn.request(&format!("LOAD {spec}"))?;
                if load.starts_with("OK") {
                    return conn.request(&format!("USE {name}"));
                }
                return Ok(load);
            }
        }
        Ok(reply)
    }

    /// Forward a data-plane verb over the pinned connection, after
    /// re-checking that the pin still matches the directory — a moved or
    /// unloaded network is a clean error, never a silent reroute that
    /// would drop (or misapply) the backend session's evidence.
    fn forward(&mut self, line: &str) -> String {
        self.forward_multi(line, 1)
    }

    /// Forward expecting `n` reply lines (the final `CASE` of an n-case
    /// batch; every other verb has `n == 1`). The lines come back joined —
    /// the line server writes them out as n wire lines.
    fn forward_multi(&mut self, line: &str, n: usize) -> String {
        let Some(active) = self.active.as_mut() else {
            return "ERR no network selected (USE <net> first)".into();
        };
        match self.cluster.confirm(&active.net, &active.backend) {
            Confirm::Current => {}
            Confirm::Moved => {
                let net = active.net.clone();
                // dropping the pin closes the conn; the backend session
                // (evidence, any open batch) dies with it
                self.drop_pin();
                return format!("ERR network {net:?} moved to another backend (rebalance or failover); USE it again");
            }
            Confirm::Unloaded => {
                let net = active.net.clone();
                self.drop_pin();
                return format!("ERR network {net:?} is no longer loaded anywhere; LOAD and USE it again");
            }
        }
        match active.conn.request_lines(line, n) {
            Ok(lines) => {
                let reply = lines.join("\n");
                if reply.starts_with("ERR network") {
                    // the backend session tore its selection down (net
                    // evicted or reloaded under it) and cleared its
                    // evidence — keep the mirror in sync
                    self.committed.clear();
                    self.pending.clear();
                }
                reply
            }
            Err(e) => {
                let (net, id) = (active.net.clone(), active.backend.clone());
                self.drop_pin();
                // verified report: failover runs before we reply, so the
                // client's very next USE normally lands on a survivor
                self.cluster.report_failure(&id);
                format!("ERR backend {id} for network {net:?} is unreachable ({e}); USE the network again once rerouted")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_cluster() -> Arc<Cluster> {
        Cluster::start(ClusterConfig {
            probe_interval: Duration::from_millis(50),
            probe_timeout: Duration::from_millis(200),
            connect_timeout: Duration::from_millis(200),
            io_timeout: Duration::from_secs(1),
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn empty_cluster_refuses_work_cleanly() {
        let cluster = empty_cluster();
        assert!(cluster.load("asia").starts_with("ERR no live backends"), "{}", cluster.load("asia"));
        assert!(cluster.load("no-such-net").starts_with("ERR unknown network"));
        assert_eq!(cluster.lookup("asia"), Lookup::Unknown);
        assert_eq!(cluster.owner("asia"), None);
        assert!(cluster.replicas_of("asia").is_empty());
        assert!(cluster.read_targets("asia").is_empty());
        assert!(cluster.ping_line().contains("backends=0 alive=0 nets=0"));
        assert!(cluster.stats_line().starts_with("STATS cluster"), "{}", cluster.stats_line());
        // nothing to scrape and nothing served: an empty cluster is not
        // "partial", it is just empty
        assert!(!cluster.stats_line().contains("stats=partial"), "{}", cluster.stats_line());
        assert_eq!(cluster.nets_line(), "OK nets=0");
        assert_eq!(cluster.topo_line(), "OK backends=0");
        cluster.shutdown();
    }

    #[test]
    fn join_requires_a_live_backend() {
        let cluster = empty_cluster();
        // bind-then-drop: the port is real but nothing listens on it
        let dead = {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            listener.local_addr().unwrap()
        };
        assert!(cluster.join(dead).is_err());
        assert!(cluster.backends().is_empty());
        cluster.shutdown();
    }

    #[test]
    fn session_errors_without_a_selection() {
        let cluster = empty_cluster();
        let mut session = ClusterSession::new(Arc::clone(&cluster));
        let line = |s: &mut ClusterSession, input: &str| match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        };
        assert!(line(&mut session, "QUERY lung").starts_with("ERR no network selected"));
        assert!(line(&mut session, "OBSERVE a=b").starts_with("ERR no network selected"));
        assert!(line(&mut session, "USE asia").starts_with("ERR not loaded"));
        assert!(line(&mut session, "USE").starts_with("ERR usage: USE"));
        assert!(line(&mut session, "LOAD").starts_with("ERR usage: LOAD"));
        assert!(line(&mut session, "FROB x").starts_with("ERR unknown verb"));
        assert!(line(&mut session, "").starts_with("ERR empty request"));
        assert!(line(&mut session, "PING").starts_with("OK pong"));
        assert_eq!(session.current_net(), None);
        assert_eq!(session.handle("quit"), SessionReply::Quit);
        cluster.shutdown();
    }

    #[test]
    fn join_and_handoff_validate_before_any_io() {
        let cluster = empty_cluster();
        let mut session = ClusterSession::new(Arc::clone(&cluster));
        let line = |s: &mut ClusterSession, input: &str| match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        };
        assert!(line(&mut session, "JOIN").starts_with("ERR usage: JOIN"));
        assert!(line(&mut session, "JOIN nonsense").starts_with("ERR usage: JOIN"));
        // export needs a pinned session
        assert!(line(&mut session, "HANDOFF").starts_with("ERR no network selected"));
        // import validates token shape before touching any backend
        assert!(line(&mut session, "HANDOFF asia notapair").starts_with("ERR usage: HANDOFF"));
        // well-formed import of an unknown net fails cleanly at the USE step
        let reply = line(&mut session, "HANDOFF asia smoke=yes");
        assert!(reply.starts_with("ERR handoff replay failed at USE"), "{reply}");
        cluster.shutdown();
    }

    #[test]
    fn learn_verb_validates_before_routing() {
        let cluster = empty_cluster();
        let mut session = ClusterSession::new(Arc::clone(&cluster));
        let line = |s: &mut ClusterSession, input: &str| match s.handle(input) {
            SessionReply::Line(l) => l,
            SessionReply::Quit => "QUIT".into(),
        };
        assert!(line(&mut session, "LEARN").starts_with("ERR usage: LEARN"));
        assert!(line(&mut session, "LEARN x asia 10").starts_with("ERR usage: LEARN"));
        assert!(line(&mut session, "LEARN x asia ten 1").starts_with("ERR bad sample count"));
        assert!(line(&mut session, "LEARN x asia 0 1").starts_with("ERR learn spec sample count"));
        // well-formed but nowhere to run: refused at placement, and the
        // (expensive) learning never happened on the front tier
        assert!(line(&mut session, "LEARN x asia 100 1").starts_with("ERR no live backends"));
        // LOAD of a learn: spec also fails fast on parse errors
        assert!(cluster.load("learn:bad").starts_with("ERR learn spec"));
        cluster.shutdown();
    }

    #[test]
    fn backend_stats_lines_parse() {
        let parsed = parse_backend_stats(
            "STATS uptime_ms=12 nets=2 | asia queries=5 errors=1 qps=2.50 p50_us=120 p99_us=900 | cancer queries=0 errors=0 qps=0.00 p50_us=0 p99_us=0",
        );
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].net, "asia");
        assert_eq!(parsed[0].queries, 5);
        assert_eq!(parsed[0].errors, 1);
        assert_eq!(parsed[0].p99_us, 900);
        assert_eq!(parsed[1].net, "cancer");
        assert_eq!(parsed[1].queries, 0);
        assert!(parse_backend_stats("STATS uptime_ms=1 nets=0").is_empty());
    }

    #[test]
    fn net_agg_sums_counts_and_keeps_primary_percentiles() {
        let owners = vec!["b1".to_string(), "b0".to_string()];
        let mut agg = NetAgg::new(&owners);
        let s0 = NetStat { net: "asia".into(), queries: 4, errors: 1, qps: 2.0, p50_us: 70, p99_us: 700 };
        let s1 = NetStat { net: "asia".into(), queries: 6, errors: 0, qps: 3.0, p50_us: 90, p99_us: 900 };
        // the non-primary replica reports first: its percentiles hold only
        // until the primary's snapshot arrives; counts always sum
        agg.add(&s0, false);
        assert_eq!((agg.p50_us, agg.p99_us), (70, 700));
        agg.add(&s1, true);
        assert_eq!(agg.queries, 10);
        assert_eq!(agg.errors, 1);
        assert_eq!(agg.seen, 2);
        assert_eq!((agg.p50_us, agg.p99_us), (90, 900));
        assert_eq!(agg.primary, "b1");
        assert_eq!(agg.total, 2);
    }
}
