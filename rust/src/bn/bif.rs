//! Parser and writer for the BIF (Bayesian Interchange Format) dialect used
//! by the bnlearn repository and UnBBayes — the format the paper's six
//! evaluation networks are distributed in.
//!
//! Supported constructs:
//!
//! ```text
//! network <name> { ... }                      // properties ignored
//! variable <name> {
//!   type discrete [ <k> ] { s1, s2, ... };
//! }
//! probability ( <child> ) { table p...; }
//! probability ( <child> | p1, p2 ) {
//!   table p...;                               // row-major, child fastest
//!   // or per-row entries:
//!   (s_a, s_b) p1, p2, ...;
//!   default p1, p2, ...;                      // fills unlisted rows
//! }
//! ```

use std::collections::HashMap;

use crate::bn::cpt::Cpt;
use crate::bn::network::Network;
use crate::bn::variable::Variable;
use crate::{Error, Result};

// ---------------------------------------------------------------- lexer --

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Number(f64),
    Punct(char),
}

struct Lexer {
    toks: Vec<(Tok, usize)>, // (token, line)
    pos: usize,
}

fn lex(src: &str) -> Result<Vec<(Tok, usize)>> {
    let mut toks = Vec::new();
    let mut chars = src.char_indices().peekable();
    let mut line = 1usize;
    let bytes = src.as_bytes();
    while let Some((i, c)) = chars.next() {
        match c {
            '\n' => line += 1,
            c if c.is_whitespace() => {}
            '/' if matches!(chars.peek(), Some((_, '/'))) => {
                // line comment
                for (_, c2) in chars.by_ref() {
                    if c2 == '\n' {
                        line += 1;
                        break;
                    }
                }
            }
            '/' if matches!(chars.peek(), Some((_, '*'))) => {
                chars.next();
                let mut prev = ' ';
                for (_, c2) in chars.by_ref() {
                    if c2 == '\n' {
                        line += 1;
                    }
                    if prev == '*' && c2 == '/' {
                        break;
                    }
                    prev = c2;
                }
            }
            '{' | '}' | '(' | ')' | '[' | ']' | ',' | ';' | '|' | '=' => toks.push((Tok::Punct(c), line)),
            '"' => {
                // quoted identifier / property value
                let start = i + 1;
                let mut end = start;
                for (j, c2) in chars.by_ref() {
                    if c2 == '"' {
                        end = j;
                        break;
                    }
                    if c2 == '\n' {
                        line += 1;
                    }
                }
                toks.push((Tok::Ident(src[start..end].to_string()), line));
            }
            c if c.is_ascii_digit() || c == '-' || c == '+' || c == '.' => {
                let start = i;
                let mut end = i + c.len_utf8();
                while let Some(&(j, c2)) = chars.peek() {
                    if c2.is_ascii_digit() || c2 == '.' || c2 == 'e' || c2 == 'E' || c2 == '-' || c2 == '+' {
                        // only allow -/+ after an exponent marker
                        if (c2 == '-' || c2 == '+') && !matches!(bytes[j - 1], b'e' | b'E') {
                            break;
                        }
                        end = j + c2.len_utf8();
                        chars.next();
                    } else {
                        break;
                    }
                }
                let text = &src[start..end];
                let n: f64 = text
                    .parse()
                    .map_err(|_| Error::Parse { line, msg: format!("bad number {text:?}") })?;
                toks.push((Tok::Number(n), line));
            }
            c if c.is_alphanumeric() || c == '_' => {
                let start = i;
                let mut end = i + c.len_utf8();
                while let Some(&(j, c2)) = chars.peek() {
                    if c2.is_alphanumeric() || c2 == '_' || c2 == '-' || c2 == '.' {
                        end = j + c2.len_utf8();
                        chars.next();
                    } else {
                        break;
                    }
                }
                toks.push((Tok::Ident(src[start..end].to_string()), line));
            }
            other => {
                return Err(Error::Parse { line, msg: format!("unexpected character {other:?}") });
            }
        }
    }
    Ok(toks)
}

impl Lexer {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _)| t)
    }

    fn line(&self) -> usize {
        self.toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|&(_, l)| l)
            .unwrap_or(0)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self
            .toks
            .get(self.pos)
            .cloned()
            .ok_or_else(|| Error::Parse { line: self.line(), msg: "unexpected end of input".into() })?;
        self.pos += 1;
        Ok(t.0)
    }

    fn expect_punct(&mut self, c: char) -> Result<()> {
        match self.next()? {
            Tok::Punct(p) if p == c => Ok(()),
            other => Err(Error::Parse { line: self.line(), msg: format!("expected {c:?}, found {other:?}") }),
        }
    }

    fn expect_ident(&mut self) -> Result<String> {
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            other => Err(Error::Parse { line: self.line(), msg: format!("expected identifier, found {other:?}") }),
        }
    }

    fn expect_number(&mut self) -> Result<f64> {
        match self.next()? {
            Tok::Number(n) => Ok(n),
            Tok::Ident(s) => s
                .parse()
                .map_err(|_| Error::Parse { line: self.line(), msg: format!("expected number, found {s:?}") }),
            other => Err(Error::Parse { line: self.line(), msg: format!("expected number, found {other:?}") }),
        }
    }

    /// Skip a balanced `{ ... }` block (for ignored properties).
    fn skip_block(&mut self) -> Result<()> {
        self.expect_punct('{')?;
        let mut depth = 1usize;
        while depth > 0 {
            match self.next()? {
                Tok::Punct('{') => depth += 1,
                Tok::Punct('}') => depth -= 1,
                _ => {}
            }
        }
        Ok(())
    }
}

// --------------------------------------------------------------- parser --

/// Parse BIF text into a [`Network`].
pub fn parse(src: &str) -> Result<Network> {
    let toks = lex(src)?;
    let mut lx = Lexer { toks, pos: 0 };

    let mut net_name = String::from("network");
    let mut vars: Vec<Variable> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    // (child, parents, entries) — resolved to Cpts once all cards are known.
    struct RawProb {
        child: usize,
        parents: Vec<usize>,
        body: ProbBody,
        line: usize,
    }
    enum ProbBody {
        Table(Vec<f64>),
        Rows { rows: Vec<(Vec<String>, Vec<f64>)>, default: Option<Vec<f64>> },
    }
    let mut probs: Vec<RawProb> = Vec::new();

    while lx.peek().is_some() {
        let kw = lx.expect_ident()?;
        match kw.as_str() {
            "network" => {
                net_name = lx.expect_ident()?;
                lx.skip_block()?;
            }
            "variable" => {
                let name = lx.expect_ident()?;
                lx.expect_punct('{')?;
                let mut states: Vec<String> = Vec::new();
                loop {
                    match lx.next()? {
                        Tok::Punct('}') => break,
                        Tok::Ident(s) if s == "type" => {
                            let kind = lx.expect_ident()?;
                            if kind != "discrete" {
                                return Err(Error::Parse {
                                    line: lx.line(),
                                    msg: format!("unsupported variable type {kind:?}"),
                                });
                            }
                            lx.expect_punct('[')?;
                            let k = lx.expect_number()? as usize;
                            lx.expect_punct(']')?;
                            lx.expect_punct('{')?;
                            loop {
                                match lx.next()? {
                                    Tok::Punct('}') => break,
                                    Tok::Punct(',') => {}
                                    Tok::Ident(s) => states.push(s),
                                    Tok::Number(n) => states.push(format!("{n}")),
                                    other => {
                                        return Err(Error::Parse {
                                            line: lx.line(),
                                            msg: format!("bad state name {other:?}"),
                                        })
                                    }
                                }
                            }
                            lx.expect_punct(';')?;
                            if states.len() != k {
                                return Err(Error::Parse {
                                    line: lx.line(),
                                    msg: format!("variable {name}: declared {k} states, listed {}", states.len()),
                                });
                            }
                        }
                        Tok::Ident(s) if s == "property" => {
                            // skip to ';'
                            while lx.next()? != Tok::Punct(';') {}
                        }
                        other => {
                            return Err(Error::Parse { line: lx.line(), msg: format!("unexpected {other:?} in variable") })
                        }
                    }
                }
                if index.insert(name.clone(), vars.len()).is_some() {
                    return Err(Error::Parse { line: lx.line(), msg: format!("duplicate variable {name:?}") });
                }
                vars.push(Variable { name, states });
            }
            "probability" => {
                let line = lx.line();
                lx.expect_punct('(')?;
                let child_name = lx.expect_ident()?;
                let child = *index
                    .get(&child_name)
                    .ok_or_else(|| Error::Parse { line, msg: format!("unknown variable {child_name:?}") })?;
                let mut parents: Vec<usize> = Vec::new();
                match lx.next()? {
                    Tok::Punct(')') => {}
                    Tok::Punct('|') => loop {
                        let p = lx.expect_ident()?;
                        let pid = *index
                            .get(&p)
                            .ok_or_else(|| Error::Parse { line, msg: format!("unknown parent {p:?}") })?;
                        parents.push(pid);
                        match lx.next()? {
                            Tok::Punct(',') => {}
                            Tok::Punct(')') => break,
                            other => {
                                return Err(Error::Parse { line, msg: format!("expected ',' or ')', found {other:?}") })
                            }
                        }
                    },
                    other => return Err(Error::Parse { line, msg: format!("expected '|' or ')', found {other:?}") }),
                }
                lx.expect_punct('{')?;
                let mut table: Option<Vec<f64>> = None;
                let mut rows: Vec<(Vec<String>, Vec<f64>)> = Vec::new();
                let mut default: Option<Vec<f64>> = None;
                loop {
                    match lx.next()? {
                        Tok::Punct('}') => break,
                        Tok::Ident(s) if s == "table" => {
                            let mut v = Vec::new();
                            loop {
                                match lx.next()? {
                                    Tok::Punct(';') => break,
                                    Tok::Punct(',') => {}
                                    Tok::Number(n) => v.push(n),
                                    other => {
                                        return Err(Error::Parse { line, msg: format!("bad table entry {other:?}") })
                                    }
                                }
                            }
                            table = Some(v);
                        }
                        Tok::Ident(s) if s == "default" => {
                            let mut v = Vec::new();
                            loop {
                                match lx.next()? {
                                    Tok::Punct(';') => break,
                                    Tok::Punct(',') => {}
                                    Tok::Number(n) => v.push(n),
                                    other => {
                                        return Err(Error::Parse { line, msg: format!("bad default entry {other:?}") })
                                    }
                                }
                            }
                            default = Some(v);
                        }
                        Tok::Punct('(') => {
                            let mut config: Vec<String> = Vec::new();
                            loop {
                                match lx.next()? {
                                    Tok::Punct(')') => break,
                                    Tok::Punct(',') => {}
                                    Tok::Ident(s) => config.push(s),
                                    Tok::Number(n) => config.push(format!("{n}")),
                                    other => {
                                        return Err(Error::Parse { line, msg: format!("bad row config {other:?}") })
                                    }
                                }
                            }
                            let mut v = Vec::new();
                            loop {
                                match lx.next()? {
                                    Tok::Punct(';') => break,
                                    Tok::Punct(',') => {}
                                    Tok::Number(n) => v.push(n),
                                    other => {
                                        return Err(Error::Parse { line, msg: format!("bad row entry {other:?}") })
                                    }
                                }
                            }
                            rows.push((config, v));
                        }
                        Tok::Ident(s) if s == "property" => {
                            while lx.next()? != Tok::Punct(';') {}
                        }
                        other => {
                            return Err(Error::Parse { line, msg: format!("unexpected {other:?} in probability") })
                        }
                    }
                }
                let body = if let Some(t) = table {
                    ProbBody::Table(t)
                } else {
                    ProbBody::Rows { rows, default }
                };
                probs.push(RawProb { child, parents, body, line });
            }
            other => {
                return Err(Error::Parse { line: lx.line(), msg: format!("unexpected top-level keyword {other:?}") })
            }
        }
    }

    // Resolve probability blocks into CPTs.
    let cards: Vec<usize> = vars.iter().map(|v| v.card()).collect();
    let mut cpts: Vec<Option<Cpt>> = (0..vars.len()).map(|_| None).collect();
    for rp in probs {
        let child_card = cards[rp.child];
        let n_rows: usize = rp.parents.iter().map(|&p| cards[p]).product();
        let probs_flat: Vec<f64> = match rp.body {
            ProbBody::Table(t) => t,
            ProbBody::Rows { rows, default } => {
                let mut flat = vec![f64::NAN; n_rows * child_card];
                if let Some(d) = &default {
                    if d.len() != child_card {
                        return Err(Error::Parse {
                            line: rp.line,
                            msg: format!("default row has {} entries, child has {} states", d.len(), child_card),
                        });
                    }
                    for r in 0..n_rows {
                        flat[r * child_card..(r + 1) * child_card].copy_from_slice(d);
                    }
                }
                for (config, v) in rows {
                    if config.len() != rp.parents.len() {
                        return Err(Error::Parse {
                            line: rp.line,
                            msg: format!("row lists {} parent states, expected {}", config.len(), rp.parents.len()),
                        });
                    }
                    if v.len() != child_card {
                        return Err(Error::Parse {
                            line: rp.line,
                            msg: format!("row has {} entries, child has {} states", v.len(), child_card),
                        });
                    }
                    let mut row = 0usize;
                    for (i, &p) in rp.parents.iter().enumerate() {
                        let s = vars[p].state_index(&config[i]).ok_or_else(|| Error::Parse {
                            line: rp.line,
                            msg: format!("unknown state {:?} of parent {:?}", config[i], vars[p].name),
                        })?;
                        row = row * cards[p] + s;
                    }
                    flat[row * child_card..(row + 1) * child_card].copy_from_slice(&v);
                }
                if flat.iter().any(|p| p.is_nan()) {
                    return Err(Error::Parse {
                        line: rp.line,
                        msg: format!("probability block for {:?} leaves rows unspecified", vars[rp.child].name),
                    });
                }
                flat
            }
        };
        let cpt = Cpt::new(rp.child, rp.parents, probs_flat, &cards).map_err(|e| Error::Parse {
            line: rp.line,
            msg: format!("{e}"),
        })?;
        if cpts[rp.child].is_some() {
            return Err(Error::Parse {
                line: rp.line,
                msg: format!("duplicate probability block for {:?}", vars[rp.child].name),
            });
        }
        cpts[rp.child] = Some(cpt);
    }
    let cpts: Vec<Cpt> = cpts
        .into_iter()
        .enumerate()
        .map(|(v, c)| c.ok_or_else(|| Error::InvalidNetwork(format!("no probability block for {:?}", vars[v].name))))
        .collect::<Result<_>>()?;

    Network::new(net_name, vars, cpts)
}

/// Read a network from a `.bif` file.
pub fn parse_file(path: &std::path::Path) -> Result<Network> {
    let src = std::fs::read_to_string(path)?;
    parse(&src)
}

// --------------------------------------------------------------- writer --

/// Serialize a network to BIF text (table form).
pub fn write(net: &Network) -> String {
    let mut out = String::new();
    out.push_str(&format!("network {} {{\n}}\n", net.name));
    for v in &net.vars {
        out.push_str(&format!("variable {} {{\n  type discrete [ {} ] {{ ", v.name, v.card()));
        out.push_str(&v.states.join(", "));
        out.push_str(" };\n}\n");
    }
    for cpt in &net.cpts {
        if cpt.parents.is_empty() {
            out.push_str(&format!("probability ( {} ) {{\n  table ", net.vars[cpt.child].name));
        } else {
            let ps: Vec<&str> = cpt.parents.iter().map(|&p| net.vars[p].name.as_str()).collect();
            out.push_str(&format!(
                "probability ( {} | {} ) {{\n  table ",
                net.vars[cpt.child].name,
                ps.join(", ")
            ));
        }
        let entries: Vec<String> = cpt.probs.iter().map(|p| format!("{p}")).collect();
        out.push_str(&entries.join(", "));
        out.push_str(";\n}\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"
network mini {
}
variable rain {
  type discrete [ 2 ] { yes, no };
}
variable grass {
  type discrete [ 2 ] { wet, dry };
}
probability ( rain ) {
  table 0.2, 0.8;
}
probability ( grass | rain ) {
  (yes) 0.9, 0.1;
  (no) 0.1, 0.9;
}
"#;

    #[test]
    fn parse_mini_rowform() {
        let net = parse(MINI).unwrap();
        assert_eq!(net.name, "mini");
        assert_eq!(net.n(), 2);
        let g = net.var_id("grass").unwrap();
        assert_eq!(net.parents(g), &[net.var_id("rain").unwrap()]);
        let cards = net.cards();
        assert_eq!(net.cpts[g].row(&[0], &cards), &[0.9, 0.1]);
        assert_eq!(net.cpts[g].row(&[1], &cards), &[0.1, 0.9]);
    }

    #[test]
    fn parse_table_form() {
        let src = r#"
network t { }
variable a { type discrete [ 3 ] { x, y, z }; }
variable b { type discrete [ 2 ] { t, f }; }
probability ( a ) { table 0.2, 0.3, 0.5; }
probability ( b | a ) { table 0.1, 0.9, 0.4, 0.6, 0.7, 0.3; }
"#;
        let net = parse(src).unwrap();
        let cards = net.cards();
        assert_eq!(net.cpts[1].row(&[2], &cards), &[0.7, 0.3]);
    }

    #[test]
    fn parse_default_rows() {
        let src = r#"
network d { }
variable a { type discrete [ 2 ] { t, f }; }
variable b { type discrete [ 2 ] { t, f }; }
probability ( a ) { table 0.5, 0.5; }
probability ( b | a ) {
  default 0.5, 0.5;
  (t) 0.99, 0.01;
}
"#;
        let net = parse(src).unwrap();
        let cards = net.cards();
        assert_eq!(net.cpts[1].row(&[0], &cards), &[0.99, 0.01]);
        assert_eq!(net.cpts[1].row(&[1], &cards), &[0.5, 0.5]);
    }

    #[test]
    fn comments_and_properties_ignored() {
        let src = r#"
// top comment
network c { property "version 1"; }
variable a {
  property "position = (10, 20)";
  type discrete [ 2 ] { t, f }; /* inline */
}
probability ( a ) { table 0.3, 0.7; }
"#;
        let net = parse(src).unwrap();
        assert_eq!(net.n(), 1);
        assert_eq!(net.cpts[0].probs, vec![0.3, 0.7]);
    }

    #[test]
    fn roundtrip_write_parse() {
        let net = parse(MINI).unwrap();
        let text = write(&net);
        let net2 = parse(&text).unwrap();
        assert_eq!(net.n(), net2.n());
        for v in 0..net.n() {
            assert_eq!(net.vars[v], net2.vars[v]);
            assert_eq!(net.cpts[v].parents, net2.cpts[v].parents);
            for (a, b) in net.cpts[v].probs.iter().zip(&net2.cpts[v].probs) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn errors_have_lines() {
        let src = "network x { }\nvariable a { type discrete [ 2 ] { t, f }; }\nprobability ( zzz ) { table 1; }";
        match parse(src) {
            Err(Error::Parse { line, .. }) => assert!(line >= 3),
            other => panic!("expected parse error, got {other:?}"),
        }
    }

    #[test]
    fn missing_cpt_rejected() {
        let src = "network x { }\nvariable a { type discrete [ 2 ] { t, f }; }";
        assert!(parse(src).is_err());
    }

    #[test]
    fn scientific_notation_numbers() {
        let src = r#"
network s { }
variable a { type discrete [ 2 ] { t, f }; }
probability ( a ) { table 1e-1, 9.0E-1; }
"#;
        let net = parse(src).unwrap();
        assert!((net.cpts[0].probs[0] - 0.1).abs() < 1e-12);
        assert!((net.cpts[0].probs[1] - 0.9).abs() < 1e-12);
    }
}
