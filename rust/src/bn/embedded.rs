//! Classic textbook networks embedded as BIF text.
//!
//! The paper's six evaluation networks come from the bnlearn repository,
//! which is not reachable in this offline environment; these small,
//! well-known networks (with their published CPTs) anchor correctness:
//! JT posteriors on them are checked against hand-derived values and the
//! brute-force enumeration oracle. Structural analogs of the six paper
//! networks are produced by [`crate::bn::netgen`].

use crate::bn::bif;
use crate::bn::network::Network;

/// The "Asia" / "chest clinic" network (Lauritzen & Spiegelhalter 1988):
/// 8 binary variables, the canonical JT example.
pub const ASIA_BIF: &str = r#"
network asia {
}
variable asia {
  type discrete [ 2 ] { yes, no };
}
variable tub {
  type discrete [ 2 ] { yes, no };
}
variable smoke {
  type discrete [ 2 ] { yes, no };
}
variable lung {
  type discrete [ 2 ] { yes, no };
}
variable bronc {
  type discrete [ 2 ] { yes, no };
}
variable either {
  type discrete [ 2 ] { yes, no };
}
variable xray {
  type discrete [ 2 ] { yes, no };
}
variable dysp {
  type discrete [ 2 ] { yes, no };
}
probability ( asia ) {
  table 0.01, 0.99;
}
probability ( tub | asia ) {
  (yes) 0.05, 0.95;
  (no) 0.01, 0.99;
}
probability ( smoke ) {
  table 0.5, 0.5;
}
probability ( lung | smoke ) {
  (yes) 0.1, 0.9;
  (no) 0.01, 0.99;
}
probability ( bronc | smoke ) {
  (yes) 0.6, 0.4;
  (no) 0.3, 0.7;
}
probability ( either | lung, tub ) {
  (yes, yes) 1.0, 0.0;
  (yes, no) 1.0, 0.0;
  (no, yes) 1.0, 0.0;
  (no, no) 0.0, 1.0;
}
probability ( xray | either ) {
  (yes) 0.98, 0.02;
  (no) 0.05, 0.95;
}
probability ( dysp | bronc, either ) {
  (yes, yes) 0.9, 0.1;
  (yes, no) 0.8, 0.2;
  (no, yes) 0.7, 0.3;
  (no, no) 0.1, 0.9;
}
"#;

/// The "Cancer" network (Korb & Nicholson): 5 binary variables.
pub const CANCER_BIF: &str = r#"
network cancer {
}
variable Pollution {
  type discrete [ 2 ] { low, high };
}
variable Smoker {
  type discrete [ 2 ] { True, False };
}
variable Cancer {
  type discrete [ 2 ] { True, False };
}
variable Xray {
  type discrete [ 2 ] { positive, negative };
}
variable Dyspnoea {
  type discrete [ 2 ] { True, False };
}
probability ( Pollution ) {
  table 0.9, 0.1;
}
probability ( Smoker ) {
  table 0.3, 0.7;
}
probability ( Cancer | Pollution, Smoker ) {
  (low, True) 0.03, 0.97;
  (low, False) 0.001, 0.999;
  (high, True) 0.05, 0.95;
  (high, False) 0.02, 0.98;
}
probability ( Xray | Cancer ) {
  (True) 0.9, 0.1;
  (False) 0.2, 0.8;
}
probability ( Dyspnoea | Cancer ) {
  (True) 0.65, 0.35;
  (False) 0.3, 0.7;
}
"#;

/// The "Sprinkler" network (Pearl): 4 binary variables, a diamond —
/// the smallest network whose moral graph is not already triangulated.
pub const SPRINKLER_BIF: &str = r#"
network sprinkler {
}
variable cloudy {
  type discrete [ 2 ] { yes, no };
}
variable sprinkler {
  type discrete [ 2 ] { on, off };
}
variable rain {
  type discrete [ 2 ] { yes, no };
}
variable wetgrass {
  type discrete [ 2 ] { yes, no };
}
probability ( cloudy ) {
  table 0.5, 0.5;
}
probability ( sprinkler | cloudy ) {
  (yes) 0.1, 0.9;
  (no) 0.5, 0.5;
}
probability ( rain | cloudy ) {
  (yes) 0.8, 0.2;
  (no) 0.2, 0.8;
}
probability ( wetgrass | sprinkler, rain ) {
  (on, yes) 0.99, 0.01;
  (on, no) 0.9, 0.1;
  (off, yes) 0.9, 0.1;
  (off, no) 0.0, 1.0;
}
"#;

/// Parse the Asia network.
pub fn asia() -> Network {
    bif::parse(ASIA_BIF).expect("embedded asia BIF must parse")
}

/// Parse the Cancer network.
pub fn cancer() -> Network {
    bif::parse(CANCER_BIF).expect("embedded cancer BIF must parse")
}

/// Parse the Sprinkler network.
pub fn sprinkler() -> Network {
    bif::parse(SPRINKLER_BIF).expect("embedded sprinkler BIF must parse")
}

/// A 12-node mixed-cardinality network (cards 2–4), generated
/// deterministically — exercises non-binary paths in tests and examples.
pub fn mixed12() -> Network {
    use crate::bn::netgen::NetSpec;
    NetSpec {
        name: "mixed12".into(),
        nodes: 12,
        arcs: 16,
        max_parents: 3,
        card_choices: vec![(2, 0.5), (3, 0.3), (4, 0.2)],
        locality: 6,
        max_table: 1 << 12,
        alpha: 1.0,
        seed: 0xA51A,
    }
    .generate()
}

/// Look an embedded network up by name.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "asia" => Some(asia()),
        "cancer" => Some(cancer()),
        "sprinkler" => Some(sprinkler()),
        "mixed12" => Some(mixed12()),
        _ => None,
    }
}

/// Names of all embedded networks.
pub const NAMES: &[&str] = &["asia", "cancer", "sprinkler", "mixed12"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asia_parses_with_expected_shape() {
        let net = asia();
        assert_eq!(net.n(), 8);
        assert_eq!(net.n_arcs(), 8);
        // bnlearn reports 18 independent parameters for asia
        assert_eq!(net.n_params(), 18);
    }

    #[test]
    fn cancer_parses() {
        let net = cancer();
        assert_eq!(net.n(), 5);
        assert_eq!(net.n_arcs(), 4);
        assert_eq!(net.n_params(), 10);
    }

    #[test]
    fn sprinkler_parses() {
        let net = sprinkler();
        assert_eq!(net.n(), 4);
        assert_eq!(net.n_arcs(), 4);
    }

    #[test]
    fn mixed12_is_valid_and_deterministic() {
        let a = mixed12();
        let b = mixed12();
        assert_eq!(a.n(), 12);
        a.validate().unwrap();
        for v in 0..a.n() {
            assert_eq!(a.cpts[v].probs, b.cpts[v].probs);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for name in NAMES {
            assert!(by_name(name).is_some(), "missing embedded net {name}");
        }
        assert!(by_name("nope").is_none());
    }
}
