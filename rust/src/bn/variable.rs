//! Discrete random variables.

/// A discrete random variable: a name plus an ordered, named state space.
///
/// Variables are referenced everywhere else by their index (`VarId`) in the
/// owning [`crate::bn::Network`]; the struct itself carries only metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variable {
    /// Unique (within a network) variable name.
    pub name: String,
    /// Ordered state names; `states.len()` is the cardinality.
    pub states: Vec<String>,
}

/// Index of a variable within its [`crate::bn::Network`].
pub type VarId = usize;

impl Variable {
    /// Create a variable from a name and state names.
    pub fn new(name: impl Into<String>, states: &[&str]) -> Self {
        Variable {
            name: name.into(),
            states: states.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Create a variable with anonymous states `s0..s{card-1}`.
    pub fn with_card(name: impl Into<String>, card: usize) -> Self {
        assert!(card >= 1, "a variable needs at least one state");
        Variable {
            name: name.into(),
            states: (0..card).map(|i| format!("s{i}")).collect(),
        }
    }

    /// Number of states.
    #[inline]
    pub fn card(&self) -> usize {
        self.states.len()
    }

    /// Index of a state by name.
    pub fn state_index(&self, state: &str) -> Option<usize> {
        self.states.iter().position(|s| s == state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn card_and_state_lookup() {
        let v = Variable::new("smoke", &["yes", "no"]);
        assert_eq!(v.card(), 2);
        assert_eq!(v.state_index("no"), Some(1));
        assert_eq!(v.state_index("maybe"), None);
    }

    #[test]
    fn with_card_names_states() {
        let v = Variable::with_card("x", 3);
        assert_eq!(v.states, vec!["s0", "s1", "s2"]);
    }

    #[test]
    #[should_panic]
    fn zero_card_panics() {
        Variable::with_card("x", 0);
    }
}
