//! Forward (ancestral) sampling from a Bayesian network.
//!
//! Used by the coordinator's test-case generator: the paper draws evidence
//! for each test case from the network itself ("randomly generated 2,000
//! test cases, each with 20% of the observed variables"); sampling the
//! joint guarantees the evidence has non-zero probability.

use crate::bn::network::Network;
use crate::rng::Rng;

/// One ancestral pass: draw every variable in `order` into `assignment`,
/// reusing `config` as the parent-configuration scratch. The single
/// definition both samplers share — their RNG streams are identical by
/// construction, not by test pin alone.
fn draw_row(
    net: &Network,
    order: &[usize],
    cards: &[usize],
    rng: &mut Rng,
    assignment: &mut [usize],
    config: &mut Vec<usize>,
) {
    for &v in order {
        let cpt = &net.cpts[v];
        config.clear();
        config.extend(cpt.parents.iter().map(|&p| assignment[p]));
        assignment[v] = rng.categorical(cpt.row(config, cards));
    }
}

/// One likelihood-weighting pass: walk `order` like [`forward_sample`],
/// but **clamp** every variable with an observation in `obs` (dense,
/// indexed by variable id) to its observed state and multiply the
/// returned weight by the CPT probability of that state given the drawn
/// parents. Unobserved variables are sampled exactly as in `draw_row`.
/// Returns the sample's importance weight `P(e_clamped | parents)`; a
/// zero weight short-circuits the walk (the sample contributes nothing).
pub fn draw_weighted_row(
    net: &Network,
    order: &[usize],
    cards: &[usize],
    obs: &[Option<usize>],
    rng: &mut Rng,
    assignment: &mut [usize],
    config: &mut Vec<usize>,
) -> f64 {
    let mut weight = 1.0f64;
    for &v in order {
        let cpt = &net.cpts[v];
        config.clear();
        config.extend(cpt.parents.iter().map(|&p| assignment[p]));
        let row = cpt.row(config, cards);
        match obs[v] {
            Some(s) => {
                assignment[v] = s;
                weight *= row[s];
                if weight == 0.0 {
                    return 0.0;
                }
            }
            None => assignment[v] = rng.categorical(row),
        }
    }
    weight
}

/// Draw one complete assignment (state index per variable) via ancestral
/// sampling in topological order.
pub fn forward_sample(net: &Network, rng: &mut Rng) -> Vec<usize> {
    let order = net.topo_order().expect("validated networks are acyclic");
    let cards = net.cards();
    let mut assignment = vec![usize::MAX; net.n()];
    let mut config = Vec::new();
    draw_row(net, &order, &cards, rng, &mut assignment, &mut config);
    assignment
}

/// Draw `n` samples.
pub fn forward_samples(net: &Network, rng: &mut Rng, n: usize) -> Vec<Vec<usize>> {
    (0..n).map(|_| forward_sample(net, rng)).collect()
}

/// Draw `n` samples straight into **column-major** storage
/// (`cols[v][r]` = row `r`'s state of variable `v`) — the layout
/// [`crate::learn::Dataset`] wants, produced without materializing the
/// row-major `Vec<Vec<usize>>` intermediate first (at learning-scale
/// sample counts that copy dominates generation). The topological order,
/// cardinalities, and scratch row are hoisted out of the loop, so the
/// per-row cost is the categorical draws alone.
///
/// Draws the **same stream** as [`forward_samples`]: one categorical draw
/// per variable in topological order per row, so the two samplers are
/// interchangeable experiment-for-experiment.
pub fn forward_samples_columns(net: &Network, rng: &mut Rng, n: usize) -> Vec<Vec<u32>> {
    let order = net.topo_order().expect("validated networks are acyclic");
    let cards = net.cards();
    let mut cols: Vec<Vec<u32>> = (0..net.n()).map(|_| Vec::with_capacity(n)).collect();
    // one scratch row: parents must be drawn before children, so a row is
    // assembled variable-by-variable and then scattered to the columns
    let mut assignment = vec![usize::MAX; net.n()];
    let mut config = Vec::new();
    for _ in 0..n {
        draw_row(net, &order, &cards, rng, &mut assignment, &mut config);
        for (v, col) in cols.iter_mut().enumerate() {
            col.push(assignment[v] as u32);
        }
    }
    cols
}

/// Monte-Carlo estimate of a marginal P(v = s) — a slow cross-check used in
/// tests to validate exact inference from an independent direction.
pub fn mc_marginal(net: &Network, v: usize, s: usize, n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut hits = 0usize;
    for _ in 0..n {
        if forward_sample(net, &mut rng)[v] == s {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn samples_are_complete_and_in_range() {
        let net = embedded::asia();
        let mut rng = Rng::new(1);
        for s in forward_samples(&net, &mut rng, 100) {
            assert_eq!(s.len(), net.n());
            for (v, &st) in s.iter().enumerate() {
                assert!(st < net.card(v));
            }
        }
    }

    #[test]
    fn column_major_sampler_draws_the_same_stream() {
        let net = embedded::asia();
        let mut rng_rows = Rng::new(77);
        let rows = forward_samples(&net, &mut rng_rows, 64);
        let mut rng_cols = Rng::new(77);
        let cols = forward_samples_columns(&net, &mut rng_cols, 64);
        assert_eq!(cols.len(), net.n());
        for (v, col) in cols.iter().enumerate() {
            assert_eq!(col.len(), 64);
            for (r, &s) in col.iter().enumerate() {
                assert_eq!(s as usize, rows[r][v], "row {r} var {v}");
            }
        }
        // and the generators are left in identical states
        assert_eq!(rng_rows.next_u64(), rng_cols.next_u64());
    }

    #[test]
    fn weighted_row_without_observations_matches_forward_sample() {
        let net = embedded::asia();
        let order = net.topo_order().unwrap();
        let cards = net.cards();
        let obs = vec![None; net.n()];
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let mut row = vec![usize::MAX; net.n()];
        let mut config = Vec::new();
        for _ in 0..32 {
            let w = draw_weighted_row(&net, &order, &cards, &obs, &mut rng_b, &mut row, &mut config);
            assert_eq!(w, 1.0);
            assert_eq!(row, forward_sample(&net, &mut rng_a));
        }
    }

    #[test]
    fn weighted_row_clamps_observations_and_weights_them() {
        // clamp the root "smoke": the weight is exactly P(smoke=yes) = 0.5
        // on every draw, and the assignment always carries the clamp
        let net = embedded::asia();
        let order = net.topo_order().unwrap();
        let cards = net.cards();
        let smoke = net.var_id("smoke").unwrap();
        let mut obs = vec![None; net.n()];
        obs[smoke] = Some(0);
        let mut rng = Rng::new(9);
        let mut row = vec![usize::MAX; net.n()];
        let mut config = Vec::new();
        for _ in 0..32 {
            let w = draw_weighted_row(&net, &order, &cards, &obs, &mut rng, &mut row, &mut config);
            assert!((w - 0.5).abs() < 1e-12, "weight {w}");
            assert_eq!(row[smoke], 0);
        }
    }

    #[test]
    fn mc_marginal_matches_root_prior() {
        let net = embedded::asia();
        let a = net.var_id("asia").unwrap();
        let p = mc_marginal(&net, a, 0, 200_000, 42);
        assert!((p - 0.01).abs() < 0.002, "P(asia=yes) ~ 0.01, got {p}");
    }

    #[test]
    fn mc_marginal_matches_derived_value() {
        // P(lung=yes) = 0.5*0.1 + 0.5*0.01 = 0.055
        let net = embedded::asia();
        let lung = net.var_id("lung").unwrap();
        let p = mc_marginal(&net, lung, 0, 200_000, 43);
        assert!((p - 0.055).abs() < 0.004, "P(lung=yes) ~ 0.055, got {p}");
    }
}
