//! Forward (ancestral) sampling from a Bayesian network.
//!
//! Used by the coordinator's test-case generator: the paper draws evidence
//! for each test case from the network itself ("randomly generated 2,000
//! test cases, each with 20% of the observed variables"); sampling the
//! joint guarantees the evidence has non-zero probability.

use crate::bn::network::Network;
use crate::rng::Rng;

/// Draw one complete assignment (state index per variable) via ancestral
/// sampling in topological order.
pub fn forward_sample(net: &Network, rng: &mut Rng) -> Vec<usize> {
    let order = net.topo_order().expect("validated networks are acyclic");
    let cards = net.cards();
    let mut assignment = vec![usize::MAX; net.n()];
    for &v in &order {
        let cpt = &net.cpts[v];
        let config: Vec<usize> = cpt.parents.iter().map(|&p| assignment[p]).collect();
        let row = cpt.row(&config, &cards);
        assignment[v] = rng.categorical(row);
    }
    assignment
}

/// Draw `n` samples.
pub fn forward_samples(net: &Network, rng: &mut Rng, n: usize) -> Vec<Vec<usize>> {
    (0..n).map(|_| forward_sample(net, rng)).collect()
}

/// Monte-Carlo estimate of a marginal P(v = s) — a slow cross-check used in
/// tests to validate exact inference from an independent direction.
pub fn mc_marginal(net: &Network, v: usize, s: usize, n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut hits = 0usize;
    for _ in 0..n {
        if forward_sample(net, &mut rng)[v] == s {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn samples_are_complete_and_in_range() {
        let net = embedded::asia();
        let mut rng = Rng::new(1);
        for s in forward_samples(&net, &mut rng, 100) {
            assert_eq!(s.len(), net.n());
            for (v, &st) in s.iter().enumerate() {
                assert!(st < net.card(v));
            }
        }
    }

    #[test]
    fn mc_marginal_matches_root_prior() {
        let net = embedded::asia();
        let a = net.var_id("asia").unwrap();
        let p = mc_marginal(&net, a, 0, 200_000, 42);
        assert!((p - 0.01).abs() < 0.002, "P(asia=yes) ~ 0.01, got {p}");
    }

    #[test]
    fn mc_marginal_matches_derived_value() {
        // P(lung=yes) = 0.5*0.1 + 0.5*0.01 = 0.055
        let net = embedded::asia();
        let lung = net.var_id("lung").unwrap();
        let p = mc_marginal(&net, lung, 0, 200_000, 43);
        assert!((p - 0.055).abs() < 0.004, "P(lung=yes) ~ 0.055, got {p}");
    }
}
