//! Seeded synthetic Bayesian-network generator.
//!
//! The paper evaluates on six real networks from the bnlearn repository
//! (Hailfinder, Pathfinder, Diabetes, Pigs, Munin2, Munin4), which cannot
//! be downloaded in this offline environment. This module generates
//! **structural analogs**: random DAGs matching each network's published
//! node count, arc count, maximum in-degree and cardinality profile, with
//! Dirichlet-sampled CPTs. Junction-tree cost is governed by the clique
//! size distribution, which the `locality` (parent-window) and `max_table`
//! knobs control, so the analogs exercise the same inter-/intra-clique
//! trade-offs the paper's Table 1 probes (see DESIGN.md §3).

use crate::bn::cpt::Cpt;
use crate::bn::network::Network;
use crate::bn::variable::Variable;
use crate::rng::Rng;

/// Specification of a synthetic network.
#[derive(Clone, Debug)]
pub struct NetSpec {
    /// Network name.
    pub name: String,
    /// Number of variables.
    pub nodes: usize,
    /// Target number of arcs (may fall slightly short if constraints bind).
    pub arcs: usize,
    /// Maximum in-degree.
    pub max_parents: usize,
    /// Weighted cardinality choices, e.g. `[(2, 0.7), (3, 0.3)]`.
    pub card_choices: Vec<(usize, f64)>,
    /// Parents are drawn from the `locality` nodes preceding a child in the
    /// topological order. Small windows → chain-like low-treewidth DAGs;
    /// large windows → bushier graphs with bigger cliques.
    pub locality: usize,
    /// Reject a parent candidate if the child's family table
    /// (child × parents state space) would exceed this many entries —
    /// keeps generated families (and hence cliques) tractable.
    pub max_table: usize,
    /// Dirichlet concentration for CPT rows (1.0 = uniform simplex).
    pub alpha: f64,
    /// RNG seed; the same spec always yields the same network.
    pub seed: u64,
}

impl NetSpec {
    /// Generate the network.
    pub fn generate(&self) -> Network {
        assert!(self.nodes >= 1);
        assert!(!self.card_choices.is_empty());
        let mut rng = Rng::new(self.seed ^ 0x0FA5_7B41);

        // Cardinalities.
        let weights: Vec<f64> = self.card_choices.iter().map(|&(_, w)| w).collect();
        let cards: Vec<usize> = (0..self.nodes)
            .map(|_| self.card_choices[rng.categorical(&weights)].0)
            .collect();

        // Arcs: nodes are already in topological order (i -> j only if i < j).
        let mut parents: Vec<Vec<usize>> = vec![Vec::new(); self.nodes];
        let mut family_size: Vec<usize> = cards.clone();
        let mut placed = 0usize;
        let mut attempts = 0usize;
        let max_attempts = self.arcs * 50 + 1000;
        while placed < self.arcs && attempts < max_attempts {
            attempts += 1;
            let child = rng.range(1, self.nodes - 1);
            if parents[child].len() >= self.max_parents.min(child) {
                continue;
            }
            let lo = child.saturating_sub(self.locality.max(1));
            let parent = rng.range(lo, child - 1);
            if parents[child].contains(&parent) {
                continue;
            }
            if family_size[child].saturating_mul(cards[parent]) > self.max_table {
                continue;
            }
            parents[child].push(parent);
            family_size[child] *= cards[parent];
            placed += 1;
        }

        // Variables + CPTs.
        let vars: Vec<Variable> = (0..self.nodes)
            .map(|i| Variable::with_card(format!("n{i:04}"), cards[i]))
            .collect();
        let cpts: Vec<Cpt> = (0..self.nodes)
            .map(|v| {
                let ps = parents[v].clone();
                let rows: usize = ps.iter().map(|&p| cards[p]).product();
                let c = cards[v];
                let mut probs = Vec::with_capacity(rows * c);
                for _ in 0..rows {
                    probs.extend(rng.dirichlet(c, self.alpha));
                }
                Cpt { child: v, parents: ps, probs }
            })
            .collect();

        Network::new(self.name.clone(), vars, cpts).expect("generated network must validate")
    }
}

/// The six Table-1 networks as synthetic analogs (`<name>-sim`).
///
/// Node/arc counts, max in-degree and cardinality mixes follow the bnlearn
/// repository statistics for the real networks; `locality`/`max_table` are
/// tuned so junction-tree state-space totals keep the same *ordering*
/// (Hailfinder ≪ Pathfinder < Pigs < Munin2 < Diabetes < Munin4) at a scale
/// where a full benchmark sweep finishes in minutes, not days (see
/// DESIGN.md §3).
pub fn paper_suite() -> Vec<NetSpec> {
    vec![
        // Hailfinder: 56 nodes, 66 arcs, max in-deg 4, cards 2..11 (avg ~4)
        NetSpec {
            name: "hailfinder-sim".into(),
            nodes: 56,
            arcs: 66,
            max_parents: 4,
            card_choices: vec![(2, 0.35), (3, 0.25), (4, 0.2), (6, 0.1), (11, 0.1)],
            locality: 12,
            max_table: 1 << 16,
            alpha: 1.0,
            seed: 0x4A11,
        },
        // Pathfinder: 109 nodes, 195 arcs, max in-deg 5, some very large cards
        NetSpec {
            name: "pathfinder-sim".into(),
            nodes: 109,
            arcs: 195,
            max_parents: 5,
            card_choices: vec![(2, 0.3), (3, 0.25), (4, 0.2), (8, 0.15), (16, 0.1)],
            locality: 10,
            max_table: 1 << 16,
            alpha: 1.0,
            seed: 0x9A7F,
        },
        // Diabetes: 413 nodes, 602 arcs, max in-deg 2, cards up to 21
        NetSpec {
            name: "diabetes-sim".into(),
            nodes: 413,
            arcs: 602,
            max_parents: 2,
            card_choices: vec![(3, 0.2), (5, 0.3), (11, 0.3), (21, 0.2)],
            locality: 6,
            max_table: 1 << 16,
            alpha: 1.0,
        seed: 0xD1AB,
        },
        // Pigs: 441 nodes, 592 arcs, max in-deg 2, all cards 3
        NetSpec {
            name: "pigs-sim".into(),
            nodes: 441,
            arcs: 592,
            max_parents: 2,
            card_choices: vec![(3, 1.0)],
            locality: 22,
            max_table: 1 << 17,
            alpha: 1.0,
            seed: 0x0126,
        },
        // Munin2: 1003 nodes, 1244 arcs, max in-deg 3, cards up to 21
        NetSpec {
            name: "munin2-sim".into(),
            nodes: 1003,
            arcs: 1244,
            max_parents: 3,
            card_choices: vec![(2, 0.2), (3, 0.2), (5, 0.3), (7, 0.2), (21, 0.1)],
            locality: 8,
            max_table: 1 << 15,
            alpha: 1.0,
            seed: 0x2222,
        },
        // Munin4: 1041 nodes, 1397 arcs, max in-deg 3, cards up to 21
        NetSpec {
            name: "munin4-sim".into(),
            nodes: 1041,
            arcs: 1397,
            max_parents: 3,
            card_choices: vec![(2, 0.15), (3, 0.2), (5, 0.3), (7, 0.2), (21, 0.15)],
            locality: 12,
            max_table: 1 << 16,
            alpha: 1.0,
            seed: 0x4444,
        },
    ]
}

/// Look a paper-suite spec up by its `<name>-sim` name.
pub fn paper_spec(name: &str) -> Option<NetSpec> {
    paper_suite().into_iter().find(|s| s.name == name)
}

/// Generate a paper-suite network by name (`hailfinder-sim`, ...).
pub fn paper_net(name: &str) -> Option<Network> {
    paper_spec(name).map(|s| s.generate())
}

/// Names in the paper suite, in Table-1 order.
pub fn paper_names() -> Vec<String> {
    paper_suite().into_iter().map(|s| s.name).collect()
}

/// A deliberately **intractable** network (`intractable-sim`): binary
/// variables with a full parent window and dense arcs, so every family
/// table stays tiny (≤ `max_table` entries — forward sampling is cheap)
/// while the moralized graph's treewidth explodes and the junction-tree
/// state space blows past anything compilable. This is the fixture the
/// approximate-tier fallback tests and `make approx-smoke` load: exact
/// compile would allocate gigabytes, cost estimation + likelihood
/// weighting serve it in milliseconds.
pub fn intractable_spec() -> NetSpec {
    NetSpec {
        name: "intractable-sim".into(),
        nodes: 48,
        arcs: 288,
        max_parents: 8,
        card_choices: vec![(2, 1.0)],
        locality: 48,
        max_table: 1 << 9,
        alpha: 1.0,
        seed: 0xDE45E,
    }
}

/// A small random network for property tests: `nodes` ≤ ~10, random arcs,
/// cards 2–3 — small enough for brute-force enumeration.
pub fn tiny_random(seed: u64, nodes: usize) -> Network {
    let mut rng = Rng::new(seed);
    let arcs = if nodes < 2 { 0 } else { rng.range(nodes / 2, (nodes * 3 / 2).min(nodes * (nodes - 1) / 2)) };
    NetSpec {
        name: format!("tiny-{seed}"),
        nodes,
        arcs,
        max_parents: 3,
        card_choices: vec![(2, 0.7), (3, 0.3)],
        locality: nodes,
        max_table: 1 << 10,
        alpha: 1.0,
        seed: seed ^ 0x7171,
    }
    .generate()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = &paper_suite()[0];
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.n(), b.n());
        for v in 0..a.n() {
            assert_eq!(a.cpts[v].parents, b.cpts[v].parents);
            assert_eq!(a.cpts[v].probs, b.cpts[v].probs);
        }
    }

    #[test]
    fn paper_suite_matches_published_shapes() {
        // (name, nodes, arcs, max in-degree) per the bnlearn repository.
        let expect = [
            ("hailfinder-sim", 56, 66, 4),
            ("pathfinder-sim", 109, 195, 5),
            ("diabetes-sim", 413, 602, 2),
            ("pigs-sim", 441, 592, 2),
            ("munin2-sim", 1003, 1244, 3),
            ("munin4-sim", 1041, 1397, 3),
        ];
        for (name, nodes, arcs, maxp) in expect {
            let net = paper_net(name).unwrap();
            let s = net.stats();
            assert_eq!(s.nodes, nodes, "{name} nodes");
            // arc placement can fall slightly short when constraints bind
            assert!(
                s.arcs as f64 >= arcs as f64 * 0.93 && s.arcs <= arcs,
                "{name}: {} arcs vs target {arcs}",
                s.arcs
            );
            assert!(s.max_in_degree <= maxp, "{name} max in-degree");
            net.validate().unwrap();
        }
    }

    #[test]
    fn max_parents_respected() {
        let net = NetSpec {
            name: "mp".into(),
            nodes: 60,
            arcs: 200,
            max_parents: 2,
            card_choices: vec![(2, 1.0)],
            locality: 60,
            max_table: usize::MAX,
            alpha: 1.0,
            seed: 5,
        }
        .generate();
        for v in 0..net.n() {
            assert!(net.parents(v).len() <= 2);
        }
    }

    #[test]
    fn family_table_cap_respected() {
        let cap = 64;
        let net = NetSpec {
            name: "cap".into(),
            nodes: 40,
            arcs: 120,
            max_parents: 6,
            card_choices: vec![(4, 1.0)],
            locality: 40,
            max_table: cap,
            alpha: 1.0,
            seed: 6,
        }
        .generate();
        for v in 0..net.n() {
            let fam: usize = net.parents(v).iter().map(|&p| net.card(p)).product::<usize>() * net.card(v);
            assert!(fam <= cap, "family of {v} has {fam} entries");
        }
    }

    #[test]
    fn intractable_spec_is_cheap_to_sample_but_expensive_to_compile() {
        let net = intractable_spec().generate();
        net.validate().unwrap();
        assert_eq!(net.name, "intractable-sim");
        // every family table is small: forward sampling stays cheap
        for v in 0..net.n() {
            let fam: usize = net.parents(v).iter().map(|&p| net.card(p)).product::<usize>() * net.card(v);
            assert!(fam <= 1 << 9, "family of {v} has {fam} entries");
        }
        // …but the junction-tree state space is astronomically large
        let cost =
            crate::jt::tree::estimate_cost(&net, crate::jt::triangulate::TriangulationHeuristic::MinFill);
        assert!(cost > 1e9, "estimated cost {cost} is not intractable");
    }

    #[test]
    fn tiny_random_validates() {
        for seed in 0..20 {
            let net = tiny_random(seed, 3 + (seed as usize % 6));
            net.validate().unwrap();
        }
    }

    #[test]
    fn single_node_network() {
        let net = NetSpec {
            name: "one".into(),
            nodes: 1,
            arcs: 0,
            max_parents: 0,
            card_choices: vec![(2, 1.0)],
            locality: 1,
            max_table: 4,
            alpha: 1.0,
            seed: 1,
        }
        .generate();
        assert_eq!(net.n(), 1);
        net.validate().unwrap();
    }
}
