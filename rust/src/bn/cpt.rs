//! Conditional probability tables.

use crate::bn::variable::VarId;

/// The conditional probability table `P(child | parents)`.
///
/// # Layout
///
/// `probs` is row-major over `[parents..., child]` with the **child state
/// varying fastest**: entry for parent configuration `(p_0, .., p_{k-1})`
/// and child state `c` lives at
///
/// ```text
/// ((p_0 * card(parent_1) + p_1) * card(parent_2) + ...) * card(child) + c
/// ```
///
/// This matches the BIF `table` ordering used by bnlearn / UnBBayes
/// exports, so parsing is a straight copy.
#[derive(Clone, Debug, PartialEq)]
pub struct Cpt {
    /// The variable this CPT distributes over.
    pub child: VarId,
    /// Parent variables, in the order the probability rows are indexed.
    pub parents: Vec<VarId>,
    /// Flattened probabilities; length = child card × Π parent cards.
    pub probs: Vec<f64>,
}

impl Cpt {
    /// Build a CPT, checking the table length against the cardinalities.
    ///
    /// `cards[v]` must give the cardinality of every variable id used.
    pub fn new(child: VarId, parents: Vec<VarId>, probs: Vec<f64>, cards: &[usize]) -> crate::Result<Self> {
        let expected: usize = parents.iter().map(|&p| cards[p]).product::<usize>() * cards[child];
        if probs.len() != expected {
            return Err(crate::Error::InvalidNetwork(format!(
                "CPT for variable {} has {} entries, expected {}",
                child,
                probs.len(),
                expected
            )));
        }
        Ok(Cpt { child, parents, probs })
    }

    /// A uniform CPT (handy for tests and placeholder nodes).
    pub fn uniform(child: VarId, parents: Vec<VarId>, cards: &[usize]) -> Self {
        let rows: usize = parents.iter().map(|&p| cards[p]).product();
        let c = cards[child];
        Cpt {
            child,
            parents,
            probs: vec![1.0 / c as f64; rows * c],
        }
    }

    /// Number of parent configurations (rows).
    pub fn rows(&self, cards: &[usize]) -> usize {
        self.parents.iter().map(|&p| cards[p]).product()
    }

    /// The distribution over the child for one parent configuration,
    /// `config[i]` being the state of `parents[i]`.
    pub fn row(&self, config: &[usize], cards: &[usize]) -> &[f64] {
        debug_assert_eq!(config.len(), self.parents.len());
        let mut row = 0usize;
        for (i, &p) in self.parents.iter().enumerate() {
            debug_assert!(config[i] < cards[p]);
            row = row * cards[p] + config[i];
        }
        let c = cards[self.child];
        &self.probs[row * c..(row + 1) * c]
    }

    /// Check every row sums to 1 (within `tol`) and entries are in [0, 1].
    pub fn validate(&self, cards: &[usize], tol: f64) -> crate::Result<()> {
        let c = cards[self.child];
        if self.probs.iter().any(|&p| !(0.0..=1.0 + tol).contains(&p) || p.is_nan()) {
            return Err(crate::Error::InvalidNetwork(format!(
                "CPT for variable {} has probabilities outside [0,1]",
                self.child
            )));
        }
        for (r, row) in self.probs.chunks(c).enumerate() {
            let s: f64 = row.iter().sum();
            if (s - 1.0).abs() > tol {
                return Err(crate::Error::InvalidNetwork(format!(
                    "CPT row {} of variable {} sums to {}, expected 1",
                    r, self.child, s
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // cards: v0 has 2 states, v1 has 3, v2 has 2.
    const CARDS: &[usize] = &[2, 3, 2];

    #[test]
    fn new_checks_length() {
        assert!(Cpt::new(0, vec![], vec![0.3, 0.7], CARDS).is_ok());
        assert!(Cpt::new(0, vec![], vec![0.3, 0.3, 0.4], CARDS).is_err());
        assert!(Cpt::new(2, vec![0, 1], vec![0.5; 12], CARDS).is_ok());
        assert!(Cpt::new(2, vec![0, 1], vec![0.5; 10], CARDS).is_err());
    }

    #[test]
    fn uniform_rows_sum_to_one() {
        let c = Cpt::uniform(1, vec![0, 2], CARDS);
        assert_eq!(c.probs.len(), 2 * 2 * 3);
        c.validate(CARDS, 1e-12).unwrap();
    }

    #[test]
    fn row_indexing_matches_layout() {
        // P(v2 | v0, v1): rows ordered (v0, v1) with v1 fastest.
        let probs: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let c = Cpt { child: 2, parents: vec![0, 1], probs };
        // config (v0=1, v1=2) -> row = 1*3+2 = 5 -> entries 10, 11
        assert_eq!(c.row(&[1, 2], CARDS), &[10.0, 11.0]);
        assert_eq!(c.row(&[0, 0], CARDS), &[0.0, 1.0]);
    }

    #[test]
    fn validate_rejects_bad_rows() {
        let c = Cpt { child: 0, parents: vec![], probs: vec![0.5, 0.6] };
        assert!(c.validate(CARDS, 1e-9).is_err());
        let c = Cpt { child: 0, parents: vec![], probs: vec![-0.1, 1.1] };
        assert!(c.validate(CARDS, 1e-9).is_err());
    }
}
