//! Parser for the Hugin `.net` format — the other format the bnlearn
//! repository (and the Hugin / GeNIe tools) distribute networks in.
//!
//! Supported subset (what bnlearn exports):
//!
//! ```text
//! net { }
//! node A {
//!   states = ( "yes" "no" );
//! }
//! potential ( A | B C ) {
//!   data = (( 0.2 0.8 )
//!           ( 0.3 0.7 ));   % comment
//! }
//! ```
//!
//! `data` is row-major over the parents (as listed) with the child
//! varying fastest — the same flattening as a BIF `table`, so the nested
//! parentheses carry no information beyond grouping and are skipped.

use std::collections::HashMap;

use crate::bn::cpt::Cpt;
use crate::bn::network::Network;
use crate::bn::variable::Variable;
use crate::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Str(String),
    Number(f64),
    Punct(char),
}

fn lex(src: &str) -> Result<Vec<(Tok, usize)>> {
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut chars = src.char_indices().peekable();
    while let Some((i, c)) = chars.next() {
        match c {
            '\n' => line += 1,
            c if c.is_whitespace() => {}
            '%' => {
                // comment to end of line
                for (_, c2) in chars.by_ref() {
                    if c2 == '\n' {
                        line += 1;
                        break;
                    }
                }
            }
            '{' | '}' | '(' | ')' | '|' | '=' | ';' => toks.push((Tok::Punct(c), line)),
            '"' => {
                let start = i + 1;
                let mut end = start;
                for (j, c2) in chars.by_ref() {
                    if c2 == '"' {
                        end = j;
                        break;
                    }
                    if c2 == '\n' {
                        line += 1;
                    }
                }
                toks.push((Tok::Str(src[start..end].to_string()), line));
            }
            c if c.is_ascii_digit() || c == '-' || c == '.' => {
                let start = i;
                let mut end = i + c.len_utf8();
                while let Some(&(j, c2)) = chars.peek() {
                    if c2.is_ascii_digit() || matches!(c2, '.' | 'e' | 'E' | '-' | '+') {
                        end = j + c2.len_utf8();
                        chars.next();
                    } else {
                        break;
                    }
                }
                let text = &src[start..end];
                let n: f64 =
                    text.parse().map_err(|_| Error::Parse { line, msg: format!("bad number {text:?}") })?;
                toks.push((Tok::Number(n), line));
            }
            c if c.is_alphanumeric() || c == '_' => {
                let start = i;
                let mut end = i + c.len_utf8();
                while let Some(&(j, c2)) = chars.peek() {
                    if c2.is_alphanumeric() || c2 == '_' || c2 == '-' || c2 == '.' {
                        end = j + c2.len_utf8();
                        chars.next();
                    } else {
                        break;
                    }
                }
                toks.push((Tok::Ident(src[start..end].to_string()), line));
            }
            other => return Err(Error::Parse { line, msg: format!("unexpected character {other:?}") }),
        }
    }
    Ok(toks)
}

/// Parse Hugin `.net` text into a [`Network`].
pub fn parse(src: &str) -> Result<Network> {
    let toks = lex(src)?;
    let mut pos = 0usize;
    let line_at = |p: usize| toks.get(p.min(toks.len().saturating_sub(1))).map(|&(_, l)| l).unwrap_or(0);
    let next = |p: &mut usize| -> Result<&Tok> {
        let t = toks.get(*p).map(|(t, _)| t).ok_or_else(|| Error::Parse {
            line: line_at(*p),
            msg: "unexpected end of input".into(),
        })?;
        *p += 1;
        Ok(t)
    };

    let mut vars: Vec<Variable> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut raw_pots: Vec<(usize, Vec<usize>, Vec<f64>, usize)> = Vec::new();
    let mut net_name = String::from("network");

    while pos < toks.len() {
        let line = line_at(pos);
        match next(&mut pos)? {
            Tok::Ident(kw) if kw == "net" => {
                // optional name, then a block to skip
                if let Some((Tok::Ident(name), _)) = toks.get(pos) {
                    net_name = name.clone();
                    pos += 1;
                }
                skip_block(&toks, &mut pos, line)?;
            }
            Tok::Ident(kw) if kw == "node" => {
                let name = match next(&mut pos)? {
                    Tok::Ident(n) => n.clone(),
                    Tok::Str(n) => n.clone(),
                    other => return Err(Error::Parse { line, msg: format!("bad node name {other:?}") }),
                };
                expect_punct(&toks, &mut pos, '{')?;
                let mut states: Vec<String> = Vec::new();
                let mut depth = 1usize;
                while depth > 0 {
                    match next(&mut pos)? {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => depth -= 1,
                        Tok::Ident(f) if f == "states" && depth == 1 => {
                            expect_punct(&toks, &mut pos, '=')?;
                            expect_punct(&toks, &mut pos, '(')?;
                            loop {
                                match next(&mut pos)? {
                                    Tok::Punct(')') => break,
                                    Tok::Str(s) => states.push(s.clone()),
                                    Tok::Ident(s) => states.push(s.clone()),
                                    Tok::Number(n) => states.push(format!("{n}")),
                                    other => {
                                        return Err(Error::Parse {
                                            line,
                                            msg: format!("bad state {other:?}"),
                                        })
                                    }
                                }
                            }
                        }
                        _ => {}
                    }
                }
                if states.is_empty() {
                    return Err(Error::Parse { line, msg: format!("node {name} has no states") });
                }
                if index.insert(name.clone(), vars.len()).is_some() {
                    return Err(Error::Parse { line, msg: format!("duplicate node {name:?}") });
                }
                vars.push(Variable { name, states });
            }
            Tok::Ident(kw) if kw == "potential" => {
                expect_punct(&toks, &mut pos, '(')?;
                let child_name = match next(&mut pos)? {
                    Tok::Ident(n) => n.clone(),
                    other => return Err(Error::Parse { line, msg: format!("bad child {other:?}") }),
                };
                let child = *index
                    .get(&child_name)
                    .ok_or_else(|| Error::Parse { line, msg: format!("unknown node {child_name:?}") })?;
                let mut parents: Vec<usize> = Vec::new();
                loop {
                    match next(&mut pos)? {
                        Tok::Punct(')') => break,
                        Tok::Punct('|') => {}
                        Tok::Ident(p) => {
                            let pid = *index
                                .get(p)
                                .ok_or_else(|| Error::Parse { line, msg: format!("unknown parent {p:?}") })?;
                            parents.push(pid);
                        }
                        other => return Err(Error::Parse { line, msg: format!("bad parent {other:?}") }),
                    }
                }
                expect_punct(&toks, &mut pos, '{')?;
                // scan the block: collect every number inside `data = ...;`
                let mut probs: Vec<f64> = Vec::new();
                let mut depth = 1usize;
                let mut in_data = false;
                while depth > 0 {
                    match next(&mut pos)? {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => depth -= 1,
                        Tok::Ident(f) if f == "data" && depth == 1 => in_data = true,
                        Tok::Punct(';') => in_data = false,
                        Tok::Number(n) if in_data => probs.push(*n),
                        _ => {}
                    }
                }
                raw_pots.push((child, parents, probs, line));
            }
            other => return Err(Error::Parse { line, msg: format!("unexpected top-level {other:?}") }),
        }
    }

    let cards: Vec<usize> = vars.iter().map(|v| v.card()).collect();
    let mut cpts: Vec<Option<Cpt>> = (0..vars.len()).map(|_| None).collect();
    for (child, parents, probs, line) in raw_pots {
        let cpt = Cpt::new(child, parents, probs, &cards)
            .map_err(|e| Error::Parse { line, msg: e.to_string() })?;
        if cpts[child].is_some() {
            return Err(Error::Parse { line, msg: format!("duplicate potential for {:?}", vars[child].name) });
        }
        cpts[child] = Some(cpt);
    }
    let cpts: Vec<Cpt> = cpts
        .into_iter()
        .enumerate()
        .map(|(v, c)| c.ok_or_else(|| Error::InvalidNetwork(format!("no potential for {:?}", vars[v].name))))
        .collect::<Result<_>>()?;
    Network::new(net_name, vars, cpts)
}

fn expect_punct(toks: &[(Tok, usize)], pos: &mut usize, c: char) -> Result<()> {
    match toks.get(*pos) {
        Some((Tok::Punct(p), _)) if *p == c => {
            *pos += 1;
            Ok(())
        }
        Some((other, line)) => Err(Error::Parse { line: *line, msg: format!("expected {c:?}, found {other:?}") }),
        None => Err(Error::Parse { line: 0, msg: format!("expected {c:?}, found end of input") }),
    }
}

fn skip_block(toks: &[(Tok, usize)], pos: &mut usize, line: usize) -> Result<()> {
    expect_punct(toks, pos, '{')?;
    let mut depth = 1usize;
    while depth > 0 {
        match toks.get(*pos) {
            Some((Tok::Punct('{'), _)) => depth += 1,
            Some((Tok::Punct('}'), _)) => depth -= 1,
            Some(_) => {}
            None => return Err(Error::Parse { line, msg: "unterminated block".into() }),
        }
        *pos += 1;
    }
    Ok(())
}

/// Read a network from a `.net` file.
pub fn parse_file(path: &std::path::Path) -> Result<Network> {
    parse(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"
net
{
  node_size = (80 40);
}
node rain
{
  states = ( "yes" "no" );
  label = "Rain today";
}
node grass
{
  states = ( "wet" "dry" );
}
potential ( rain )
{
  data = ( 0.2 0.8 );
}
potential ( grass | rain )
{
  data = (( 0.9 0.1 )   % rain = yes
          ( 0.1 0.9 )); % rain = no
}
"#;

    #[test]
    fn parses_mini_net() {
        let net = parse(MINI).unwrap();
        assert_eq!(net.n(), 2);
        let g = net.var_id("grass").unwrap();
        let r = net.var_id("rain").unwrap();
        assert_eq!(net.parents(g), &[r]);
        let cards = net.cards();
        assert_eq!(net.cpts[g].row(&[0], &cards), &[0.9, 0.1]);
        assert_eq!(net.cpts[r].probs, vec![0.2, 0.8]);
        net.validate().unwrap();
    }

    #[test]
    fn agrees_with_bif_parse_of_the_same_network() {
        // same distribution written in both formats must produce identical
        // posteriors
        use crate::jt::evidence::Evidence;
        let bif_src = r#"
network mini { }
variable rain { type discrete [ 2 ] { yes, no }; }
variable grass { type discrete [ 2 ] { wet, dry }; }
probability ( rain ) { table 0.2, 0.8; }
probability ( grass | rain ) { (yes) 0.9, 0.1; (no) 0.1, 0.9; }
"#;
        let a = parse(MINI).unwrap();
        let b = crate::bn::bif::parse(bif_src).unwrap();
        let pa = crate::infer::exact::enumerate(&a, &Evidence::none()).unwrap();
        let pb = crate::infer::exact::enumerate(&b, &Evidence::none()).unwrap();
        for v in 0..2 {
            for s in 0..2 {
                assert!((pa.probs[v][s] - pb.probs[v][s]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn missing_potential_rejected() {
        let src = r#"
net { }
node a { states = ( "x" "y" ); }
"#;
        assert!(parse(src).is_err());
    }

    #[test]
    fn bad_data_length_rejected() {
        let src = r#"
net { }
node a { states = ( "x" "y" ); }
potential ( a ) { data = ( 0.5 0.3 0.2 ); }
"#;
        assert!(parse(src).is_err());
    }

    #[test]
    fn comments_and_properties_ignored() {
        let src = "net { } % top\nnode a { states = ( \"t\" \"f\" ); position = (10 20); }\npotential ( a ) { data = ( 1.0 0.0 ); }";
        let net = parse(src).unwrap();
        assert_eq!(net.cpts[0].probs, vec![1.0, 0.0]);
    }
}
