//! The Bayesian network: variables + DAG + CPTs.

use std::collections::HashMap;

use crate::bn::cpt::Cpt;
use crate::bn::variable::{VarId, Variable};
use crate::{Error, Result};

/// A discrete Bayesian network.
///
/// Invariants (enforced by [`Network::validate`], which all constructors in
/// this crate run):
/// * exactly one CPT per variable, `cpts[v].child == v`;
/// * the parent relation is acyclic;
/// * every CPT row is a probability distribution.
#[derive(Clone, Debug)]
pub struct Network {
    /// Network name (from BIF or generator).
    pub name: String,
    /// Variables; `VarId` indexes into this.
    pub vars: Vec<Variable>,
    /// `cpts[v]` is the CPT of variable `v`.
    pub cpts: Vec<Cpt>,
    name_index: HashMap<String, VarId>,
    children: Vec<Vec<VarId>>,
}

impl Network {
    /// Assemble and validate a network.
    pub fn new(name: impl Into<String>, vars: Vec<Variable>, cpts: Vec<Cpt>) -> Result<Self> {
        let mut name_index = HashMap::with_capacity(vars.len());
        for (i, v) in vars.iter().enumerate() {
            if name_index.insert(v.name.clone(), i).is_some() {
                return Err(Error::InvalidNetwork(format!("duplicate variable name {:?}", v.name)));
            }
        }
        let mut children = vec![Vec::new(); vars.len()];
        for cpt in &cpts {
            for &p in &cpt.parents {
                children[p].push(cpt.child);
            }
        }
        let net = Network {
            name: name.into(),
            vars,
            cpts,
            name_index,
            children,
        };
        net.validate()?;
        Ok(net)
    }

    /// Number of variables.
    #[inline]
    pub fn n(&self) -> usize {
        self.vars.len()
    }

    /// Cardinality of variable `v`.
    #[inline]
    pub fn card(&self, v: VarId) -> usize {
        self.vars[v].card()
    }

    /// All cardinalities, indexed by `VarId`.
    pub fn cards(&self) -> Vec<usize> {
        self.vars.iter().map(|v| v.card()).collect()
    }

    /// Parents of `v` (CPT order).
    #[inline]
    pub fn parents(&self, v: VarId) -> &[VarId] {
        &self.cpts[v].parents
    }

    /// Children of `v`.
    #[inline]
    pub fn children(&self, v: VarId) -> &[VarId] {
        &self.children[v]
    }

    /// Look a variable up by name.
    pub fn var_id(&self, name: &str) -> Result<VarId> {
        self.name_index
            .get(name)
            .copied()
            .ok_or_else(|| Error::UnknownVariable(name.to_string()))
    }

    /// Resolve `(variable, state)` names to ids.
    pub fn state_id(&self, var: &str, state: &str) -> Result<(VarId, usize)> {
        let v = self.var_id(var)?;
        let s = self.vars[v]
            .state_index(state)
            .ok_or_else(|| Error::UnknownState { var: var.to_string(), state: state.to_string() })?;
        Ok((v, s))
    }

    /// Total number of directed edges.
    pub fn n_arcs(&self) -> usize {
        self.cpts.iter().map(|c| c.parents.len()).sum()
    }

    /// Total number of independent CPT parameters
    /// (Σ_v (card(v) − 1) · Π_p card(p); the bnlearn repository statistic).
    pub fn n_params(&self) -> usize {
        self.cpts
            .iter()
            .map(|c| {
                let rows: usize = c.parents.iter().map(|&p| self.card(p)).product();
                rows * (self.card(c.child) - 1)
            })
            .sum()
    }

    /// A topological order of the variables (parents before children).
    pub fn topo_order(&self) -> Result<Vec<VarId>> {
        let n = self.n();
        let mut indeg: Vec<usize> = (0..n).map(|v| self.parents(v).len()).collect();
        let mut stack: Vec<VarId> = (0..n).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(v) = stack.pop() {
            order.push(v);
            for &c in self.children(v) {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    stack.push(c);
                }
            }
        }
        if order.len() != n {
            return Err(Error::InvalidNetwork("parent relation contains a cycle".into()));
        }
        Ok(order)
    }

    /// Validate all invariants (one CPT per var, acyclicity, row sums).
    pub fn validate(&self) -> Result<()> {
        if self.cpts.len() != self.vars.len() {
            return Err(Error::InvalidNetwork(format!(
                "{} variables but {} CPTs",
                self.vars.len(),
                self.cpts.len()
            )));
        }
        let cards = self.cards();
        for (v, cpt) in self.cpts.iter().enumerate() {
            if cpt.child != v {
                return Err(Error::InvalidNetwork(format!(
                    "CPT at slot {} is for variable {}",
                    v, cpt.child
                )));
            }
            let mut seen = std::collections::HashSet::new();
            for &p in &cpt.parents {
                if p >= self.n() {
                    return Err(Error::InvalidNetwork(format!("variable {} has out-of-range parent {}", v, p)));
                }
                if p == v {
                    return Err(Error::InvalidNetwork(format!("variable {} is its own parent", v)));
                }
                if !seen.insert(p) {
                    return Err(Error::InvalidNetwork(format!("variable {} has duplicate parent {}", v, p)));
                }
            }
            cpt.validate(&cards, 1e-6)?;
        }
        self.topo_order()?;
        Ok(())
    }

    /// Human-readable summary (node/arc/parameter counts, max in-degree,
    /// max state count) — the statistics the bnlearn repository reports.
    pub fn stats(&self) -> NetworkStats {
        NetworkStats {
            name: self.name.clone(),
            nodes: self.n(),
            arcs: self.n_arcs(),
            params: self.n_params(),
            max_in_degree: (0..self.n()).map(|v| self.parents(v).len()).max().unwrap_or(0),
            max_card: self.vars.iter().map(|v| v.card()).max().unwrap_or(0),
            avg_card: if self.n() == 0 {
                0.0
            } else {
                self.vars.iter().map(|v| v.card()).sum::<usize>() as f64 / self.n() as f64
            },
        }
    }
}

/// Summary statistics for a network (see [`Network::stats`]).
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkStats {
    pub name: String,
    pub nodes: usize,
    pub arcs: usize,
    pub params: usize,
    pub max_in_degree: usize,
    pub max_card: usize,
    pub avg_card: f64,
}

impl std::fmt::Display for NetworkStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {} nodes, {} arcs, {} params, max in-degree {}, max card {}, avg card {:.2}",
            self.name, self.nodes, self.arcs, self.params, self.max_in_degree, self.max_card, self.avg_card
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> Network {
        // a -> b -> c, all binary
        let vars = vec![
            Variable::new("a", &["t", "f"]),
            Variable::new("b", &["t", "f"]),
            Variable::new("c", &["t", "f"]),
        ];
        let cards = [2, 2, 2];
        let cpts = vec![
            Cpt::new(0, vec![], vec![0.6, 0.4], &cards).unwrap(),
            Cpt::new(1, vec![0], vec![0.7, 0.3, 0.2, 0.8], &cards).unwrap(),
            Cpt::new(2, vec![1], vec![0.9, 0.1, 0.5, 0.5], &cards).unwrap(),
        ];
        Network::new("chain3", vars, cpts).unwrap()
    }

    #[test]
    fn basic_accessors() {
        let net = chain3();
        assert_eq!(net.n(), 3);
        assert_eq!(net.n_arcs(), 2);
        assert_eq!(net.card(0), 2);
        assert_eq!(net.parents(1), &[0]);
        assert_eq!(net.children(0), &[1]);
        assert_eq!(net.var_id("c").unwrap(), 2);
        assert!(net.var_id("zzz").is_err());
        assert_eq!(net.state_id("a", "f").unwrap(), (0, 1));
        assert!(net.state_id("a", "x").is_err());
    }

    #[test]
    fn n_params_matches_bnlearn_convention() {
        let net = chain3();
        // a: 1, b: 2 rows * 1, c: 2 rows * 1 -> 5
        assert_eq!(net.n_params(), 5);
    }

    #[test]
    fn topo_order_is_valid() {
        let net = chain3();
        let order = net.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 3];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        assert!(pos[0] < pos[1] && pos[1] < pos[2]);
    }

    #[test]
    fn cycle_is_rejected() {
        let vars = vec![Variable::with_card("a", 2), Variable::with_card("b", 2)];
        let cards = [2, 2];
        let cpts = vec![
            Cpt::new(0, vec![1], vec![0.5; 4], &cards).unwrap(),
            Cpt::new(1, vec![0], vec![0.5; 4], &cards).unwrap(),
        ];
        assert!(Network::new("cyc", vars, cpts).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let vars = vec![Variable::with_card("a", 2), Variable::with_card("a", 2)];
        let cards = [2, 2];
        let cpts = vec![
            Cpt::new(0, vec![], vec![0.5, 0.5], &cards).unwrap(),
            Cpt::new(1, vec![], vec![0.5, 0.5], &cards).unwrap(),
        ];
        assert!(Network::new("dup", vars, cpts).is_err());
    }

    #[test]
    fn self_parent_rejected() {
        let vars = vec![Variable::with_card("a", 2)];
        let cpts = vec![Cpt { child: 0, parents: vec![0], probs: vec![0.5; 4] }];
        assert!(Network::new("selfp", vars, cpts).is_err());
    }

    #[test]
    fn stats_display() {
        let s = chain3().stats();
        assert_eq!(s.nodes, 3);
        assert_eq!(s.arcs, 2);
        assert!(format!("{s}").contains("chain3"));
    }
}
