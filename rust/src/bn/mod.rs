//! Discrete Bayesian-network model and I/O.
//!
//! This is the substrate layer the paper assumes: random variables with a
//! finite state space, a DAG of conditional dependencies, and one
//! conditional probability table (CPT) per variable. The module also owns
//! everything needed to *obtain* networks in this offline environment:
//! a BIF parser/writer ([`bif`]), classic textbook networks embedded as BIF
//! text ([`embedded`]), and a seeded synthetic generator that produces
//! structural analogs of the six bnlearn networks used in the paper's
//! Table 1 ([`netgen`]).

pub mod bif;
pub mod cpt;
pub mod embedded;
pub mod hugin;
pub mod netgen;
pub mod network;
pub mod sample;
pub mod variable;

pub use cpt::Cpt;
pub use network::Network;
pub use variable::Variable;

/// Resolve a network spec string to a loaded [`Network`].
///
/// A spec is an embedded name (`asia`, `cancer`, `sprinkler`, `mixed12`),
/// a paper-suite analog (`hailfinder-sim` … `munin4-sim`), a path to a
/// `.bif` / Hugin `.net` file, or a `learn:` spec
/// (`learn:<name>:<samples>:<seed>:<base-spec>`) that samples from the
/// base network and learns a structure + parameters deterministically
/// (see [`crate::learn`]). This is the single loading entry point the
/// CLI and the serving fleet's registry share.
pub fn resolve_spec(spec: &str) -> crate::Result<Network> {
    if crate::learn::is_learn_spec(spec) {
        return crate::learn::resolve_learn_spec(spec);
    }
    if let Some(net) = embedded::by_name(spec) {
        return Ok(net);
    }
    if let Some(net) = netgen::paper_net(spec) {
        return Ok(net);
    }
    if spec == "intractable-sim" {
        // the approximate-tier fixture: cheap to sample, hopeless to compile
        return Ok(netgen::intractable_spec().generate());
    }
    let path = std::path::Path::new(spec);
    if path.exists() {
        // dispatch on extension: .net = Hugin, everything else = BIF
        if path.extension().map(|e| e == "net").unwrap_or(false) {
            return hugin::parse_file(path);
        }
        return bif::parse_file(path);
    }
    Err(crate::Error::msg(format!(
        "unknown network {spec:?} (embedded: {}; paper suite: {}; or a .bif/.net path)",
        embedded::NAMES.join(", "),
        netgen::paper_names().join(", ")
    )))
}

#[cfg(test)]
mod tests {
    #[test]
    fn resolve_spec_covers_embedded_paper_and_missing() {
        assert_eq!(super::resolve_spec("asia").unwrap().name, "asia");
        assert!(super::resolve_spec("hailfinder-sim").is_ok());
        assert_eq!(super::resolve_spec("intractable-sim").unwrap().name, "intractable-sim");
        assert!(super::resolve_spec("no-such-net").is_err());
    }

    #[test]
    fn resolve_spec_handles_learn_specs() {
        let net = super::resolve_spec("learn:tiny:2000:3:sprinkler").unwrap();
        assert_eq!(net.name, "tiny");
        assert_eq!(net.n(), 4);
        assert!(super::resolve_spec("learn:bad").is_err());
        assert!(super::resolve_spec("learn:x:100:1:no-such-base").is_err());
    }
}
