//! Discrete Bayesian-network model and I/O.
//!
//! This is the substrate layer the paper assumes: random variables with a
//! finite state space, a DAG of conditional dependencies, and one
//! conditional probability table (CPT) per variable. The module also owns
//! everything needed to *obtain* networks in this offline environment:
//! a BIF parser/writer ([`bif`]), classic textbook networks embedded as BIF
//! text ([`embedded`]), and a seeded synthetic generator that produces
//! structural analogs of the six bnlearn networks used in the paper's
//! Table 1 ([`netgen`]).

pub mod bif;
pub mod cpt;
pub mod embedded;
pub mod hugin;
pub mod netgen;
pub mod network;
pub mod sample;
pub mod variable;

pub use cpt::Cpt;
pub use network::Network;
pub use variable::Variable;
