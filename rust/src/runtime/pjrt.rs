//! Thin PJRT wrapper: client construction, HLO-text compilation,
//! execution with `f64` buffers.
//!
//! Interchange is HLO **text** — `HloModuleProto::from_text_file`
//! reassigns instruction ids, avoiding the 64-bit-id protos of jax ≥ 0.5
//! that xla_extension 0.5.1 rejects (see python/compile/aot.py).

use std::path::Path;

use crate::{Error, Result};

fn wrap(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// A PJRT CPU client plus compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu().map_err(wrap)? })
    }

    /// Platform name (e.g. "cpu") for reporting.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::msg("non-utf8 artifact path"))?,
        )
        .map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(Executable { exe })
    }
}

impl Executable {
    /// Execute with `f64` inputs of the given shapes; returns the first
    /// output of the 1-tuple result (aot.py lowers with
    /// `return_tuple=True`) flattened to a `Vec<f64>`.
    pub fn run_f64(&self, inputs: &[(&[f64], &[i64])]) -> Result<Vec<f64>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).map_err(wrap)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Runtime("executable produced no output".into()))?
            .to_literal_sync()
            .map_err(wrap)?;
        let first = out.to_tuple1().map_err(wrap)?;
        first.to_vec::<f64>().map_err(wrap)
    }

    /// Execute and return all outputs of a tuple result.
    pub fn run_f64_multi(&self, inputs: &[(&[f64], &[i64])]) -> Result<Vec<Vec<f64>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).map_err(wrap)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Runtime("executable produced no output".into()))?
            .to_literal_sync()
            .map_err(wrap)?;
        let parts = out.to_tuple().map_err(wrap)?;
        parts.into_iter().map(|p| p.to_vec::<f64>().map_err(wrap)).collect()
    }
}
