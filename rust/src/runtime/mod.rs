//! The XLA/PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! * [`pjrt`] — thin wrapper over the `xla` crate (PJRT CPU client,
//!   HLO-text loading, execution).
//! * [`buckets`] — artifact manifest, shape-bucket selection, zero-padding
//!   and the sep-major 2-D view permutation of clique tables.
//! * [`ops`] — the `TableOps2d` backend trait with `NativeOps` (plain
//!   loops, the default hot path) and `XlaOps` (PJRT-executed artifacts);
//!   `benches/table_ops.rs` measures the crossover.
//! * [`accel`] — `SeqXlaEngine`, a sequential engine that routes
//!   sufficiently large messages through the XLA backend, proving the
//!   three layers compose on the request path.
//!
//! Python runs only at build time (`make artifacts`); the binary consumes
//! HLO text exclusively.

pub mod accel;
pub mod buckets;
pub mod ops;
pub mod pjrt;

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True if the artifact directory looks built (used by tests/benches to
/// skip XLA-dependent sections with a notice instead of failing).
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    dir.join("manifest.txt").exists()
}
