//! The XLA/PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! * [`pjrt`] — thin wrapper over the `xla` crate (PJRT CPU client,
//!   HLO-text loading, execution).
//! * [`buckets`] — artifact manifest, shape-bucket selection, zero-padding
//!   and the sep-major 2-D view permutation of clique tables.
//! * [`ops`] — the `TableOps2d` backend trait with `NativeOps` (plain
//!   loops, the default hot path) and `XlaOps` (PJRT-executed artifacts);
//!   `benches/table_ops.rs` measures the crossover.
//! * [`accel`] — `SeqXlaEngine`, a sequential engine that routes
//!   sufficiently large messages through the XLA backend, proving the
//!   three layers compose on the request path.
//!
//! Python runs only at build time (`make artifacts`); the binary consumes
//! HLO text exclusively.
//!
//! Everything that touches the `xla` crate (`pjrt`, `accel`, the `XlaOps`
//! backend in [`ops`]) is gated behind the off-by-default `xla` cargo
//! feature, so the default build is pure-std and offline-safe; `NativeOps`
//! and the bucket/manifest machinery are always available.

#[cfg(feature = "xla")]
pub mod accel;
pub mod buckets;
pub mod ops;
#[cfg(feature = "xla")]
pub mod pjrt;

/// Default artifact directory name (`make artifacts` writes it at the repo
/// root; see [`artifact_dir`] for cwd-robust resolution).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True if the artifact directory looks built (used by tests/benches to
/// skip XLA-dependent sections with a notice instead of failing).
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    dir.join("manifest.txt").exists()
}

/// Resolve the artifact directory: `$FASTBN_ARTIFACTS` if set, else the
/// first of `artifacts/`, `../artifacts/` that looks built. The second
/// candidate matters because cargo runs test and bench binaries with the
/// *package* root (`rust/`) as cwd, one level below the repo root where
/// `make artifacts` writes.
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("FASTBN_ARTIFACTS") {
        return std::path::PathBuf::from(p);
    }
    for cand in [DEFAULT_ARTIFACT_DIR, "../artifacts"] {
        let p = std::path::Path::new(cand);
        if artifacts_available(p) {
            return p.to_path_buf();
        }
    }
    std::path::PathBuf::from(DEFAULT_ARTIFACT_DIR)
}
