//! Table-op backends over the sep-major 2-D layout.
//!
//! `NativeOps` is the plain-Rust hot path (what the paper's CPU algorithm
//! does, restated in the 2-D layout so both backends are measured on the
//! same memory access pattern); `XlaOps` executes the AOT artifacts via
//! PJRT with bucket padding. `benches/table_ops.rs` sweeps table sizes to
//! find the dispatch-overhead crossover.

#[cfg(feature = "xla")]
use std::collections::HashMap;
#[cfg(feature = "xla")]
use std::path::{Path, PathBuf};

#[cfg(feature = "xla")]
use crate::runtime::buckets::{pad_2d, unpad_2d, Manifest};
#[cfg(feature = "xla")]
use crate::runtime::pjrt::{Executable, PjrtRuntime};
use crate::Result;
#[cfg(feature = "xla")]
use crate::Error;

/// A backend for the two dominant table operations on `(m, k)` sep-major
/// tables.
pub trait TableOps2d {
    /// Backend name for reports.
    fn name(&self) -> &'static str;

    /// Row sums: `out[r] = Σ_c table[r, c]`; `out.len() == m`.
    fn marginalize(&mut self, table: &[f64], m: usize, k: usize, out: &mut [f64]) -> Result<()>;

    /// In-place `table[r, c] *= new[r]/old[r]` (0/0 → 0).
    fn absorb(&mut self, table: &mut [f64], m: usize, k: usize, sep_new: &[f64], sep_old: &[f64]) -> Result<()>;
}

/// Plain-loop backend.
#[derive(Default)]
pub struct NativeOps;

impl TableOps2d for NativeOps {
    fn name(&self) -> &'static str {
        "native"
    }

    fn marginalize(&mut self, table: &[f64], m: usize, k: usize, out: &mut [f64]) -> Result<()> {
        debug_assert_eq!(table.len(), m * k);
        debug_assert_eq!(out.len(), m);
        for r in 0..m {
            out[r] = table[r * k..(r + 1) * k].iter().sum();
        }
        Ok(())
    }

    fn absorb(&mut self, table: &mut [f64], m: usize, k: usize, sep_new: &[f64], sep_old: &[f64]) -> Result<()> {
        debug_assert_eq!(table.len(), m * k);
        for r in 0..m {
            let ratio = if sep_old[r] != 0.0 { sep_new[r] / sep_old[r] } else { 0.0 };
            for x in &mut table[r * k..(r + 1) * k] {
                *x *= ratio;
            }
        }
        Ok(())
    }
}

/// PJRT-backed ops over the AOT artifacts (requires the `xla` feature).
#[cfg(feature = "xla")]
pub struct XlaOps {
    runtime: PjrtRuntime,
    manifest: Manifest,
    dir: PathBuf,
    execs: HashMap<(&'static str, (usize, usize)), Executable>,
    // reusable padding buffers
    buf_table: Vec<f64>,
    buf_sep_new: Vec<f64>,
    buf_sep_old: Vec<f64>,
}

#[cfg(feature = "xla")]
impl XlaOps {
    /// Load the manifest and create the PJRT client. Executables compile
    /// lazily on first use per (op, bucket).
    pub fn load(dir: &Path) -> Result<XlaOps> {
        let manifest = Manifest::load(dir)?;
        if manifest.buckets.is_empty() {
            return Err(Error::Runtime("artifact manifest has no usable buckets".into()));
        }
        Ok(XlaOps {
            runtime: PjrtRuntime::cpu()?,
            manifest,
            dir: dir.to_path_buf(),
            execs: HashMap::new(),
            buf_table: Vec::new(),
            buf_sep_new: Vec::new(),
            buf_sep_old: Vec::new(),
        })
    }

    /// The available buckets.
    pub fn buckets(&self) -> &[(usize, usize)] {
        &self.manifest.buckets
    }

    /// Largest table this backend can serve.
    pub fn capacity(&self) -> (usize, usize) {
        self.manifest.buckets.last().copied().unwrap_or((0, 0))
    }

    /// True if an `(m, k)` table fits some bucket.
    pub fn fits(&self, m: usize, k: usize) -> bool {
        self.manifest.bucket_for(m, k).is_some()
    }

    fn executable(&mut self, op: &'static str, bucket: (usize, usize)) -> Result<&Executable> {
        if !self.execs.contains_key(&(op, bucket)) {
            let file = self
                .manifest
                .file_for(op, bucket)
                .ok_or_else(|| Error::Runtime(format!("no {op} artifact for bucket {bucket:?}")))?;
            let exe = self.runtime.compile_hlo_text(&self.dir.join(file))?;
            self.execs.insert((op, bucket), exe);
        }
        Ok(&self.execs[&(op, bucket)])
    }
}

#[cfg(feature = "xla")]
impl XlaOps {
    /// Batched bucket list: `(B, M, K)` shapes with both `bmarg` and
    /// `babsorb` artifacts.
    pub fn batched_buckets(&self) -> Vec<(usize, usize, usize)> {
        self.manifest
            .entries
            .iter()
            .filter(|(op, d, _)| op == "bmarg" && d.len() == 3)
            .filter(|(_, d, _)| {
                self.manifest
                    .entries
                    .iter()
                    .any(|(op2, d2, _)| op2 == "babsorb" && d2 == d)
            })
            .map(|(_, d, _)| (d[0], d[1], d[2]))
            .collect()
    }

    fn batched_executable(&mut self, op: &'static str, b: usize, m: usize, k: usize) -> Result<&Executable> {
        // batched artifacts are keyed by (op, (b * m, k)) to reuse the map
        let key = (op, (b * (1 << 20) + m, k));
        if !self.execs.contains_key(&key) {
            let file = self
                .manifest
                .entries
                .iter()
                .find(|(o, d, _)| o == op && d.len() == 3 && d[0] == b && d[1] == m && d[2] == k)
                .map(|(_, _, f)| f.clone())
                .ok_or_else(|| Error::Runtime(format!("no {op} artifact for ({b},{m},{k})")))?;
            let exe = self.runtime.compile_hlo_text(&self.dir.join(&file))?;
            self.execs.insert(key, exe);
        }
        Ok(&self.execs[&key])
    }

    /// Batched row-sum marginalization: `tables` is `(B, M, K)` flattened;
    /// returns `(B, M)` flattened. Amortizes one PJRT dispatch over `B`
    /// same-bucket messages (e.g. the same edge across evidence cases).
    pub fn marginalize_batch(&mut self, tables: &[f64], b: usize, m: usize, k: usize) -> Result<Vec<f64>> {
        debug_assert_eq!(tables.len(), b * m * k);
        let exe = self.batched_executable("bmarg", b, m, k)?;
        exe.run_f64(&[(tables, &[b as i64, m as i64, k as i64])])
    }

    /// Batched absorb: `tables` `(B, M, K)`, `sep_new`/`sep_old` `(B, M)`;
    /// returns the updated `(B, M, K)` tables.
    pub fn absorb_batch(
        &mut self,
        tables: &[f64],
        b: usize,
        m: usize,
        k: usize,
        sep_new: &[f64],
        sep_old: &[f64],
    ) -> Result<Vec<f64>> {
        debug_assert_eq!(tables.len(), b * m * k);
        debug_assert_eq!(sep_new.len(), b * m);
        debug_assert_eq!(sep_old.len(), b * m);
        let exe = self.batched_executable("babsorb", b, m, k)?;
        exe.run_f64(&[
            (tables, &[b as i64, m as i64, k as i64]),
            (sep_new, &[b as i64, m as i64]),
            (sep_old, &[b as i64, m as i64]),
        ])
    }
}

#[cfg(feature = "xla")]
impl TableOps2d for XlaOps {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn marginalize(&mut self, table: &[f64], m: usize, k: usize, out: &mut [f64]) -> Result<()> {
        let bucket = self
            .manifest
            .bucket_for(m, k)
            .ok_or_else(|| Error::Runtime(format!("no bucket for ({m}, {k})")))?;
        let (bm, bk) = bucket;
        let mut buf = std::mem::take(&mut self.buf_table);
        pad_2d(table, m, k, bm, bk, &mut buf);
        let exe = self.executable("marg", bucket)?;
        let result = exe.run_f64(&[(&buf, &[bm as i64, bk as i64])])?;
        self.buf_table = buf;
        out.copy_from_slice(&result[..m]);
        Ok(())
    }

    fn absorb(&mut self, table: &mut [f64], m: usize, k: usize, sep_new: &[f64], sep_old: &[f64]) -> Result<()> {
        let bucket = self
            .manifest
            .bucket_for(m, k)
            .ok_or_else(|| Error::Runtime(format!("no bucket for ({m}, {k})")))?;
        let (bm, bk) = bucket;
        let mut buf = std::mem::take(&mut self.buf_table);
        pad_2d(table, m, k, bm, bk, &mut buf);
        // pad separators: old=1 on padding rows avoids 0/0 work, new=0
        // keeps padded rows at zero
        let mut sep_new_buf = std::mem::take(&mut self.buf_sep_new);
        sep_new_buf.clear();
        sep_new_buf.extend_from_slice(sep_new);
        sep_new_buf.resize(bm, 0.0);
        let mut sep_old_buf = std::mem::take(&mut self.buf_sep_old);
        sep_old_buf.clear();
        sep_old_buf.extend_from_slice(sep_old);
        sep_old_buf.resize(bm, 1.0);
        let exe = self.executable("absorb", bucket)?;
        let result = exe.run_f64(&[
            (&buf, &[bm as i64, bk as i64]),
            (&sep_new_buf, &[bm as i64]),
            (&sep_old_buf, &[bm as i64]),
        ])?;
        unpad_2d(&result, bm, bk, m, k, table);
        self.buf_table = buf;
        self.buf_sep_new = sep_new_buf;
        self.buf_sep_old = sep_old_buf;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[cfg(feature = "xla")]
    use crate::rng::Rng;

    #[test]
    fn native_ops_match_directly_computed_values() {
        let mut native = NativeOps;
        let table = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // (2,3)
        let mut out = vec![0.0; 2];
        native.marginalize(&table, 2, 3, &mut out).unwrap();
        assert_eq!(out, vec![6.0, 15.0]);

        let mut t = table.clone();
        native.absorb(&mut t, 2, 3, &[2.0, 0.0], &[1.0, 0.0]).unwrap();
        assert_eq!(t, vec![2.0, 4.0, 6.0, 0.0, 0.0, 0.0]);
    }

    #[cfg(feature = "xla")]
    #[test]
    fn xla_ops_match_native_on_random_tables() {
        let dir = crate::runtime::artifact_dir();
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut xla = match XlaOps::load(&dir) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("skipping: XLA backend unavailable ({e})");
                return;
            }
        };
        let mut native = NativeOps;
        let mut rng = Rng::new(11);
        for &(m, k) in &[(3usize, 5usize), (16, 16), (17, 40), (200, 100)] {
            if !xla.fits(m, k) {
                continue;
            }
            let table: Vec<f64> = (0..m * k).map(|_| rng.f64()).collect();
            let mut a = vec![0.0; m];
            let mut b = vec![0.0; m];
            native.marginalize(&table, m, k, &mut a).unwrap();
            xla.marginalize(&table, m, k, &mut b).unwrap();
            for j in 0..m {
                assert!((a[j] - b[j]).abs() < 1e-9, "({m},{k}) row {j}: {} vs {}", a[j], b[j]);
            }

            let sep_new: Vec<f64> = (0..m).map(|_| rng.f64()).collect();
            let sep_old: Vec<f64> = (0..m).map(|_| 0.1 + rng.f64()).collect();
            let mut ta = table.clone();
            let mut tb = table.clone();
            native.absorb(&mut ta, m, k, &sep_new, &sep_old).unwrap();
            xla.absorb(&mut tb, m, k, &sep_new, &sep_old).unwrap();
            for i in 0..m * k {
                assert!((ta[i] - tb[i]).abs() < 1e-9, "({m},{k}) entry {i}");
            }
        }
    }
}
