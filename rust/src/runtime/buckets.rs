//! Shape buckets, padding, and the sep-major 2-D view of clique tables.
//!
//! The AOT artifacts are compiled for fixed `(M, K)` shapes (XLA is
//! static-shape); the runtime pads each clique's 2-D view up to the
//! smallest bucket that fits. Padding is all-zero, which both table ops
//! treat as absent mass (zero rows marginalize to zero; absorb multiplies
//! zeros), so results are exact after slicing back.
//!
//! The 2-D view itself reorders a clique table so the separator variables
//! become the leading (row) axis: row `m` enumerates separator
//! configurations in separator-table order, column `k` the remaining
//! variables. This is the TPU-side answer to the paper's index mappings —
//! gather once into the layout where the ops are dense (see DESIGN.md
//! §Hardware-Adaptation).

use std::path::Path;

use crate::jt::tree::{Clique, Separator};
use crate::{Error, Result};

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// `(M, K)` buckets that have both `marg` and `absorb` artifacts,
    /// sorted by area then rows.
    pub buckets: Vec<(usize, usize)>,
    /// All `(op, dims, filename)` entries.
    pub entries: Vec<(String, Vec<usize>, String)>,
}

impl Manifest {
    /// Read `manifest.txt` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() < 3 {
                return Err(Error::msg(format!("bad manifest line {line:?}")));
            }
            let op = parts[0].to_string();
            let dims: Vec<usize> = parts[1..parts.len() - 1]
                .iter()
                .map(|d| d.parse().map_err(|_| Error::msg(format!("bad dim in {line:?}"))))
                .collect::<Result<_>>()?;
            entries.push((op, dims, parts[parts.len() - 1].to_string()));
        }
        let mut margs: Vec<(usize, usize)> = entries
            .iter()
            .filter(|(op, dims, _)| op == "marg" && dims.len() == 2)
            .map(|(_, d, _)| (d[0], d[1]))
            .collect();
        margs.retain(|&(m, k)| {
            entries.iter().any(|(op, d, _)| op == "absorb" && d.len() == 2 && d[0] == m && d[1] == k)
        });
        margs.sort_by_key(|&(m, k)| (m * k, m));
        Ok(Manifest { buckets: margs, entries })
    }

    /// Smallest bucket covering an `(m, k)` table, if any.
    pub fn bucket_for(&self, m: usize, k: usize) -> Option<(usize, usize)> {
        self.buckets.iter().copied().find(|&(bm, bk)| bm >= m && bk >= k)
    }

    /// Filename for an op at a bucket.
    pub fn file_for(&self, op: &str, bucket: (usize, usize)) -> Option<&str> {
        self.entries
            .iter()
            .find(|(o, d, _)| o == op && d.len() == 2 && d[0] == bucket.0 && d[1] == bucket.1)
            .map(|(_, _, f)| f.as_str())
    }
}

/// The sep-major 2-D view of one (clique, separator) pair.
///
/// `perm[m * k_len + k]` is the flat clique index of 2-D position
/// `(m, k)`; row `m` equals the separator-table index by construction.
#[derive(Clone, Debug)]
pub struct SepMajorView {
    /// Rows = separator length.
    pub m_len: usize,
    /// Columns = clique length / separator length.
    pub k_len: usize,
    /// 2-D position → flat clique index.
    pub perm: Vec<u32>,
}

impl SepMajorView {
    /// Build the view for `clique` with `sep ⊆ clique`.
    pub fn build(clique: &Clique, sep: &Separator) -> SepMajorView {
        // axis order: sep vars (sorted, matching sep-table layout), then
        // the rest of the clique vars (sorted)
        let rest: Vec<usize> =
            clique.vars.iter().copied().filter(|v| sep.vars.binary_search(v).is_err()).collect();
        let m_len = sep.len.max(1);
        let k_len = clique.len / m_len;

        // per-axis clique strides in the (sep..., rest...) order
        let stride_of = |v: usize| -> usize {
            let pos = clique.vars.binary_search(&v).expect("sep var must be in clique");
            clique.strides[pos]
        };
        let axis_vars: Vec<usize> = sep.vars.iter().chain(rest.iter()).copied().collect();
        let axis_cards: Vec<usize> = axis_vars
            .iter()
            .map(|&v| {
                let pos = clique.vars.binary_search(&v).unwrap();
                clique.cards[pos]
            })
            .collect();
        let axis_strides: Vec<usize> = axis_vars.iter().map(|&v| stride_of(v)).collect();

        // odometer over (sep..., rest...) emitting the clique flat index
        let mut perm = Vec::with_capacity(clique.len);
        let mut digits = vec![0usize; axis_vars.len()];
        let mut flat = 0usize;
        for _ in 0..clique.len {
            perm.push(flat as u32);
            for i in (0..digits.len()).rev() {
                digits[i] += 1;
                if digits[i] < axis_cards[i] {
                    flat += axis_strides[i];
                    break;
                }
                digits[i] = 0;
                flat -= (axis_cards[i] - 1) * axis_strides[i];
            }
        }
        SepMajorView { m_len, k_len, perm }
    }

    /// Gather the clique table into the 2-D layout.
    pub fn pack(&self, clique: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.perm.len());
        for (o, &p) in out.iter_mut().zip(&self.perm) {
            *o = clique[p as usize];
        }
    }

    /// Scatter a 2-D-layout table back into the clique layout.
    pub fn unpack(&self, packed: &[f64], clique: &mut [f64]) {
        debug_assert_eq!(packed.len(), self.perm.len());
        for (x, &p) in packed.iter().zip(&self.perm) {
            clique[p as usize] = *x;
        }
    }
}

/// Zero-pad a row-major `(m, k)` table into an `(bm, bk)` buffer.
pub fn pad_2d(src: &[f64], m: usize, k: usize, bm: usize, bk: usize, dst: &mut Vec<f64>) {
    debug_assert!(bm >= m && bk >= k);
    dst.clear();
    dst.resize(bm * bk, 0.0);
    for row in 0..m {
        dst[row * bk..row * bk + k].copy_from_slice(&src[row * k..(row + 1) * k]);
    }
}

/// Slice an `(bm, bk)` buffer back down to `(m, k)` row-major.
pub fn unpad_2d(src: &[f64], bm: usize, bk: usize, m: usize, k: usize, dst: &mut [f64]) {
    debug_assert!(bm >= m && bk >= k);
    debug_assert_eq!(dst.len(), m * k);
    let _ = bm;
    for row in 0..m {
        dst[row * k..(row + 1) * k].copy_from_slice(&src[row * bk..row * bk + k]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::ops;
    use crate::jt::tree::JunctionTree;
    use crate::jt::triangulate::TriangulationHeuristic;
    use crate::rng::Rng;

    #[test]
    fn manifest_parses_and_selects_buckets() {
        let dir = crate::runtime::artifact_dir();
        if !crate::runtime::artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(!man.buckets.is_empty());
        let (bm, bk) = man.bucket_for(10, 10).unwrap();
        assert!(bm >= 10 && bk >= 10);
        // exact fit picks the exact bucket
        let first = man.buckets[0];
        assert_eq!(man.bucket_for(first.0, first.1).unwrap(), first);
        assert!(man.file_for("marg", first).is_some());
        assert!(man.file_for("absorb", first).is_some());
        // oversize request yields None
        assert!(man.bucket_for(1 << 20, 1 << 20).is_none());
    }

    #[test]
    fn sep_major_view_is_a_permutation_and_rows_match_sep_indices() {
        let net = embedded::mixed12();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut rng = Rng::new(3);
        for (sid, sep) in jt.seps.iter().enumerate() {
            for &cid in &[sep.a, sep.b] {
                let clique = &jt.cliques[cid];
                let view = SepMajorView::build(clique, sep);
                assert_eq!(view.m_len * view.k_len, clique.len);
                assert_eq!(view.m_len, sep.len);
                // permutation property
                let mut seen = vec![false; clique.len];
                for &p in &view.perm {
                    assert!(!seen[p as usize]);
                    seen[p as usize] = true;
                }
                // row sums through the view == map-based marginalization
                let data: Vec<f64> = (0..clique.len).map(|_| rng.f64()).collect();
                let mut packed = vec![0.0; clique.len];
                view.pack(&data, &mut packed);
                let mut by_rows = vec![0.0; sep.len];
                for m in 0..view.m_len {
                    by_rows[m] = packed[m * view.k_len..(m + 1) * view.k_len].iter().sum();
                }
                let mut by_map = vec![0.0; sep.len];
                ops::marg_with_map(&data, jt.edge_maps[sid].from(sep, cid), &mut by_map);
                for j in 0..sep.len {
                    assert!((by_rows[j] - by_map[j]).abs() < 1e-9, "sep {sid} clique {cid} row {j}");
                }
                // pack/unpack roundtrip
                let mut restored = vec![0.0; clique.len];
                view.unpack(&packed, &mut restored);
                assert_eq!(restored, data);
            }
        }
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let src: Vec<f64> = (0..6).map(|x| x as f64).collect(); // (2,3)
        let mut padded = Vec::new();
        pad_2d(&src, 2, 3, 4, 8, &mut padded);
        assert_eq!(padded.len(), 32);
        assert_eq!(padded[0..3], [0.0, 1.0, 2.0]);
        assert_eq!(padded[8..11], [3.0, 4.0, 5.0]);
        assert!(padded[3..8].iter().all(|&x| x == 0.0));
        let mut out = vec![0.0; 6];
        unpad_2d(&padded, 4, 8, 2, 3, &mut out);
        assert_eq!(out, src);
    }
}
