//! `SeqXlaEngine` — a sequential engine that routes large messages
//! through the PJRT/XLA backend.
//!
//! Proves the three-layer composition on the request path: the Rust
//! coordinator walks the tree; for each message whose tables exceed
//! `threshold` entries (and fit an artifact bucket), the clique is packed
//! into its sep-major 2-D view, the AOT `marg`/`absorb` artifacts run via
//! PJRT, and the results are scattered back. Smaller messages use the
//! native kernels — on CPU the PJRT dispatch overhead dominates small
//! tables (see `benches/table_ops.rs` for the measured crossover).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::propagate::Scratch;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::runtime::buckets::SepMajorView;
use crate::runtime::ops::{TableOps2d, XlaOps};
use crate::{Error, Result};

/// Sequential engine with XLA-accelerated large-table operations.
pub struct SeqXlaEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    xla: XlaOps,
    /// Minimum clique entries to route through XLA.
    threshold: usize,
    /// Cached sep-major views per (clique, sep) actually routed.
    views: HashMap<(usize, usize), SepMajorView>,
    scratch: Scratch,
    packed: Vec<f64>,
    /// Count of ops served by XLA vs native (for reporting).
    pub xla_ops: u64,
    /// Count of ops served natively.
    pub native_ops: u64,
}

impl SeqXlaEngine {
    /// Build from an artifact directory. `threshold` in table entries.
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig, artifact_dir: &Path, threshold: usize) -> Result<Self> {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let xla = XlaOps::load(artifact_dir)?;
        let scratch = Scratch::for_tree(&jt);
        let max_clique = jt.cliques.iter().map(|c| c.len).max().unwrap_or(1);
        Ok(SeqXlaEngine {
            jt,
            sched,
            xla,
            threshold,
            views: HashMap::new(),
            scratch,
            packed: Vec::with_capacity(max_clique),
            xla_ops: 0,
            native_ops: 0,
        })
    }

    fn view(&mut self, clique: usize, sep: usize) -> &SepMajorView {
        let jt = &self.jt;
        self.views
            .entry((clique, sep))
            .or_insert_with(|| SepMajorView::build(&jt.cliques[clique], &jt.seps[sep]))
    }

    /// Whether a (clique, sep) op should go through XLA.
    fn use_xla(&self, clique: usize, sep: usize) -> bool {
        let c = &self.jt.cliques[clique];
        let s = &self.jt.seps[sep];
        let k = c.len / s.len.max(1);
        c.len >= self.threshold && self.xla.fits(s.len, k)
    }

    fn send(&mut self, state: &mut TreeState, msg: Msg) -> Result<f64> {
        let sep_len = self.jt.seps[msg.sep].len;

        // marginalization
        {
            let new_sep_owned: Vec<f64>;
            if self.use_xla(msg.from, msg.sep) {
                let view = self.view(msg.from, msg.sep).clone();
                let mut packed = std::mem::take(&mut self.packed);
                packed.resize(view.perm.len(), 0.0);
                view.pack(state.clique(msg.from), &mut packed);
                let mut out = vec![0.0; view.m_len];
                self.xla.marginalize(&packed, view.m_len, view.k_len, &mut out)?;
                self.packed = packed;
                self.xla_ops += 1;
                new_sep_owned = out;
            } else {
                let sep_meta = &self.jt.seps[msg.sep];
                let map = self.jt.edge_maps[msg.sep].from(sep_meta, msg.from);
                let mut out = vec![0.0; sep_len];
                ops::marg_with_map(state.clique(msg.from), map, &mut out);
                self.native_ops += 1;
                new_sep_owned = out;
            }
            self.scratch.new_sep[..sep_len].copy_from_slice(&new_sep_owned);
        }

        let mass = ops::sum(&self.scratch.new_sep[..sep_len]);
        if mass == 0.0 {
            return Ok(0.0);
        }
        ops::scale(&mut self.scratch.new_sep[..sep_len], 1.0 / mass);
        state.log_z += mass.ln();

        // extension (+ reduction)
        if self.use_xla(msg.to, msg.sep) {
            let view = self.view(msg.to, msg.sep).clone();
            let mut packed = std::mem::take(&mut self.packed);
            packed.resize(view.perm.len(), 0.0);
            view.pack(state.clique(msg.to), &mut packed);
            let old = state.sep(msg.sep).to_vec();
            self.xla
                .absorb(&mut packed, view.m_len, view.k_len, &self.scratch.new_sep[..sep_len], &old)?;
            view.unpack(&packed, state.clique_mut(msg.to));
            self.packed = packed;
            self.xla_ops += 1;
        } else {
            let sep_meta = &self.jt.seps[msg.sep];
            let map = self.jt.edge_maps[msg.sep].from(sep_meta, msg.to);
            ops::ratio(&self.scratch.new_sep[..sep_len], state.sep(msg.sep), &mut self.scratch.ratio[..sep_len]);
            ops::extend_with_map(state.clique_mut(msg.to), map, &self.scratch.ratio[..sep_len]);
            self.native_ops += 1;
        }
        state.sep_mut(msg.sep).copy_from_slice(&self.scratch.new_sep[..sep_len]);
        Ok(mass)
    }
}

impl Engine for SeqXlaEngine {
    fn name(&self) -> &'static str {
        "Fast-BNI-seq+xla"
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        state.reset(&self.jt);
        ev.apply(&self.jt, state);
        let up: Vec<Vec<Msg>> = self.sched.up_layers.clone();
        for layer in &up {
            for &msg in layer {
                if self.send(state, msg)? == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        for root in self.sched.roots.clone() {
            let data = state.clique_mut(root);
            let mass = ops::sum(data);
            if mass == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            ops::scale(data, 1.0 / mass);
            state.log_z += mass.ln();
        }
        let z = state.log_z;
        let down: Vec<Vec<Msg>> = self.sched.down_layers.clone();
        for layer in &down {
            for &msg in layer {
                if self.send(state, msg)? == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        state.log_z = z;
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}
