//! Criterion-lite benchmark harness.
//!
//! `criterion` is unavailable offline; this module supplies the subset the
//! bench targets need — warmup + N timed samples, robust summary stats,
//! and aligned table printing — with `harness = false` targets so
//! `cargo bench` works unchanged.

use std::time::{Duration, Instant};

/// Summary over timed samples.
#[derive(Clone, Debug)]
pub struct Stat {
    pub n: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stat {
    /// Compute from raw samples (must be non-empty).
    pub fn from_samples(mut samples: Vec<Duration>) -> Stat {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples.iter().map(|s| (s.as_secs_f64() - mean_s).powi(2)).sum::<f64>() / n as f64;
        Stat {
            n,
            mean,
            median: samples[n / 2],
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
}

impl std::fmt::Display for Stat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  median {:>10.3?}  σ {:>9.3?}  min {:>10.3?}  max {:>10.3?}  (n={})",
            self.mean, self.median, self.stddev, self.min, self.max, self.n
        )
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    /// Untimed warmup iterations.
    pub warmup: usize,
    /// Timed samples.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 7 }
    }
}

impl Bench {
    /// New runner.
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench { warmup, samples }
    }

    /// Time `f` (whole-call granularity).
    pub fn run(&self, mut f: impl FnMut()) -> Stat {
        for _ in 0..self.warmup {
            f();
        }
        let samples = (0..self.samples.max(1))
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed()
            })
            .collect();
        Stat::from_samples(samples)
    }
}

/// Environment-variable override helper for bench scale knobs
/// (`FASTBN_CASES=100 cargo bench`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Print an aligned table: `headers`, then rows.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Format a duration in adaptive units for table cells.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Format a speedup factor the way Table 1 does.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_from_known_samples() {
        let s = Stat::from_samples(vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ]);
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.median, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
    }

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0usize;
        let b = Bench::new(3, 5);
        let counter = std::cell::RefCell::new(&mut count);
        b.run(|| {
            **counter.borrow_mut() += 1;
        });
        assert_eq!(count, 8);
    }

    #[test]
    fn env_override() {
        assert_eq!(env_usize("FASTBN_TEST_NOT_SET_XYZ", 42), 42);
        std::env::set_var("FASTBN_TEST_SET_XYZ", "7");
        assert_eq!(env_usize("FASTBN_TEST_SET_XYZ", 42), 7);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(3)), "3.0µs");
        assert_eq!(fmt_speedup(7.25), "7.2");
    }
}
