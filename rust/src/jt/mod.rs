//! Junction-tree compilation and calibration substrate.
//!
//! The classical pipeline the paper builds on:
//!
//! 1. **moralize** the DAG ([`moralize`]);
//! 2. **triangulate** the moral graph with an elimination heuristic and
//!    read off the maximal cliques ([`triangulate`]);
//! 3. assemble the **junction tree** — maximum-weight spanning tree over
//!    the clique graph, running-intersection property guaranteed
//!    ([`tree`]);
//! 4. attach **potential tables** (one per clique/separator) initialized
//!    from the CPTs ([`potential`], [`state`]);
//! 5. enter **evidence** ([`evidence`]) and **propagate** messages
//!    (collect + distribute) to calibrate ([`propagate`]).
//!
//! The potential-table *operations* — marginalization, extension,
//! reduction — and the **index mappings** between clique and separator
//! tables that dominate their cost (the bottleneck the paper simplifies)
//! live in [`ops`] and [`mapping`]; the explicit SIMD lane micro-kernels
//! backing the batched (case-major) variants live in [`simd`]. The parallel schedules over this
//! substrate (leveling, root selection, the six engines) live in
//! [`crate::engine`].

pub mod evidence;
pub mod mapping;
pub mod moralize;
pub mod mpe;
pub mod ops;
pub mod potential;
pub mod propagate;
pub mod schedule;
pub mod simd;
pub mod state;
pub mod tree;
pub mod triangulate;
