//! Explicit SIMD lane micro-kernels for the case-major batched tier.
//!
//! The lane-interleaved arena ([`crate::jt::state::BatchState`]) stores
//! entry `i`, case `b` at `i*lanes + b`, so every batched kernel in
//! [`crate::jt::ops`] bottoms out in a short element-wise loop over a
//! contiguous `&[f64]` lane slice. Those loops *should* auto-vectorize,
//! but nothing guarantees the compiler actually does — this module makes
//! the vector shape explicit: each operation is driven through fixed-width
//! `[f64; 8]` blocks, then `[f64; 4]` blocks, then a scalar tail. Stable
//! Rust guarantees nothing about instruction selection either, but a
//! fixed-size array of independent element-wise ops is the canonical
//! shape LLVM turns into vector instructions at every `-C opt-level`
//! worth using, and the 8/4/1 ladder keeps partial-occupancy slices
//! (`occ < lanes`) on the widest block they fit.
//!
//! **Bit-identity is by construction, not by luck.** Every kernel here is
//! per-element — `dst[i] op= src[i]` with no cross-element reduction — so
//! blocking the loop changes *which registers* hold the values, never the
//! sequence of floating-point operations applied to any one element.
//! SIMD output is therefore bit-identical to the scalar twin, and the
//! repo's bitwise-determinism contract survives vectorization. The
//! `scalar` submodule keeps the plain loops compiled in every
//! configuration so tests (and `benches/kernels.rs`) can assert exactly
//! that, byte for byte.
//!
//! Selection is compile-time: the on-by-default `simd` cargo feature
//! routes the public names at the blocked drivers; `--no-default-features`
//! routes them at `scalar` — the pure-std zero-dependency build is
//! untouched either way (no `std::simd`, no arch intrinsics, no nightly).

/// Preferred lane-width multiple for batched chunk boundaries: the widest
/// block the drivers use. Chunk splits aligned to this never cut a full
/// 8-wide block into scalar-tail work mid-table (see
/// [`crate::engine::pool::chunk_ranges_aligned`]).
pub const LANE_WIDTH: usize = 8;

/// Generate one lane-wise `dst op= src` kernel: 8-wide blocks, then
/// 4-wide on the remainder, then a scalar tail. `$body` is the
/// per-element statement over `$d: &mut f64`, `$s: f64`.
macro_rules! lanewise {
    ($(#[$doc:meta])* $name:ident, |$d:ident, $s:ident| $body:expr) => {
        $(#[$doc])*
        #[inline]
        pub fn $name(dst: &mut [f64], src: &[f64]) {
            debug_assert_eq!(dst.len(), src.len());
            let mut d8 = dst.chunks_exact_mut(8);
            let mut s8 = src.chunks_exact(8);
            for (db, sb) in d8.by_ref().zip(s8.by_ref()) {
                let db: &mut [f64; 8] = db.try_into().unwrap();
                let sb: &[f64; 8] = sb.try_into().unwrap();
                for k in 0..8 {
                    let $d = &mut db[k];
                    let $s = sb[k];
                    $body;
                }
            }
            let mut d4 = d8.into_remainder().chunks_exact_mut(4);
            let mut s4 = s8.remainder().chunks_exact(4);
            for (db, sb) in d4.by_ref().zip(s4.by_ref()) {
                let db: &mut [f64; 4] = db.try_into().unwrap();
                let sb: &[f64; 4] = sb.try_into().unwrap();
                for k in 0..4 {
                    let $d = &mut db[k];
                    let $s = sb[k];
                    $body;
                }
            }
            for ($d, &$s) in d4.into_remainder().iter_mut().zip(s4.remainder()) {
                $body;
            }
        }
    };
}

/// Plain-loop twins of every blocked kernel, compiled in **every** feature
/// configuration: with `simd` off they *are* the public kernels; with
/// `simd` on they are the reference the bit-exactness suite and
/// `benches/kernels.rs` compare the blocked drivers against.
pub mod scalar {
    /// `dst[k] += src[k]`.
    #[inline]
    pub fn add_assign(dst: &mut [f64], src: &[f64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }

    /// `dst[k] *= src[k]`.
    #[inline]
    pub fn mul_assign(dst: &mut [f64], src: &[f64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d *= s;
        }
    }

    /// `dst[k] /= src[k]`.
    #[inline]
    pub fn div_assign(dst: &mut [f64], src: &[f64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d /= s;
        }
    }

    /// `dst[k] = src[k]` when strictly greater (same comparison as the
    /// single-case max-product kernels in [`crate::jt::mpe`]).
    #[inline]
    pub fn max_assign(dst: &mut [f64], src: &[f64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            if s > *d {
                *d = s;
            }
        }
    }
}

/// The blocked 8/4/1 drivers (selected by the `simd` feature).
#[cfg(feature = "simd")]
mod wide {
    lanewise!(
        /// `dst[k] += src[k]`, in fixed-width blocks.
        add_assign,
        |d, s| *d += s
    );
    lanewise!(
        /// `dst[k] *= src[k]`, in fixed-width blocks.
        mul_assign,
        |d, s| *d *= s
    );
    lanewise!(
        /// `dst[k] /= src[k]`, in fixed-width blocks.
        div_assign,
        |d, s| *d /= s
    );
    lanewise!(
        /// `dst[k] = src[k]` when strictly greater, in fixed-width blocks.
        max_assign,
        |d, s| {
            if s > *d {
                *d = s;
            }
        }
    );
}

#[cfg(feature = "simd")]
pub use wide::{add_assign, div_assign, max_assign, mul_assign};

#[cfg(not(feature = "simd"))]
pub use scalar::{add_assign, div_assign, max_assign, mul_assign};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Slice lengths crossing every dispatch tier: scalar tail only,
    /// exactly one 4-block, 4-block + tail, exactly one 8-block, 8 + tail,
    /// 8 + 4, 8 + 4 + tail, and a long mixed run.
    const LENS: [usize; 10] = [1, 2, 3, 4, 7, 8, 11, 12, 15, 64];

    fn pair(len: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let d: Vec<f64> = (0..len).map(|_| rng.f64() * 4.0 - 2.0).collect();
        let s: Vec<f64> = (0..len).map(|_| rng.f64() * 4.0 - 2.0).collect();
        (d, s)
    }

    /// The selected kernels are **bit-identical** to the plain scalar
    /// loops at every length across the 8/4/1 dispatch ladder — the
    /// contract that lets the batched tier vectorize without touching the
    /// repo's bitwise-determinism guarantees. (With `simd` off the two
    /// sides are the same function; CI runs both feature configs.)
    #[test]
    fn blocked_kernels_bit_identical_to_scalar() {
        type Kernel = (&'static str, fn(&mut [f64], &[f64]), fn(&mut [f64], &[f64]));
        let kernels: [Kernel; 4] = [
            ("add", add_assign, scalar::add_assign),
            ("mul", mul_assign, scalar::mul_assign),
            ("div", div_assign, scalar::div_assign),
            ("max", max_assign, scalar::max_assign),
        ];
        for (name, blocked, plain) in kernels {
            for (case, &len) in LENS.iter().enumerate() {
                let (d0, s) = pair(len, 0xC0FFEE ^ ((case as u64) << 8));
                let mut got = d0.clone();
                blocked(&mut got, &s);
                let mut want = d0.clone();
                plain(&mut want, &s);
                for k in 0..len {
                    assert_eq!(
                        got[k].to_bits(),
                        want[k].to_bits(),
                        "{name} len {len} element {k}: {} != {}",
                        got[k],
                        want[k]
                    );
                }
            }
        }
    }

    #[test]
    fn div_by_zero_and_zero_operands_follow_ieee() {
        // the kernels are raw IEEE ops — the 0/0 → 0 junction-tree
        // convention lives in ops::ratio / the lane finish, not here
        let mut d = vec![1.0, 0.0, -3.0];
        div_assign(&mut d, &[0.0, 0.0, 1.5]);
        assert_eq!(d[0], f64::INFINITY);
        assert!(d[1].is_nan());
        assert_eq!(d[2], -2.0);
    }

    #[test]
    fn max_assign_keeps_dst_on_ties_and_nan_src() {
        let mut d = vec![1.0, 2.0, 3.0];
        max_assign(&mut d, &[1.0, f64::NAN, 5.0]);
        assert_eq!(d, vec![1.0, 2.0, 5.0]);
    }
}
