//! The three dominant potential-table operations, as flat kernels.
//!
//! The paper identifies **marginalization** (clique → separator sum),
//! **extension** (separator broadcast into a clique) and **reduction**
//! (separator division, folded here into the extension of the ratio
//! `new/old`) as the operations that dominate junction-tree inference.
//! Everything here works on raw `&[f64]` tables plus the precomputed index
//! maps of [`crate::jt::mapping`]; engines differ only in *how* they chunk
//! and schedule these kernels.
//!
//! Range variants (`*_range`) operate on a sub-interval of the source
//! table so parallel engines can flatten entries into tasks; the
//! `*_divmod` variants recompute projections per entry (the naive
//! baseline); `atomic_*` variants implement the element-wise GPU-style
//! scatter used by the `Element` comparison engine.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::jt::mapping::{project_divmod, ProjectedOdometer};

/// `dst[map[i]] += src[i]` over the whole table. `dst` must be pre-zeroed.
#[inline]
pub fn marg_with_map(src: &[f64], map: &[u32], dst: &mut [f64]) {
    debug_assert_eq!(src.len(), map.len());
    for (x, &m) in src.iter().zip(map) {
        dst[m as usize] += x;
    }
}

/// `dst[map[i]] += src[i]` for `i` in `range` only.
#[inline]
pub fn marg_range(src: &[f64], map: &[u32], range: std::ops::Range<usize>, dst: &mut [f64]) {
    for i in range {
        dst[map[i] as usize] += src[i];
    }
}

/// Marginalization with per-entry div/mod projection (naive baseline).
pub fn marg_divmod(
    src: &[f64],
    src_cards: &[usize],
    src_strides: &[usize],
    proj_strides: &[usize],
    dst: &mut [f64],
) {
    for (i, &x) in src.iter().enumerate() {
        dst[project_divmod(src_cards, src_strides, proj_strides, i)] += x;
    }
}

/// Marginalization with an incremental odometer (no divisions, no map).
pub fn marg_odometer(src: &[f64], src_cards: &[usize], proj_strides: &[usize], dst: &mut [f64]) {
    let mut odo = ProjectedOdometer::new(src_cards, proj_strides);
    for &x in src {
        dst[odo.current()] += x;
    // advancing after the read keeps the final wrap cost off the hot loop
        odo.step();
    }
}

/// Atomic scatter-add used by the element-wise engine: each element does a
/// CAS loop on the destination bits (the CPU analog of GPU atomicAdd).
#[inline]
pub fn atomic_add_f64(slot: &AtomicU64, value: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = f64::from_bits(cur) + value;
        match slot.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

/// `dst[map[i]] += src[i]` for `i` in `range`, with atomic accumulation.
#[inline]
pub fn atomic_marg_range(src: &[f64], map: &[u32], range: std::ops::Range<usize>, dst: &[AtomicU64]) {
    for i in range {
        atomic_add_f64(&dst[map[i] as usize], src[i]);
    }
}

/// Extension + reduction fused: `dst[i] *= ratio[map[i]]` over the table.
#[inline]
pub fn extend_with_map(dst: &mut [f64], map: &[u32], ratio: &[f64]) {
    debug_assert_eq!(dst.len(), map.len());
    for (x, &m) in dst.iter_mut().zip(map) {
        *x *= ratio[m as usize];
    }
}

/// `dst[i] *= ratio[map[i]]` for `i` in `range` only.
#[inline]
pub fn extend_range(dst: &mut [f64], map: &[u32], range: std::ops::Range<usize>, ratio: &[f64]) {
    for i in range {
        dst[i] *= ratio[map[i] as usize];
    }
}

/// Extension with per-entry div/mod projection (naive baseline).
pub fn extend_divmod(
    dst: &mut [f64],
    dst_cards: &[usize],
    dst_strides: &[usize],
    proj_strides: &[usize],
    ratio: &[f64],
) {
    for (i, x) in dst.iter_mut().enumerate() {
        *x *= ratio[project_divmod(dst_cards, dst_strides, proj_strides, i)];
    }
}

/// Extension with an incremental odometer.
pub fn extend_odometer(dst: &mut [f64], dst_cards: &[usize], proj_strides: &[usize], ratio: &[f64]) {
    let mut odo = ProjectedOdometer::new(dst_cards, proj_strides);
    for x in dst.iter_mut() {
        *x *= ratio[odo.current()];
        odo.step();
    }
}

// ------------------------------------------------------------ run-based --
// Run-compressed kernels (see `mapping::RunMap`): the projected index is
// constant over contiguous runs, so marginalization sums whole slices and
// extension broadcasts one ratio per slice — vectorizable, and the map
// array shrinks by `run_len`×. Used by the Fast-BNI engines (seq, hybrid,
// the XLA packer); comparison baselines keep the per-entry kernels their
// source papers describe. §Perf in EXPERIMENTS.md records the gain.

use crate::jt::mapping::RunMap;

/// `dst[rm.map[r]] += Σ src[r·L .. (r+1)·L]` over the whole table.
#[inline]
pub fn marg_runs(src: &[f64], rm: &RunMap, dst: &mut [f64]) {
    let l = rm.run_len;
    debug_assert_eq!(src.len(), rm.map.len() * l);
    for (r, &m) in rm.map.iter().enumerate() {
        let run = &src[r * l..(r + 1) * l];
        let mut acc = 0.0;
        for &x in run {
            acc += x;
        }
        dst[m as usize] += acc;
    }
}

/// Run-based marginalization over an **entry** range (partial head/tail
/// runs handled) — lets engines keep entry-based chunking.
pub fn marg_runs_range(src: &[f64], rm: &RunMap, entries: std::ops::Range<usize>, dst: &mut [f64]) {
    let l = rm.run_len;
    let (start, end) = (entries.start, entries.end);
    if start >= end {
        return;
    }
    let first_run = start / l;
    let last_run = (end - 1) / l;
    for r in first_run..=last_run {
        let lo = (r * l).max(start);
        let hi = ((r + 1) * l).min(end);
        let mut acc = 0.0;
        for &x in &src[lo..hi] {
            acc += x;
        }
        dst[rm.map[r] as usize] += acc;
    }
}

/// `dst[r·L..(r+1)·L] *= ratio[rm.map[r]]` over the whole table.
#[inline]
pub fn extend_runs(dst: &mut [f64], rm: &RunMap, ratio: &[f64]) {
    let l = rm.run_len;
    debug_assert_eq!(dst.len(), rm.map.len() * l);
    for (r, &m) in rm.map.iter().enumerate() {
        let f = ratio[m as usize];
        for x in &mut dst[r * l..(r + 1) * l] {
            *x *= f;
        }
    }
}

/// Run-based extension over an **entry** range.
pub fn extend_runs_range(dst: &mut [f64], rm: &RunMap, entries: std::ops::Range<usize>, ratio: &[f64]) {
    let l = rm.run_len;
    let (start, end) = (entries.start, entries.end);
    if start >= end {
        return;
    }
    let first_run = start / l;
    let last_run = (end - 1) / l;
    for r in first_run..=last_run {
        let lo = (r * l).max(start);
        let hi = ((r + 1) * l).min(end);
        let f = ratio[rm.map[r] as usize];
        for x in &mut dst[lo..hi] {
            *x *= f;
        }
    }
}

// --------------------------------------------------------- case-major --
// Batched kernels over lane-expanded tables (see `state::BatchState`):
// entry `i` holds its `lanes` per-case values contiguously at
// `i*lanes ..< (i+1)*lanes`. The outer loop walks table entries exactly
// like the single-case kernels, so each cached map/run lookup is amortized
// `lanes`× — the same hoisting move the paper applies to index mappings,
// applied across evidence cases — and the inner per-lane loop is
// unit-stride and auto-vectorizable.
//
// Every kernel takes an **occupancy** `occ <= lanes`: the inner loops
// stop at `occ` while the stride stays `lanes`, so a partial final chunk
// (or a lone `infer` through the batched engine, `occ = 1`) pays
// per-entry work proportional to the cases actually present instead of
// the full lane count. Lanes `occ..lanes` are never read or written.
//
// The per-lane inner loops all bottom out in the explicit SIMD
// micro-kernels of [`crate::jt::simd`] (8/4/1 fixed-width blocks behind
// the on-by-default `simd` feature, plain loops without it). Every one is
// element-wise — no cross-lane reduction — so the SIMD path is
// bit-identical to the scalar path by construction; the test suites here
// and in `simd` pin that byte-for-byte.

use crate::jt::simd;

/// Case-major marginalization: `dst[map[i]*L + b] += src[i*L + b]` for
/// every entry `i` and occupied lane `b < occ`. `dst` must be pre-zeroed
/// (in its occupied lanes).
#[inline]
pub fn marg_with_map_cases(src: &[f64], map: &[u32], lanes: usize, occ: usize, dst: &mut [f64]) {
    debug_assert_eq!(src.len(), map.len() * lanes);
    debug_assert!(occ <= lanes && occ > 0);
    for (i, &m) in map.iter().enumerate() {
        let d = &mut dst[m as usize * lanes..m as usize * lanes + occ];
        let s = &src[i * lanes..i * lanes + occ];
        simd::add_assign(d, s);
    }
}

/// Case-major extension: `dst[i*L + b] *= ratio[map[i]*L + b]` for
/// occupied lanes `b < occ`.
#[inline]
pub fn ext_with_map_cases(dst: &mut [f64], map: &[u32], lanes: usize, occ: usize, ratio: &[f64]) {
    debug_assert_eq!(dst.len(), map.len() * lanes);
    debug_assert!(occ <= lanes && occ > 0);
    for (i, &m) in map.iter().enumerate() {
        let r = &ratio[m as usize * lanes..m as usize * lanes + occ];
        let d = &mut dst[i * lanes..i * lanes + occ];
        simd::mul_assign(d, r);
    }
}

/// Case-major run-based marginalization over an **entry** range (entry
/// indices are in table-entry units, as in [`marg_runs_range`]; the lane
/// expansion is internal), bounded to the occupied lanes.
pub fn marg_runs_cases_range(
    src: &[f64],
    rm: &RunMap,
    lanes: usize,
    occ: usize,
    entries: std::ops::Range<usize>,
    dst: &mut [f64],
) {
    debug_assert!(occ <= lanes && occ > 0);
    let l = rm.run_len;
    let (start, end) = (entries.start, entries.end);
    if start >= end {
        return;
    }
    let first_run = start / l;
    let last_run = (end - 1) / l;
    for r in first_run..=last_run {
        let lo = (r * l).max(start);
        let hi = ((r + 1) * l).min(end);
        let m = rm.map[r] as usize;
        let d = &mut dst[m * lanes..m * lanes + occ];
        for i in lo..hi {
            simd::add_assign(d, &src[i * lanes..i * lanes + occ]);
        }
    }
}

/// Case-major run-based extension over an **entry** range, bounded to the
/// occupied lanes.
pub fn extend_runs_cases_range(
    dst: &mut [f64],
    rm: &RunMap,
    lanes: usize,
    occ: usize,
    entries: std::ops::Range<usize>,
    ratio: &[f64],
) {
    debug_assert!(occ <= lanes && occ > 0);
    let l = rm.run_len;
    let (start, end) = (entries.start, entries.end);
    if start >= end {
        return;
    }
    let first_run = start / l;
    let last_run = (end - 1) / l;
    for r in first_run..=last_run {
        let lo = (r * l).max(start);
        let hi = ((r + 1) * l).min(end);
        let m = rm.map[r] as usize;
        let f = &ratio[m * lanes..m * lanes + occ];
        for i in lo..hi {
            simd::mul_assign(&mut dst[i * lanes..i * lanes + occ], f);
        }
    }
}

/// Per-lane sums of a lane-expanded table: `acc[b] += Σ_i xs[i*L + b]`.
/// Occupancy is `acc.len()` — pass a sub-slice to sum only the occupied
/// lanes of a wider table.
#[inline]
pub fn sum_cases(xs: &[f64], lanes: usize, acc: &mut [f64]) {
    debug_assert!(acc.len() <= lanes && !acc.is_empty());
    debug_assert_eq!(xs.len() % lanes, 0);
    let occ = acc.len();
    for row in xs.chunks_exact(lanes) {
        simd::add_assign(acc, &row[..occ]);
    }
}

/// Per-lane scaling of a lane-expanded table: `xs[i*L + b] *= factors[b]`.
/// Occupancy is `factors.len()` — lanes `factors.len()..lanes` are left
/// untouched.
#[inline]
pub fn scale_cases(xs: &mut [f64], lanes: usize, factors: &[f64]) {
    debug_assert!(factors.len() <= lanes && !factors.is_empty());
    debug_assert_eq!(xs.len() % lanes, 0);
    let occ = factors.len();
    for row in xs.chunks_exact_mut(lanes) {
        simd::mul_assign(&mut row[..occ], factors);
    }
}

/// Case-major **max**-marginalization — the max-product analog of
/// [`marg_with_map_cases`] used by the batched MPE upward pass:
/// `dst[map[i]*L + b] = max(dst[map[i]*L + b], src[i*L + b])` for occupied
/// lanes `b < occ`, with the same strictly-greater comparison as the
/// single-case [`crate::jt::mpe`] kernel. `dst` must be pre-zeroed in its
/// occupied lanes (potentials are nonnegative, so 0 is the identity).
#[inline]
pub fn max_with_map_cases(src: &[f64], map: &[u32], lanes: usize, occ: usize, dst: &mut [f64]) {
    debug_assert_eq!(src.len(), map.len() * lanes);
    debug_assert!(occ <= lanes && occ > 0);
    for (i, &m) in map.iter().enumerate() {
        let d = &mut dst[m as usize * lanes..m as usize * lanes + occ];
        let s = &src[i * lanes..i * lanes + occ];
        simd::max_assign(d, s);
    }
}

/// Per-lane maxima of a lane-expanded table: `acc[b] = max(acc[b],
/// xs[i*L + b])` over every entry `i`. Occupancy is `acc.len()`; seed the
/// accumulator with `0.0` to mirror the single-case peak fold over
/// nonnegative potentials.
#[inline]
pub fn max_cases(xs: &[f64], lanes: usize, acc: &mut [f64]) {
    debug_assert!(acc.len() <= lanes && !acc.is_empty());
    debug_assert_eq!(xs.len() % lanes, 0);
    let occ = acc.len();
    for row in xs.chunks_exact(lanes) {
        simd::max_assign(acc, &row[..occ]);
    }
}

/// Per-lane peak rescale of a lane-expanded table: `xs[i*L + b] /=
/// divisors[b]`. Occupancy is `divisors.len()` — lanes beyond it are left
/// untouched. Division (not multiplication by a reciprocal) so a batched
/// MPE peak rescale is bit-identical to the single-case `*x /= peak`.
#[inline]
pub fn scale_max_cases(xs: &mut [f64], lanes: usize, divisors: &[f64]) {
    debug_assert!(divisors.len() <= lanes && !divisors.is_empty());
    debug_assert_eq!(xs.len() % lanes, 0);
    let occ = divisors.len();
    for row in xs.chunks_exact_mut(lanes) {
        simd::div_assign(&mut row[..occ], divisors);
    }
}

/// Separator update ratio: `out[j] = new[j] / old[j]`, with the standard
/// junction-tree convention `0 / 0 = 0` (entries killed by evidence stay
/// dead).
#[inline]
pub fn ratio(new: &[f64], old: &[f64], out: &mut [f64]) {
    debug_assert_eq!(new.len(), old.len());
    debug_assert_eq!(new.len(), out.len());
    for ((o, &n), &d) in out.iter_mut().zip(new).zip(old) {
        *o = if d != 0.0 { n / d } else { 0.0 };
    }
}

/// Sum of a slice (kept as a function so engines share one definition).
#[inline]
pub fn sum(xs: &[f64]) -> f64 {
    xs.iter().sum()
}

/// Scale a slice in place.
#[inline]
pub fn scale(xs: &mut [f64], factor: f64) {
    for x in xs {
        *x *= factor;
    }
}

/// Zero a slice in place.
#[inline]
pub fn zero(xs: &mut [f64]) {
    for x in xs {
        *x = 0.0;
    }
}

/// Reduce per-worker partial separator buffers into `dst`:
/// `dst[j] = Σ_w partials[w][j]`.
#[inline]
pub fn reduce_partials(partials: &[&[f64]], dst: &mut [f64]) {
    zero(dst);
    for p in partials {
        debug_assert_eq!(p.len(), dst.len());
        for (d, &x) in dst.iter_mut().zip(*p) {
            *d += x;
        }
    }
}

/// View a `&mut [f64]` as atomic u64 slots (same layout; used by the
/// element engine during its scatter phase).
///
/// Sound because `AtomicU64` has the same size/alignment as `u64`/`f64`
/// and the borrow is exclusive, so re-typing the region for the duration
/// of the borrow introduces no aliasing.
pub fn as_atomic(xs: &mut [f64]) -> &[AtomicU64] {
    unsafe { std::slice::from_raw_parts(xs.as_mut_ptr() as *const AtomicU64, xs.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jt::mapping::{build_map, projection_strides, strides};
    use crate::rng::Rng;

    fn setup() -> (Vec<f64>, Vec<usize>, Vec<usize>, Vec<u32>, Vec<usize>, usize) {
        // clique over vars (0,1,2) cards (2,3,4); sep over (1,) card 3
        let src_vars = [0usize, 1, 2];
        let src_cards = vec![2usize, 3, 4];
        let dst_vars = [1usize];
        let dst_cards = [3usize];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let ps = projection_strides(&src_vars, &dst_vars, &dst_cards);
        let ss = strides(&src_cards);
        let mut rng = Rng::new(5);
        let src: Vec<f64> = (0..24).map(|_| rng.f64()).collect();
        (src, src_cards, ss, map, ps, 3)
    }

    #[test]
    fn marg_strategies_agree() {
        let (src, cards, ss, map, ps, dst_len) = setup();
        let mut a = vec![0.0; dst_len];
        let mut b = vec![0.0; dst_len];
        let mut c = vec![0.0; dst_len];
        marg_with_map(&src, &map, &mut a);
        marg_divmod(&src, &cards, &ss, &ps, &mut b);
        marg_odometer(&src, &cards, &ps, &mut c);
        for j in 0..dst_len {
            assert!((a[j] - b[j]).abs() < 1e-12);
            assert!((a[j] - c[j]).abs() < 1e-12);
        }
        // total mass is conserved
        let total: f64 = src.iter().sum();
        assert!((a.iter().sum::<f64>() - total).abs() < 1e-12);
    }

    #[test]
    fn marg_range_partitions_compose() {
        let (src, _, _, map, _, dst_len) = setup();
        let mut whole = vec![0.0; dst_len];
        marg_with_map(&src, &map, &mut whole);
        let mut parts = vec![0.0; dst_len];
        marg_range(&src, &map, 0..7, &mut parts);
        marg_range(&src, &map, 7..20, &mut parts);
        marg_range(&src, &map, 20..24, &mut parts);
        for j in 0..dst_len {
            assert!((whole[j] - parts[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn atomic_marg_matches_serial() {
        let (src, _, _, map, _, dst_len) = setup();
        let mut expect = vec![0.0; dst_len];
        marg_with_map(&src, &map, &mut expect);
        let mut dst = vec![0.0; dst_len];
        {
            let slots = as_atomic(&mut dst);
            atomic_marg_range(&src, &map, 0..24, slots);
        }
        for j in 0..dst_len {
            assert!((dst[j] - expect[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn extend_strategies_agree() {
        let (mut a, cards, ss, map, ps, dst_len) = setup();
        let mut b = a.clone();
        let mut c = a.clone();
        let ratio_tab: Vec<f64> = (0..dst_len).map(|j| (j + 1) as f64).collect();
        extend_with_map(&mut a, &map, &ratio_tab);
        extend_divmod(&mut b, &cards, &ss, &ps, &ratio_tab);
        extend_odometer(&mut c, &cards, &ps, &ratio_tab);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-12);
            assert!((a[i] - c[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn extend_range_partitions_compose() {
        let (mut whole, _, _, map, _, dst_len) = setup();
        let ratio_tab: Vec<f64> = (0..dst_len).map(|j| 0.5 + j as f64).collect();
        let mut parts = whole.clone();
        extend_with_map(&mut whole, &map, &ratio_tab);
        extend_range(&mut parts, &map, 0..11, &ratio_tab);
        extend_range(&mut parts, &map, 11..24, &ratio_tab);
        assert_eq!(whole, parts);
    }

    #[test]
    fn run_kernels_match_entry_kernels() {
        use crate::jt::mapping::build_run_map;
        let src_vars = [0usize, 1, 2];
        let src_cards = [2usize, 3, 4];
        // dst = {1}: run_len = 4
        let dst_vars = [1usize];
        let dst_cards = [3usize];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let rm = build_run_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        assert_eq!(rm.run_len, 4);
        let mut rng = Rng::new(17);
        let src: Vec<f64> = (0..24).map(|_| rng.f64()).collect();

        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        marg_with_map(&src, &map, &mut a);
        marg_runs(&src, &rm, &mut b);
        for j in 0..3 {
            assert!((a[j] - b[j]).abs() < 1e-12);
        }

        // ranged versions compose across arbitrary (non-run-aligned) splits
        let mut c = vec![0.0; 3];
        marg_runs_range(&src, &rm, 0..5, &mut c);
        marg_runs_range(&src, &rm, 5..6, &mut c);
        marg_runs_range(&src, &rm, 6..19, &mut c);
        marg_runs_range(&src, &rm, 19..24, &mut c);
        for j in 0..3 {
            assert!((a[j] - c[j]).abs() < 1e-12, "ranged run marg entry {j}");
        }

        let ratio_tab = [0.5, 2.0, 3.0];
        let mut x = src.clone();
        let mut y = src.clone();
        extend_with_map(&mut x, &map, &ratio_tab);
        extend_runs(&mut y, &rm, &ratio_tab);
        assert_eq!(x, y);
        let mut z = src.clone();
        extend_runs_range(&mut z, &rm, 0..7, &ratio_tab);
        extend_runs_range(&mut z, &rm, 7..24, &ratio_tab);
        assert_eq!(x, z);
    }

    #[test]
    fn run_kernels_empty_and_degenerate_ranges() {
        use crate::jt::mapping::RunMap;
        let rm = RunMap { map: vec![0, 1], run_len: 3 };
        let src = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut dst = [0.0, 0.0];
        marg_runs_range(&src, &rm, 3..3, &mut dst);
        assert_eq!(dst, [0.0, 0.0]);
        let mut t = src;
        extend_runs_range(&mut t, &rm, 0..0, &[2.0, 2.0]);
        assert_eq!(t, src);
    }

    #[test]
    fn case_kernels_match_per_lane_single_case_kernels() {
        use crate::jt::mapping::build_run_map;
        let src_vars = [0usize, 1, 2];
        let src_cards = [2usize, 3, 4];
        let dst_vars = [1usize];
        let dst_cards = [3usize];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let rm = build_run_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let lanes = 5usize;
        let mut rng = Rng::new(23);
        // per-lane source tables + their lane-interleaved expansion
        let lanes_src: Vec<Vec<f64>> = (0..lanes).map(|_| (0..24).map(|_| rng.f64()).collect()).collect();
        let mut batched_src = vec![0.0; 24 * lanes];
        for (b, s) in lanes_src.iter().enumerate() {
            for (i, &x) in s.iter().enumerate() {
                batched_src[i * lanes + b] = x;
            }
        }

        // marg: map-based and run-range-based agree with per-lane marg
        let mut want = vec![vec![0.0; 3]; lanes];
        for (b, s) in lanes_src.iter().enumerate() {
            marg_with_map(s, &map, &mut want[b]);
        }
        let mut got = vec![0.0; 3 * lanes];
        marg_with_map_cases(&batched_src, &map, lanes, lanes, &mut got);
        let mut got_runs = vec![0.0; 3 * lanes];
        marg_runs_cases_range(&batched_src, &rm, lanes, lanes, 0..7, &mut got_runs);
        marg_runs_cases_range(&batched_src, &rm, lanes, lanes, 7..24, &mut got_runs);
        for j in 0..3 {
            for b in 0..lanes {
                assert!((got[j * lanes + b] - want[b][j]).abs() < 1e-12, "map entry {j} lane {b}");
                assert!((got_runs[j * lanes + b] - want[b][j]).abs() < 1e-12, "runs entry {j} lane {b}");
            }
        }

        // per-lane sums and scaling
        let mut sums = vec![0.0; lanes];
        sum_cases(&got, lanes, &mut sums);
        for (b, s) in sums.iter().enumerate() {
            let direct: f64 = lanes_src[b].iter().sum();
            assert!((s - direct).abs() < 1e-12, "lane {b} mass");
        }
        let factors: Vec<f64> = (0..lanes).map(|b| 1.0 / sums[b]).collect();
        let mut scaled = got.clone();
        scale_cases(&mut scaled, lanes, &factors);
        let mut resum = vec![0.0; lanes];
        sum_cases(&scaled, lanes, &mut resum);
        assert!(resum.iter().all(|&s| (s - 1.0).abs() < 1e-12));

        // ext: lane-expanded ratio applied per entry matches per-lane extend
        let ratio_lanes: Vec<f64> = (0..3 * lanes).map(|k| 0.25 + k as f64 * 0.1).collect();
        let mut want_ext = lanes_src.clone();
        for (b, tab) in want_ext.iter_mut().enumerate() {
            let lane_ratio: Vec<f64> = (0..3).map(|j| ratio_lanes[j * lanes + b]).collect();
            extend_with_map(tab, &map, &lane_ratio);
        }
        let mut got_ext = batched_src.clone();
        ext_with_map_cases(&mut got_ext, &map, lanes, lanes, &ratio_lanes);
        let mut got_ext_runs = batched_src.clone();
        extend_runs_cases_range(&mut got_ext_runs, &rm, lanes, lanes, 0..11, &ratio_lanes);
        extend_runs_cases_range(&mut got_ext_runs, &rm, lanes, lanes, 11..24, &ratio_lanes);
        for i in 0..24 {
            for b in 0..lanes {
                assert!((got_ext[i * lanes + b] - want_ext[b][i]).abs() < 1e-12);
                assert!((got_ext_runs[i * lanes + b] - want_ext[b][i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn case_kernels_empty_ranges_are_noops() {
        use crate::jt::mapping::RunMap;
        let rm = RunMap { map: vec![0, 1], run_len: 3 };
        let src = [1.0; 12];
        let mut dst = [0.0; 4];
        marg_runs_cases_range(&src, &rm, 2, 2, 3..3, &mut dst);
        assert_eq!(dst, [0.0; 4]);
        let mut t = src;
        extend_runs_cases_range(&mut t, &rm, 2, 2, 0..0, &[2.0; 4]);
        assert_eq!(t, src);
    }

    #[test]
    fn occupancy_bound_touches_only_occupied_lanes() {
        use crate::jt::mapping::build_run_map;
        let src_vars = [0usize, 1, 2];
        let src_cards = [2usize, 3, 4];
        let dst_vars = [1usize];
        let dst_cards = [3usize];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let rm = build_run_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let (lanes, occ) = (4usize, 2usize);
        let mut rng = Rng::new(31);
        let src: Vec<f64> = (0..24 * lanes).map(|_| rng.f64()).collect();

        // marg at occ < lanes: occupied lanes agree with a full-width run,
        // trailing lanes keep their sentinel
        let mut full = vec![0.0; 3 * lanes];
        marg_with_map_cases(&src, &map, lanes, lanes, &mut full);
        let mut part = vec![-7.0; 3 * lanes];
        for j in 0..3 {
            for b in 0..occ {
                part[j * lanes + b] = 0.0;
            }
        }
        marg_with_map_cases(&src, &map, lanes, occ, &mut part);
        let mut part_runs = part.clone();
        for j in 0..3 {
            for b in 0..occ {
                part_runs[j * lanes + b] = 0.0;
            }
        }
        marg_runs_cases_range(&src, &rm, lanes, occ, 0..9, &mut part_runs);
        marg_runs_cases_range(&src, &rm, lanes, occ, 9..24, &mut part_runs);
        for j in 0..3 {
            for b in 0..lanes {
                let idx = j * lanes + b;
                if b < occ {
                    assert!((part[idx] - full[idx]).abs() < 1e-12, "map entry {j} lane {b}");
                    assert!((part_runs[idx] - full[idx]).abs() < 1e-12, "runs entry {j} lane {b}");
                } else {
                    assert_eq!(part[idx], -7.0, "map stale lane touched at {j}/{b}");
                    assert_eq!(part_runs[idx], -7.0, "runs stale lane touched at {j}/{b}");
                }
            }
        }

        // ext at occ < lanes: trailing lanes pass through untouched
        let ratio: Vec<f64> = (0..3 * lanes).map(|k| 0.5 + k as f64 * 0.25).collect();
        let mut want = src.clone();
        ext_with_map_cases(&mut want, &map, lanes, lanes, &ratio);
        let mut got = src.clone();
        ext_with_map_cases(&mut got, &map, lanes, occ, &ratio);
        let mut got_runs = src.clone();
        extend_runs_cases_range(&mut got_runs, &rm, lanes, occ, 0..5, &ratio);
        extend_runs_cases_range(&mut got_runs, &rm, lanes, occ, 5..24, &ratio);
        for i in 0..24 {
            for b in 0..lanes {
                let idx = i * lanes + b;
                let expect = if b < occ { want[idx] } else { src[idx] };
                assert!((got[idx] - expect).abs() < 1e-12, "ext entry {i} lane {b}");
                assert!((got_runs[idx] - expect).abs() < 1e-12, "ext runs entry {i} lane {b}");
            }
        }

        // sum/scale occupancy comes from the accumulator/factor length
        let mut acc = vec![0.0; occ];
        sum_cases(&src, lanes, &mut acc);
        for (b, a) in acc.iter().enumerate() {
            let direct: f64 = (0..24).map(|i| src[i * lanes + b]).sum();
            assert!((a - direct).abs() < 1e-12, "sum lane {b}");
        }
        let doubles = vec![2.0; occ];
        let mut scaled = src.clone();
        scale_cases(&mut scaled, lanes, &doubles);
        for i in 0..24 {
            for b in 0..lanes {
                let idx = i * lanes + b;
                let expect = if b < occ { src[idx] * 2.0 } else { src[idx] };
                assert!((scaled[idx] - expect).abs() < 1e-12, "scale entry {i} lane {b}");
            }
        }
    }

    /// The bit-exactness contract behind the explicit SIMD layer: every
    /// batched kernel returns the **exact f64 bit pattern** of its scalar
    /// per-lane twin, across lane widths spanning the whole 8/4/1
    /// dispatch ladder and both full and partial occupancy. The source
    /// tables include exact zeros (evidence-killed entries), so the
    /// comparison also covers the degenerate values the sweeps produce.
    /// CI runs this under `--features simd` and `--no-default-features`.
    #[test]
    fn case_kernels_bit_identical_to_scalar_per_lane_at_every_width() {
        use crate::jt::mapping::build_run_map;
        let src_vars = [0usize, 1, 2];
        let src_cards = [2usize, 3, 4];
        let dst_vars = [1usize];
        let dst_cards = [3usize];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let rm = build_run_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let n = 24usize;
        for &lanes in &[1usize, 3, 4, 7, 8, 64] {
            for occ in [1, lanes / 2, lanes] {
                if occ == 0 || occ > lanes {
                    continue;
                }
                let mut rng = Rng::new(0xB17 ^ ((lanes as u64) << 16) ^ occ as u64);
                // per-lane scalar tables (lane b of the interleaved arena),
                // with exact zeros sprinkled in
                let lanes_src: Vec<Vec<f64>> = (0..occ)
                    .map(|_| (0..n).map(|_| if rng.f64() < 0.2 { 0.0 } else { rng.f64() }).collect())
                    .collect();
                let mut batched = vec![0.0; n * lanes];
                for (b, s) in lanes_src.iter().enumerate() {
                    for (i, &x) in s.iter().enumerate() {
                        batched[i * lanes + b] = x;
                    }
                }

                // marg (map + runs): scalar oracle is the single-case kernel
                let mut want_marg = vec![vec![0.0; 3]; occ];
                for (b, s) in lanes_src.iter().enumerate() {
                    marg_with_map(s, &map, &mut want_marg[b]);
                }
                let mut got = vec![0.0; 3 * lanes];
                marg_with_map_cases(&batched, &map, lanes, occ, &mut got);
                let mut got_runs = vec![0.0; 3 * lanes];
                marg_runs_cases_range(&batched, &rm, lanes, occ, 0..n, &mut got_runs);
                for j in 0..3 {
                    for b in 0..occ {
                        let w = want_marg[b][j].to_bits();
                        assert_eq!(got[j * lanes + b].to_bits(), w, "marg L={lanes} occ={occ} {j}/{b}");
                        assert_eq!(got_runs[j * lanes + b].to_bits(), w, "marg runs L={lanes} occ={occ} {j}/{b}");
                    }
                }

                // max (map + reduce): same shape, strictly-greater compare
                let mut want_max = vec![vec![0.0; 3]; occ];
                for (b, s) in lanes_src.iter().enumerate() {
                    for (i, &m) in map.iter().enumerate() {
                        if s[i] > want_max[b][m as usize] {
                            want_max[b][m as usize] = s[i];
                        }
                    }
                }
                let mut got_max = vec![0.0; 3 * lanes];
                max_with_map_cases(&batched, &map, lanes, occ, &mut got_max);
                for j in 0..3 {
                    for b in 0..occ {
                        assert_eq!(
                            got_max[j * lanes + b].to_bits(),
                            want_max[b][j].to_bits(),
                            "max L={lanes} occ={occ} {j}/{b}"
                        );
                    }
                }
                let mut peaks = vec![0.0; occ];
                max_cases(&batched, lanes, &mut peaks);
                for (b, peak) in peaks.iter().enumerate() {
                    let want = lanes_src[b].iter().cloned().fold(0.0f64, f64::max);
                    assert_eq!(peak.to_bits(), want.to_bits(), "peak L={lanes} occ={occ} lane {b}");
                }

                // sum / scale / peak-divide: oracle is the scalar fold
                let mut sums = vec![0.0; occ];
                sum_cases(&batched, lanes, &mut sums);
                for (b, s) in sums.iter().enumerate() {
                    assert_eq!(s.to_bits(), lanes_src[b].iter().sum::<f64>().to_bits(), "sum lane {b}");
                }
                let factors: Vec<f64> = (0..occ).map(|b| 0.5 + b as f64).collect();
                let mut scaled = batched.clone();
                scale_cases(&mut scaled, lanes, &factors);
                let divisors: Vec<f64> = peaks.iter().map(|p| p.max(1.0)).collect();
                let mut divided = batched.clone();
                scale_max_cases(&mut divided, lanes, &divisors);
                // ext: lane-expanded ratio, zeros included
                let ratio_lanes: Vec<f64> =
                    (0..3 * lanes).map(|k| if k % 5 == 0 { 0.0 } else { 0.25 + k as f64 * 0.1 }).collect();
                let mut extended = batched.clone();
                ext_with_map_cases(&mut extended, &map, lanes, occ, &ratio_lanes);
                let mut extended_runs = batched.clone();
                extend_runs_cases_range(&mut extended_runs, &rm, lanes, occ, 0..n, &ratio_lanes);
                for i in 0..n {
                    for b in 0..occ {
                        let idx = i * lanes + b;
                        let x = lanes_src[b][i];
                        assert_eq!(scaled[idx].to_bits(), (x * factors[b]).to_bits(), "scale {i}/{b}");
                        assert_eq!(divided[idx].to_bits(), (x / divisors[b]).to_bits(), "divide {i}/{b}");
                        let r = ratio_lanes[map[i] as usize * lanes + b];
                        assert_eq!(extended[idx].to_bits(), (x * r).to_bits(), "ext {i}/{b}");
                        assert_eq!(extended_runs[idx].to_bits(), (x * r).to_bits(), "ext runs {i}/{b}");
                    }
                }
            }
        }
    }

    #[test]
    fn max_kernels_leave_unoccupied_lanes_untouched() {
        let (lanes, occ) = (4usize, 2usize);
        // 2 entries → 1 sep slot (map all-zero)
        let map = vec![0u32, 0];
        let src = [1.0, 9.0, 9.0, 9.0, 3.0, 2.0, 9.0, 9.0];
        let mut dst = vec![-7.0; lanes];
        for b in 0..occ {
            dst[b] = 0.0;
        }
        max_with_map_cases(&src, &map, lanes, occ, &mut dst);
        assert_eq!(dst, vec![3.0, 9.0, -7.0, -7.0]);
        let mut xs = src;
        scale_max_cases(&mut xs, lanes, &[3.0, 2.0]);
        assert_eq!(xs[0], 1.0 / 3.0);
        assert_eq!(xs[1], 4.5);
        assert_eq!(xs[2], 9.0, "unoccupied lane scaled");
        let mut peaks = vec![0.0; occ];
        max_cases(&src, lanes, &mut peaks);
        assert_eq!(peaks, vec![3.0, 9.0]);
    }

    #[test]
    fn ratio_zero_over_zero_is_zero() {
        let mut out = vec![f64::NAN; 3];
        ratio(&[1.0, 0.0, 2.0], &[2.0, 0.0, 0.5], &mut out);
        assert_eq!(out, vec![0.5, 0.0, 4.0]);
    }

    #[test]
    fn reduce_partials_sums_workers() {
        let p1 = vec![1.0, 2.0];
        let p2 = vec![10.0, 20.0];
        let mut dst = vec![99.0, 99.0];
        reduce_partials(&[&p1, &p2], &mut dst);
        assert_eq!(dst, vec![11.0, 22.0]);
    }

    #[test]
    fn atomic_add_is_exactly_float_add() {
        let slot = AtomicU64::new(1.5f64.to_bits());
        atomic_add_f64(&slot, 2.25);
        assert_eq!(f64::from_bits(slot.load(Ordering::Relaxed)), 3.75);
    }

    #[test]
    fn zero_scale_sum_roundtrip() {
        let mut v = vec![1.0, 2.0, 3.0];
        scale(&mut v, 2.0);
        assert_eq!(sum(&v), 12.0);
        zero(&mut v);
        assert_eq!(sum(&v), 0.0);
    }
}
