//! Per-case mutable propagation state, arena-backed.
//!
//! The compiled [`crate::jt::tree::JunctionTree`] is immutable and shared;
//! each test case gets a [`TreeState`] holding its clique and separator
//! tables. Since PR 4 the tables live in **one contiguous arena** (a
//! single flat `Vec<f64>`) addressed through an [`ArenaLayout`] computed
//! at tree-compile time, instead of a `Vec<Vec<f64>>` per table.
//!
//! ## Arena layout invariants
//!
//! * The arena is laid out **cliques first, then separators**, each table
//!   occupying the contiguous half-open range its layout entry records:
//!   `clique_range(c) = clique_off[c] .. clique_off[c] + cliques[c].len`,
//!   then `sep_range(s)` analogously after the last clique. Ranges are
//!   disjoint, ordered, and tile `0..total` exactly — property-tested in
//!   `tests/jt_invariants.rs`.
//! * Offsets depend only on the compiled tree, so every `TreeState` (and
//!   every lane of a [`BatchState`]) of one tree shares one layout
//!   (`Arc`), and raw kernels can address sub-slices of one allocation.
//! * The tree's flat prototype (`JunctionTree::arena_proto`) uses the same
//!   layout with clique ranges holding the CPT products and separator
//!   ranges holding all-ones, so **reset is a single `copy_from_slice`**
//!   and replica/clone spawn is one memcpy — per-case allocation is one of
//!   the overheads the paper's baselines suffer from (EXPERIMENTS.md
//!   §Perf).
//! * A [`BatchState`] stores `lanes` cases **case-major per entry**: arena
//!   entry `i` of case `b` lives at `i * lanes + b`, so the `lanes` values
//!   of one table entry are contiguous. Batched kernels
//!   (`ops::marg_runs_cases` & co.) amortize each index-map lookup across
//!   all lanes and keep the inner loop unit-stride.

use std::ops::Range;
use std::sync::Arc;

use crate::jt::tree::JunctionTree;

/// (offset, len) table for every clique and separator in one flat arena.
///
/// Built once per compiled tree ([`ArenaLayout::build`]); shared by every
/// state via `Arc`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArenaLayout {
    /// Arena offset of each clique table.
    pub clique_off: Vec<usize>,
    /// Length of each clique table.
    pub clique_len: Vec<usize>,
    /// Arena offset of each separator table (all after the cliques).
    pub sep_off: Vec<usize>,
    /// Length of each separator table.
    pub sep_len: Vec<usize>,
    /// Total arena entries (= Σ clique lens + Σ sep lens).
    pub total: usize,
}

impl ArenaLayout {
    /// Lay out tables contiguously: cliques in index order, then seps.
    pub fn build(clique_lens: &[usize], sep_lens: &[usize]) -> Self {
        let mut clique_off = Vec::with_capacity(clique_lens.len());
        let mut off = 0usize;
        for &len in clique_lens {
            clique_off.push(off);
            off += len;
        }
        let mut sep_off = Vec::with_capacity(sep_lens.len());
        for &len in sep_lens {
            sep_off.push(off);
            off += len;
        }
        ArenaLayout {
            clique_off,
            clique_len: clique_lens.to_vec(),
            sep_off,
            sep_len: sep_lens.to_vec(),
            total: off,
        }
    }

    /// Arena range of clique `c`.
    #[inline]
    pub fn clique_range(&self, c: usize) -> Range<usize> {
        let off = self.clique_off[c];
        off..off + self.clique_len[c]
    }

    /// Arena range of separator `s`.
    #[inline]
    pub fn sep_range(&self, s: usize) -> Range<usize> {
        let off = self.sep_off[s];
        off..off + self.sep_len[s]
    }

    /// Number of cliques.
    pub fn n_cliques(&self) -> usize {
        self.clique_off.len()
    }

    /// Number of separators.
    pub fn n_seps(&self) -> usize {
        self.sep_off.len()
    }
}

/// Mutable potential tables for one inference case: one flat arena plus
/// the accumulated log normalization.
#[derive(Clone, Debug)]
pub struct TreeState {
    layout: Arc<ArenaLayout>,
    data: Vec<f64>,
    /// Accumulated log normalization: after collect, `log_z = ln P(e)`.
    pub log_z: f64,
}

impl TreeState {
    /// Allocate a state initialized from the prototype potentials (one
    /// memcpy of the tree's flat prototype).
    pub fn fresh(jt: &JunctionTree) -> Self {
        TreeState { layout: Arc::clone(&jt.layout), data: jt.arena_proto.clone(), log_z: 0.0 }
    }

    /// A zero-size placeholder state for engines that never touch clique
    /// tables (the sampling tier has no compiled tree, but the `Engine`
    /// trait still threads a `&mut TreeState` through `infer`). Holds an
    /// empty layout and arena; any table access would panic, which is the
    /// correct failure mode for code that wrongly assumes an exact tree.
    pub fn detached() -> Self {
        TreeState { layout: Arc::new(ArenaLayout::build(&[], &[])), data: Vec::new(), log_z: 0.0 }
    }

    /// Reset to the prototype without reallocating — a single
    /// `copy_from_slice` over the whole arena.
    pub fn reset(&mut self, jt: &JunctionTree) {
        debug_assert_eq!(self.data.len(), jt.arena_proto.len());
        self.data.copy_from_slice(&jt.arena_proto);
        self.log_z = 0.0;
    }

    /// The layout shared with the tree.
    #[inline]
    pub fn layout(&self) -> &Arc<ArenaLayout> {
        &self.layout
    }

    /// The whole arena.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// The whole arena, mutable.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Clique `c`'s table.
    #[inline]
    pub fn clique(&self, c: usize) -> &[f64] {
        &self.data[self.layout.clique_range(c)]
    }

    /// Clique `c`'s table, mutable.
    #[inline]
    pub fn clique_mut(&mut self, c: usize) -> &mut [f64] {
        let r = self.layout.clique_range(c);
        &mut self.data[r]
    }

    /// Separator `s`'s table.
    #[inline]
    pub fn sep(&self, s: usize) -> &[f64] {
        &self.data[self.layout.sep_range(s)]
    }

    /// Separator `s`'s table, mutable.
    #[inline]
    pub fn sep_mut(&mut self, s: usize) -> &mut [f64] {
        let r = self.layout.sep_range(s);
        &mut self.data[r]
    }

    /// Total number of f64 entries held (cliques + separators).
    pub fn n_entries(&self) -> usize {
        self.data.len()
    }
}

/// Mutable state for `lanes` cases propagated in one sweep.
///
/// Entry `i` of the arena holds its `lanes` per-case values contiguously
/// at `i * lanes ..< (i + 1) * lanes` (see the module docs). The broadcast
/// prototype is kept alongside the data so [`BatchState::reset`] is one
/// `copy_from_slice`, exactly like the single-case path.
#[derive(Clone, Debug)]
pub struct BatchState {
    layout: Arc<ArenaLayout>,
    lanes: usize,
    data: Vec<f64>,
    /// Lane-broadcast prototype (`proto[i*lanes + b] = arena_proto[i]`).
    proto: Vec<f64>,
    /// Per-lane accumulated log normalization.
    pub log_z: Vec<f64>,
}

impl BatchState {
    /// Allocate a batch state with `lanes` cases, all at the prototype.
    pub fn fresh(jt: &JunctionTree, lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let mut proto = Vec::with_capacity(jt.arena_proto.len() * lanes);
        for &x in &jt.arena_proto {
            for _ in 0..lanes {
                proto.push(x);
            }
        }
        BatchState {
            layout: Arc::clone(&jt.layout),
            lanes,
            data: proto.clone(),
            proto,
            log_z: vec![0.0; lanes],
        }
    }

    /// Number of lanes (cases per sweep).
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The layout shared with the tree.
    #[inline]
    pub fn layout(&self) -> &Arc<ArenaLayout> {
        &self.layout
    }

    /// Reset every lane to the prototype: one `copy_from_slice`.
    pub fn reset(&mut self) {
        self.data.copy_from_slice(&self.proto);
        for z in &mut self.log_z {
            *z = 0.0;
        }
    }

    /// The whole lane-expanded arena.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// The whole lane-expanded arena, mutable.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Clique `c`'s lane-expanded table (`len * lanes` values).
    #[inline]
    pub fn clique(&self, c: usize) -> &[f64] {
        let r = self.layout.clique_range(c);
        &self.data[r.start * self.lanes..r.end * self.lanes]
    }

    /// Clique `c`'s lane-expanded table, mutable.
    #[inline]
    pub fn clique_mut(&mut self, c: usize) -> &mut [f64] {
        let r = self.layout.clique_range(c);
        &mut self.data[r.start * self.lanes..r.end * self.lanes]
    }

    /// Separator `s`'s lane-expanded table.
    #[inline]
    pub fn sep(&self, s: usize) -> &[f64] {
        let r = self.layout.sep_range(s);
        &self.data[r.start * self.lanes..r.end * self.lanes]
    }

    /// Separator `s`'s lane-expanded table, mutable.
    #[inline]
    pub fn sep_mut(&mut self, s: usize) -> &mut [f64] {
        let r = self.layout.sep_range(s);
        &mut self.data[r.start * self.lanes..r.end * self.lanes]
    }

    /// One lane of clique `c`, gathered into a fresh Vec (test/debug aid;
    /// the hot path never gathers).
    pub fn lane_of_clique(&self, c: usize, lane: usize) -> Vec<f64> {
        self.clique(c).iter().skip(lane).step_by(self.lanes).copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::triangulate::TriangulationHeuristic;

    fn asia_tree() -> JunctionTree {
        JunctionTree::compile(&embedded::asia(), TriangulationHeuristic::MinFill).unwrap()
    }

    #[test]
    fn layout_tiles_the_arena_exactly() {
        let jt = asia_tree();
        let l = &jt.layout;
        let mut expect = 0usize;
        for c in 0..l.n_cliques() {
            assert_eq!(l.clique_range(c).start, expect);
            expect = l.clique_range(c).end;
        }
        for s in 0..l.n_seps() {
            assert_eq!(l.sep_range(s).start, expect);
            expect = l.sep_range(s).end;
        }
        assert_eq!(expect, l.total);
        assert_eq!(l.total, jt.total_clique_entries() + jt.total_sep_entries());
    }

    #[test]
    fn fresh_matches_prototype() {
        let jt = asia_tree();
        let st = TreeState::fresh(&jt);
        assert_eq!(st.layout().n_cliques(), jt.n_cliques());
        assert_eq!(st.layout().n_seps(), jt.seps.len());
        for c in 0..jt.n_cliques() {
            assert_eq!(st.clique(c), jt.proto_clique(c));
        }
        for s in 0..jt.seps.len() {
            assert!(st.sep(s).iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn reset_restores_after_mutation() {
        let jt = asia_tree();
        let mut st = TreeState::fresh(&jt);
        for x in st.data_mut() {
            *x = 42.0;
        }
        st.log_z = 3.0;
        st.reset(&jt);
        for c in 0..jt.n_cliques() {
            assert_eq!(st.clique(c), jt.proto_clique(c));
        }
        assert_eq!(st.sep(0)[0], 1.0);
        assert_eq!(st.log_z, 0.0);
    }

    #[test]
    fn entry_count_matches_tree() {
        let jt = asia_tree();
        let st = TreeState::fresh(&jt);
        assert_eq!(st.n_entries(), jt.total_clique_entries() + jt.total_sep_entries());
    }

    #[test]
    fn mutable_accessors_write_through_to_the_arena() {
        let jt = asia_tree();
        let mut st = TreeState::fresh(&jt);
        st.clique_mut(2)[0] = 7.5;
        st.sep_mut(1)[0] = 2.5;
        let cr = st.layout().clique_range(2);
        let sr = st.layout().sep_range(1);
        assert_eq!(st.data()[cr.start], 7.5);
        assert_eq!(st.data()[sr.start], 2.5);
    }

    #[test]
    fn batch_state_lanes_are_independent_and_reset_clean() {
        let jt = asia_tree();
        let mut bs = BatchState::fresh(&jt, 3);
        assert_eq!(bs.lanes(), 3);
        assert_eq!(bs.data().len(), jt.layout.total * 3);
        // every lane starts at the prototype
        for c in 0..jt.n_cliques() {
            for lane in 0..3 {
                assert_eq!(bs.lane_of_clique(c, lane), jt.proto_clique(c));
            }
        }
        // scribble over lane 1 only, then reset: no stale lane survives
        let lanes = bs.lanes();
        for chunk in bs.data_mut().chunks_mut(lanes) {
            chunk[1] = -9.0;
        }
        bs.log_z[1] = 5.0;
        assert_ne!(bs.lane_of_clique(0, 1), jt.proto_clique(0));
        bs.reset();
        for lane in 0..3 {
            for c in 0..jt.n_cliques() {
                assert_eq!(bs.lane_of_clique(c, lane), jt.proto_clique(c));
            }
        }
        assert_eq!(bs.log_z, vec![0.0; 3]);
    }
}
