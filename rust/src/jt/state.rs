//! Per-case mutable propagation state.
//!
//! The compiled [`crate::jt::tree::JunctionTree`] is immutable and shared;
//! each test case gets a [`TreeState`] holding its clique and separator
//! tables. States are pooled and **reset** (memcpy from the prototype)
//! rather than reallocated — per-case allocation is one of the overheads
//! the paper's baselines suffer from, and its absence is part of the
//! Fast-BNI hot path (see EXPERIMENTS.md §Perf).

use crate::jt::tree::JunctionTree;

/// Mutable potential tables for one inference case.
#[derive(Clone, Debug)]
pub struct TreeState {
    /// Clique tables, aligned with `jt.cliques`.
    pub cliques: Vec<Vec<f64>>,
    /// Separator tables, aligned with `jt.seps`; start at all-ones.
    pub seps: Vec<Vec<f64>>,
    /// Accumulated log normalization: after collect, `log_z = ln P(e)`.
    pub log_z: f64,
}

impl TreeState {
    /// Allocate a state initialized from the prototype potentials.
    pub fn fresh(jt: &JunctionTree) -> Self {
        TreeState {
            cliques: jt.prototype.clone(),
            seps: jt.seps.iter().map(|s| vec![1.0; s.len]).collect(),
            log_z: 0.0,
        }
    }

    /// Reset to the prototype without reallocating.
    pub fn reset(&mut self, jt: &JunctionTree) {
        for (dst, src) in self.cliques.iter_mut().zip(&jt.prototype) {
            dst.copy_from_slice(src);
        }
        for sep in &mut self.seps {
            for x in sep.iter_mut() {
                *x = 1.0;
            }
        }
        self.log_z = 0.0;
    }

    /// Total number of f64 entries held (cliques + separators).
    pub fn n_entries(&self) -> usize {
        self.cliques.iter().map(|c| c.len()).sum::<usize>() + self.seps.iter().map(|s| s.len()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn fresh_matches_prototype() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let st = TreeState::fresh(&jt);
        assert_eq!(st.cliques.len(), jt.n_cliques());
        assert_eq!(st.seps.len(), jt.seps.len());
        for (c, p) in st.cliques.iter().zip(&jt.prototype) {
            assert_eq!(c, p);
        }
        assert!(st.seps.iter().all(|s| s.iter().all(|&x| x == 1.0)));
    }

    #[test]
    fn reset_restores_after_mutation() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut st = TreeState::fresh(&jt);
        for c in &mut st.cliques {
            for x in c.iter_mut() {
                *x = 42.0;
            }
        }
        st.seps[0][0] = 7.0;
        st.log_z = 3.0;
        st.reset(&jt);
        for (c, p) in st.cliques.iter().zip(&jt.prototype) {
            assert_eq!(c, p);
        }
        assert_eq!(st.seps[0][0], 1.0);
        assert_eq!(st.log_z, 0.0);
    }

    #[test]
    fn entry_count_matches_tree() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let st = TreeState::fresh(&jt);
        assert_eq!(st.n_entries(), jt.total_clique_entries() + jt.total_sep_entries());
    }
}
