//! Most Probable Explanation (MPE) via max-product message passing.
//!
//! The same junction tree that answers sum-product queries answers
//! max-product ones: replace marginalization's Σ with max in the upward
//! pass, then decode greedily from the root — each clique's restricted
//! argmax (consistent with the variables already fixed by its parent) is
//! globally optimal by the max-calibration property. An extension beyond
//! the poster (exact MPE is the other canonical JT workload), reusing the
//! compiled tree, evidence entry and schedules.
//!
//! Two drivers share one decode: [`most_probable_explanation`] runs a
//! single case over a [`TreeState`]; [`most_probable_explanation_batch`]
//! runs whole caseloads over a lane-interleaved [`BatchState`] through the
//! case-major max kernels (`ops::max_with_map_cases` & co.), so MPE rides
//! the same SIMD lane layer as sum-product batching. Every kernel in the
//! max-pass is per-lane element-wise, so each lane's answer is
//! **bit-identical** to the single-case run of the same evidence — pinned
//! by the oracle tests below, not by prose.

use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::schedule::Schedule;
use crate::jt::state::{BatchState, TreeState};
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// An MPE solution.
#[derive(Clone, Debug)]
pub struct MpeResult {
    /// State index per variable (evidence variables at their observed
    /// states).
    pub assignment: Vec<usize>,
    /// `ln P(assignment)` — joint probability of the completion
    /// (includes the evidence).
    pub log_prob: f64,
}

/// `dst[map[i]] = max(dst[map[i]], src[i])` — the max-product analog of
/// marginalization.
fn max_with_map(src: &[f64], map: &[u32], dst: &mut [f64]) {
    for (x, &m) in src.iter().zip(map) {
        let d = &mut dst[m as usize];
        if *x > *d {
            *d = *x;
        }
    }
}

/// Compute the MPE for `ev` on a calibrated tree state.
///
/// `state` is reset, evidence is applied, one upward max-pass runs, and
/// the assignment is decoded root-to-leaves. The reported `log_prob` is
/// recomputed exactly from the CPTs ([`exact_log_prob`]), so the in-pass
/// peak scaling never leaks into the value.
pub fn most_probable_explanation(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut TreeState,
    ev: &Evidence,
) -> Result<MpeResult> {
    state.reset(jt);
    ev.apply(jt, state);

    // upward max-pass
    let mut new_sep_buf = vec![0.0f64; jt.seps.iter().map(|s| s.len).max().unwrap_or(1)];
    let mut ratio_buf = new_sep_buf.clone();
    for layer in &sched.up_layers {
        for msg in layer {
            let sep_meta = &jt.seps[msg.sep];
            let new_sep = &mut new_sep_buf[..sep_meta.len];
            for x in new_sep.iter_mut() {
                *x = 0.0;
            }
            let maps = &jt.edge_maps[msg.sep];
            max_with_map(state.clique(msg.from), maps.from(sep_meta, msg.from), new_sep);
            // scale by the max for numerical stability
            let peak = new_sep.iter().cloned().fold(0.0f64, f64::max);
            if peak == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            for x in new_sep.iter_mut() {
                *x /= peak;
            }
            let ratio = &mut ratio_buf[..sep_meta.len];
            ops::ratio(new_sep, state.sep(msg.sep), ratio);
            state.sep_mut(msg.sep).copy_from_slice(new_sep);
            ops::extend_with_map(state.clique_mut(msg.to), maps.from(sep_meta, msg.to), ratio);
        }
    }

    let assignment = decode(jt, sched, |c, i| state.clique(c)[i])?;
    let log_prob = exact_log_prob(jt, &assignment)?;
    Ok(MpeResult { assignment, log_prob })
}

/// Compute the MPE for every case in `cases` through a lane-interleaved
/// [`BatchState`], `state.lanes()` cases per sweep.
///
/// Each chunk runs one upward max-pass over all its lanes at once via the
/// case-major kernels; an infeasible lane (some message peaks at 0) is
/// flagged and keeps propagating zeros with divisor 1 — the same
/// per-element op sequence as live lanes, so it cannot perturb them.
/// Results come back in case order; lane `b`'s answer is bit-identical to
/// [`most_probable_explanation`] on the same evidence.
pub fn most_probable_explanation_batch(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut BatchState,
    cases: &[Evidence],
) -> Vec<Result<MpeResult>> {
    let lanes = state.lanes();
    let max_sep = jt.seps.iter().map(|s| s.len).max().unwrap_or(1);
    let mut new_sep_buf = vec![0.0f64; max_sep * lanes];
    let mut ratio_buf = new_sep_buf.clone();
    let mut out = Vec::with_capacity(cases.len());
    for chunk in cases.chunks(lanes) {
        mpe_chunk(jt, sched, state, chunk, &mut new_sep_buf, &mut ratio_buf, &mut out);
    }
    out
}

/// One batched upward max-pass + per-lane decode for `chunk.len() ≤ lanes`
/// cases, appending one `Result` per case to `out`.
fn mpe_chunk(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut BatchState,
    chunk: &[Evidence],
    new_sep_buf: &mut [f64],
    ratio_buf: &mut [f64],
    out: &mut Vec<Result<MpeResult>>,
) {
    let lanes = state.lanes();
    let occ = chunk.len();
    state.reset();
    for (b, ev) in chunk.iter().enumerate() {
        ev.apply_lane(jt, state.data_mut(), lanes, b);
    }
    let mut failed = vec![false; occ];
    let mut peaks = vec![0.0f64; occ];
    let mut divisors = vec![1.0f64; occ];
    for layer in &sched.up_layers {
        for msg in layer {
            let sep_meta = &jt.seps[msg.sep];
            let w = sep_meta.len * lanes;
            let new_sep = &mut new_sep_buf[..w];
            for x in new_sep.iter_mut() {
                *x = 0.0;
            }
            let maps = &jt.edge_maps[msg.sep];
            ops::max_with_map_cases(state.clique(msg.from), maps.from(sep_meta, msg.from), lanes, occ, new_sep);
            for p in peaks.iter_mut() {
                *p = 0.0;
            }
            ops::max_cases(new_sep, lanes, &mut peaks);
            for (b, &p) in peaks.iter().enumerate() {
                if p == 0.0 {
                    failed[b] = true;
                    divisors[b] = 1.0;
                } else {
                    divisors[b] = p;
                }
            }
            ops::scale_max_cases(new_sep, lanes, &divisors);
            let ratio = &mut ratio_buf[..w];
            let old = state.sep(msg.sep);
            for e in 0..sep_meta.len {
                let o = e * lanes;
                ops::ratio(&new_sep[o..o + occ], &old[o..o + occ], &mut ratio[o..o + occ]);
            }
            // copy only the occupied lanes back; lanes occ..lanes keep
            // their prototype ones (never read — reset wipes them)
            let sep = state.sep_mut(msg.sep);
            for e in 0..sep_meta.len {
                let o = e * lanes;
                sep[o..o + occ].copy_from_slice(&new_sep[o..o + occ]);
            }
            ops::ext_with_map_cases(state.clique_mut(msg.to), maps.from(sep_meta, msg.to), lanes, occ, ratio);
        }
    }
    for (b, &failed_b) in failed.iter().enumerate() {
        if failed_b {
            out.push(Err(Error::InconsistentEvidence));
            continue;
        }
        let r = decode(jt, sched, |c, i| state.clique(c)[i * lanes + b]).and_then(|assignment| {
            let log_prob = exact_log_prob(jt, &assignment)?;
            Ok(MpeResult { assignment, log_prob })
        });
        out.push(r);
    }
}

/// Greedy root-to-leaves decode of a max-calibrated tree: each clique's
/// restricted argmax (consistent with already-fixed variables) in BFS
/// order from the schedule roots. `value(c, i)` reads entry `i` of clique
/// `c`'s calibrated table — an accessor closure so the single-case arena
/// and one lane of a [`BatchState`] share the exact comparison sequence
/// (argmax tie-breaks included).
fn decode(jt: &JunctionTree, sched: &Schedule, value: impl Fn(usize, usize) -> f64) -> Result<Vec<usize>> {
    let n = jt.net.n();
    let mut assignment = vec![usize::MAX; n];
    let mut order: Vec<usize> = Vec::with_capacity(jt.n_cliques());
    for &r in &sched.roots {
        order.push(r);
    }
    let mut qi = 0usize;
    while qi < order.len() {
        let c = order[qi];
        qi += 1;
        for &(ch, _) in &sched.children[c] {
            order.push(ch);
        }
    }

    for &c in &order {
        let clique = &jt.cliques[c];
        // restricted argmax: entries whose digits agree with already-fixed vars
        let mut best_idx = usize::MAX;
        let mut best_val = -1.0f64;
        'entry: for i in 0..clique.len {
            let x = value(c, i);
            if x <= best_val {
                continue;
            }
            for (pos, &v) in clique.vars.iter().enumerate() {
                if assignment[v] != usize::MAX {
                    let digit = (i / clique.strides[pos]) % clique.cards[pos];
                    if digit != assignment[v] {
                        continue 'entry;
                    }
                }
            }
            best_val = x;
            best_idx = i;
        }
        if best_idx == usize::MAX || best_val <= 0.0 {
            return Err(Error::InconsistentEvidence);
        }
        for (pos, &v) in clique.vars.iter().enumerate() {
            if assignment[v] == usize::MAX {
                assignment[v] = (best_idx / clique.strides[pos]) % clique.cards[pos];
            }
        }
    }
    debug_assert!(assignment.iter().all(|&s| s != usize::MAX));
    Ok(assignment)
}

/// Exact joint log-probability of a full assignment, recomputed from the
/// CPTs. Both MPE drivers report this instead of the in-pass scaled
/// maximum — cheap, removes any residual scaling approximation, and makes
/// equal assignments yield bitwise-equal `log_prob`.
fn exact_log_prob(jt: &JunctionTree, assignment: &[usize]) -> Result<f64> {
    let cards = jt.net.cards();
    let mut logp = 0.0f64;
    for v in 0..jt.net.n() {
        let cpt = &jt.net.cpts[v];
        let config: Vec<usize> = cpt.parents.iter().map(|&p| assignment[p]).collect();
        let p = cpt.row(&config, &cards)[assignment[v]];
        if p == 0.0 {
            return Err(Error::InconsistentEvidence);
        }
        logp += p.ln();
    }
    Ok(logp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};
    use crate::jt::schedule::RootStrategy;
    use crate::jt::triangulate::TriangulationHeuristic;

    /// Brute-force MPE by joint enumeration (small nets only).
    fn brute_mpe(net: &crate::bn::network::Network, ev: &Evidence) -> (Vec<usize>, f64) {
        let cards = net.cards();
        let order = net.topo_order().unwrap();
        let mut best = (Vec::new(), -1.0f64);
        let mut assignment = vec![0usize; net.n()];
        'outer: loop {
            let consistent = ev.obs.iter().all(|&(v, s)| assignment[v] == s);
            if consistent {
                let mut p = 1.0f64;
                for &v in &order {
                    let cpt = &net.cpts[v];
                    let config: Vec<usize> = cpt.parents.iter().map(|&q| assignment[q]).collect();
                    p *= cpt.row(&config, &cards)[assignment[v]];
                }
                if p > best.1 {
                    best = (assignment.clone(), p);
                }
            }
            for i in (0..net.n()).rev() {
                assignment[i] += 1;
                if assignment[i] < cards[i] {
                    continue 'outer;
                }
                assignment[i] = 0;
                if i == 0 {
                    break 'outer;
                }
            }
        }
        best
    }

    fn check_net(net: &crate::bn::network::Network, ev: &Evidence) {
        let jt = JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        let got = most_probable_explanation(&jt, &sched, &mut state, ev).unwrap();
        let (want_assign, want_p) = brute_mpe(net, ev);
        assert!(
            (got.log_prob - want_p.ln()).abs() < 1e-9,
            "MPE prob mismatch: {} vs {} (assignment {:?} vs {:?})",
            got.log_prob,
            want_p.ln(),
            got.assignment,
            want_assign
        );
        // evidence respected
        for &(v, s) in &ev.obs {
            assert_eq!(got.assignment[v], s);
        }
    }

    #[test]
    fn mpe_matches_brute_force_on_asia() {
        let net = embedded::asia();
        check_net(&net, &Evidence::none());
        check_net(&net, &Evidence::from_pairs(&net, &[("xray", "yes")]).unwrap());
        check_net(&net, &Evidence::from_pairs(&net, &[("dysp", "yes"), ("smoke", "no")]).unwrap());
    }

    #[test]
    fn mpe_matches_brute_force_on_random_nets() {
        for seed in 0..10 {
            let net = netgen::tiny_random(seed + 500, 7);
            let mut rng = crate::rng::Rng::new(seed);
            let full = crate::bn::sample::forward_sample(&net, &mut rng);
            let ev = Evidence::from_ids(vec![(0, full[0])]);
            check_net(&net, &ev);
        }
    }

    #[test]
    fn mpe_dominates_sampled_assignments_on_a_large_net() {
        // no brute force possible; instead: the MPE's joint probability
        // must upper-bound every forward-sampled completion of the evidence
        let net = netgen::paper_net("hailfinder-sim").unwrap();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        let mut rng = crate::rng::Rng::new(777);
        let full = crate::bn::sample::forward_sample(&net, &mut rng);
        let ev = Evidence::from_ids((0..6).map(|v| (v, full[v])).collect());
        let mpe = most_probable_explanation(&jt, &sched, &mut state, &ev).unwrap();
        let cards = net.cards();
        let logp = |assignment: &[usize]| -> f64 {
            (0..net.n())
                .map(|v| {
                    let cpt = &net.cpts[v];
                    let config: Vec<usize> = cpt.parents.iter().map(|&p| assignment[p]).collect();
                    cpt.row(&config, &cards)[assignment[v]].max(1e-300).ln()
                })
                .sum()
        };
        assert!((mpe.log_prob - logp(&mpe.assignment)).abs() < 1e-9);
        for _ in 0..200 {
            let mut sample = crate::bn::sample::forward_sample(&net, &mut rng);
            for &(v, s) in &ev.obs {
                sample[v] = s;
            }
            assert!(
                logp(&sample) <= mpe.log_prob + 1e-9,
                "sampled completion beats the claimed MPE"
            );
        }
    }

    #[test]
    fn impossible_evidence_rejected() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(most_probable_explanation(&jt, &sched, &mut state, &ev).is_err());
    }

    /// Run both drivers over `cases` at lane width `lanes` and require
    /// per-case agreement: identical assignments, **bitwise**-identical
    /// log-probs, and matching feasibility verdicts.
    fn check_batch_against_single(
        jt: &JunctionTree,
        sched: &Schedule,
        cases: &[Evidence],
        lanes: usize,
    ) {
        let mut single = TreeState::fresh(jt);
        let want: Vec<Result<MpeResult>> =
            cases.iter().map(|ev| most_probable_explanation(jt, sched, &mut single, ev)).collect();
        let mut bstate = BatchState::fresh(jt, lanes);
        let got = most_probable_explanation_batch(jt, sched, &mut bstate, cases);
        assert_eq!(got.len(), cases.len());
        for (b, (g, w)) in got.iter().zip(&want).enumerate() {
            match (g, w) {
                (Ok(g), Ok(w)) => {
                    assert_eq!(g.assignment, w.assignment, "lanes {lanes} case {b}: assignment");
                    assert_eq!(
                        g.log_prob.to_bits(),
                        w.log_prob.to_bits(),
                        "lanes {lanes} case {b}: {} != {}",
                        g.log_prob,
                        w.log_prob
                    );
                }
                (Err(_), Err(_)) => {}
                _ => panic!("lanes {lanes} case {b}: batched/single disagree on feasibility"),
            }
        }
    }

    /// The batched-MPE oracle: every lane of
    /// `most_probable_explanation_batch` is bit-identical to an
    /// independent single-case run, across lane widths straddling the
    /// caseload (full chunks, partial tail chunks, occ < lanes) — the
    /// infeasible case rides in the middle of the batch, pinning that a
    /// dead lane neither poisons its neighbors nor flips feasibility.
    #[test]
    fn batched_mpe_matches_single_case_per_lane() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let cases: Vec<Evidence> = vec![
            Evidence::none(),
            Evidence::from_pairs(&net, &[("xray", "yes")]).unwrap(),
            Evidence::from_pairs(&net, &[("dysp", "yes"), ("smoke", "no")]).unwrap(),
            Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap(), // infeasible
            Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap(),
            Evidence::from_pairs(&net, &[("asia", "yes")]).unwrap(),
            Evidence::from_pairs(&net, &[("bronc", "no")]).unwrap(),
        ];
        for lanes in [1usize, 3, 4, 7, 8, 64] {
            check_batch_against_single(&jt, &sched, &cases, lanes);
        }
        // empty caseload: no sweep, no results
        let mut bstate = BatchState::fresh(&jt, 4);
        assert!(most_probable_explanation_batch(&jt, &sched, &mut bstate, &[]).is_empty());
    }

    #[test]
    fn batched_mpe_oracle_on_random_nets() {
        for seed in 0..4 {
            let net = netgen::tiny_random(seed + 500, 7);
            let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
            let sched = Schedule::build(&jt, RootStrategy::Center);
            let mut rng = crate::rng::Rng::new(seed);
            let cases: Vec<Evidence> = (0..6)
                .map(|_| {
                    let full = crate::bn::sample::forward_sample(&net, &mut rng);
                    Evidence::from_ids(vec![(0, full[0])])
                })
                .collect();
            check_batch_against_single(&jt, &sched, &cases, 4);
        }
    }
}
