//! Most Probable Explanation (MPE) via max-product message passing.
//!
//! The same junction tree that answers sum-product queries answers
//! max-product ones: replace marginalization's Σ with max in the upward
//! pass, then decode greedily from the root — each clique's restricted
//! argmax (consistent with the variables already fixed by its parent) is
//! globally optimal by the max-calibration property. An extension beyond
//! the poster (exact MPE is the other canonical JT workload), reusing the
//! compiled tree, evidence entry and schedules.

use crate::jt::evidence::Evidence;
use crate::jt::schedule::Schedule;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// An MPE solution.
#[derive(Clone, Debug)]
pub struct MpeResult {
    /// State index per variable (evidence variables at their observed
    /// states).
    pub assignment: Vec<usize>,
    /// `ln P(assignment)` — joint probability of the completion
    /// (includes the evidence).
    pub log_prob: f64,
}

/// `dst[map[i]] = max(dst[map[i]], src[i])` — the max-product analog of
/// marginalization.
fn max_with_map(src: &[f64], map: &[u32], dst: &mut [f64]) {
    for (x, &m) in src.iter().zip(map) {
        let d = &mut dst[m as usize];
        if *x > *d {
            *d = *x;
        }
    }
}

/// Compute the MPE for `ev` on a calibrated tree state.
///
/// `state` is reset, evidence is applied, one upward max-pass runs, and
/// the assignment is decoded root-to-leaves.
pub fn most_probable_explanation(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut TreeState,
    ev: &Evidence,
) -> Result<MpeResult> {
    state.reset(jt);
    ev.apply(jt, state);
    let mut log_scale = 0.0f64;

    // upward max-pass
    let mut new_sep_buf = vec![0.0f64; jt.seps.iter().map(|s| s.len).max().unwrap_or(1)];
    let mut ratio_buf = new_sep_buf.clone();
    for layer in &sched.up_layers {
        for msg in layer {
            let sep_meta = &jt.seps[msg.sep];
            let new_sep = &mut new_sep_buf[..sep_meta.len];
            for x in new_sep.iter_mut() {
                *x = 0.0;
            }
            let maps = &jt.edge_maps[msg.sep];
            max_with_map(state.clique(msg.from), maps.from(sep_meta, msg.from), new_sep);
            // scale by the max for numerical stability
            let peak = new_sep.iter().cloned().fold(0.0f64, f64::max);
            if peak == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            for x in new_sep.iter_mut() {
                *x /= peak;
            }
            log_scale += peak.ln();
            let ratio = &mut ratio_buf[..sep_meta.len];
            crate::jt::ops::ratio(new_sep, state.sep(msg.sep), ratio);
            state.sep_mut(msg.sep).copy_from_slice(new_sep);
            crate::jt::ops::extend_with_map(state.clique_mut(msg.to), maps.from(sep_meta, msg.to), ratio);
        }
    }

    // decode: roots first, then children restricted to their parents
    let n = jt.net.n();
    let mut assignment = vec![usize::MAX; n];
    let mut log_prob = log_scale;
    let mut order: Vec<usize> = Vec::with_capacity(jt.n_cliques());
    for &r in &sched.roots {
        order.push(r);
    }
    let mut qi = 0usize;
    while qi < order.len() {
        let c = order[qi];
        qi += 1;
        for &(ch, _) in &sched.children[c] {
            order.push(ch);
        }
    }

    for &c in &order {
        let clique = &jt.cliques[c];
        let data = state.clique(c);
        // restricted argmax: entries whose digits agree with already-fixed vars
        let mut best_idx = usize::MAX;
        let mut best_val = -1.0f64;
        'entry: for (i, &x) in data.iter().enumerate() {
            if x <= best_val {
                continue;
            }
            for (pos, &v) in clique.vars.iter().enumerate() {
                if assignment[v] != usize::MAX {
                    let digit = (i / clique.strides[pos]) % clique.cards[pos];
                    if digit != assignment[v] {
                        continue 'entry;
                    }
                }
            }
            best_val = x;
            best_idx = i;
        }
        if best_idx == usize::MAX || best_val <= 0.0 {
            return Err(Error::InconsistentEvidence);
        }
        for (pos, &v) in clique.vars.iter().enumerate() {
            if assignment[v] == usize::MAX {
                assignment[v] = (best_idx / clique.strides[pos]) % clique.cards[pos];
            }
        }
        if sched.parent[c].is_none() {
            // root clique contributes its (scaled) maximum once
            log_prob += best_val.ln();
        }
    }
    debug_assert!(assignment.iter().all(|&s| s != usize::MAX));

    // exact joint log-probability of the decoded assignment (cheap and
    // removes any residual scaling approximation from the reported value)
    let cards = jt.net.cards();
    let mut exact_logp = 0.0f64;
    for v in 0..n {
        let cpt = &jt.net.cpts[v];
        let config: Vec<usize> = cpt.parents.iter().map(|&p| assignment[p]).collect();
        let p = cpt.row(&config, &cards)[assignment[v]];
        if p == 0.0 {
            return Err(Error::InconsistentEvidence);
        }
        exact_logp += p.ln();
    }
    let _ = log_prob;
    Ok(MpeResult { assignment, log_prob: exact_logp })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};
    use crate::jt::schedule::RootStrategy;
    use crate::jt::triangulate::TriangulationHeuristic;

    /// Brute-force MPE by joint enumeration (small nets only).
    fn brute_mpe(net: &crate::bn::network::Network, ev: &Evidence) -> (Vec<usize>, f64) {
        let cards = net.cards();
        let order = net.topo_order().unwrap();
        let mut best = (Vec::new(), -1.0f64);
        let mut assignment = vec![0usize; net.n()];
        'outer: loop {
            let consistent = ev.obs.iter().all(|&(v, s)| assignment[v] == s);
            if consistent {
                let mut p = 1.0f64;
                for &v in &order {
                    let cpt = &net.cpts[v];
                    let config: Vec<usize> = cpt.parents.iter().map(|&q| assignment[q]).collect();
                    p *= cpt.row(&config, &cards)[assignment[v]];
                }
                if p > best.1 {
                    best = (assignment.clone(), p);
                }
            }
            for i in (0..net.n()).rev() {
                assignment[i] += 1;
                if assignment[i] < cards[i] {
                    continue 'outer;
                }
                assignment[i] = 0;
                if i == 0 {
                    break 'outer;
                }
            }
        }
        best
    }

    fn check_net(net: &crate::bn::network::Network, ev: &Evidence) {
        let jt = JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        let got = most_probable_explanation(&jt, &sched, &mut state, ev).unwrap();
        let (want_assign, want_p) = brute_mpe(net, ev);
        assert!(
            (got.log_prob - want_p.ln()).abs() < 1e-9,
            "MPE prob mismatch: {} vs {} (assignment {:?} vs {:?})",
            got.log_prob,
            want_p.ln(),
            got.assignment,
            want_assign
        );
        // evidence respected
        for &(v, s) in &ev.obs {
            assert_eq!(got.assignment[v], s);
        }
    }

    #[test]
    fn mpe_matches_brute_force_on_asia() {
        let net = embedded::asia();
        check_net(&net, &Evidence::none());
        check_net(&net, &Evidence::from_pairs(&net, &[("xray", "yes")]).unwrap());
        check_net(&net, &Evidence::from_pairs(&net, &[("dysp", "yes"), ("smoke", "no")]).unwrap());
    }

    #[test]
    fn mpe_matches_brute_force_on_random_nets() {
        for seed in 0..10 {
            let net = netgen::tiny_random(seed + 500, 7);
            let mut rng = crate::rng::Rng::new(seed);
            let full = crate::bn::sample::forward_sample(&net, &mut rng);
            let ev = Evidence::from_ids(vec![(0, full[0])]);
            check_net(&net, &ev);
        }
    }

    #[test]
    fn mpe_dominates_sampled_assignments_on_a_large_net() {
        // no brute force possible; instead: the MPE's joint probability
        // must upper-bound every forward-sampled completion of the evidence
        let net = netgen::paper_net("hailfinder-sim").unwrap();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        let mut rng = crate::rng::Rng::new(777);
        let full = crate::bn::sample::forward_sample(&net, &mut rng);
        let ev = Evidence::from_ids((0..6).map(|v| (v, full[v])).collect());
        let mpe = most_probable_explanation(&jt, &sched, &mut state, &ev).unwrap();
        let cards = net.cards();
        let logp = |assignment: &[usize]| -> f64 {
            (0..net.n())
                .map(|v| {
                    let cpt = &net.cpts[v];
                    let config: Vec<usize> = cpt.parents.iter().map(|&p| assignment[p]).collect();
                    cpt.row(&config, &cards)[assignment[v]].max(1e-300).ln()
                })
                .sum()
        };
        assert!((mpe.log_prob - logp(&mpe.assignment)).abs() < 1e-9);
        for _ in 0..200 {
            let mut sample = crate::bn::sample::forward_sample(&net, &mut rng);
            for &(v, s) in &ev.obs {
                sample[v] = s;
            }
            assert!(
                logp(&sample) <= mpe.log_prob + 1e-9,
                "sampled completion beats the claimed MPE"
            );
        }
    }

    #[test]
    fn impossible_evidence_rejected() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(most_probable_explanation(&jt, &sched, &mut state, &ev).is_err());
    }
}
