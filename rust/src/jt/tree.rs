//! Junction-tree construction.
//!
//! `JunctionTree::compile` runs the full pipeline: moralize → triangulate →
//! maximal cliques → maximum-weight spanning tree (Kruskal + union-find) →
//! CPT assignment → prototype potentials → per-edge index maps. The result
//! is immutable and shared by every engine and every test case; all
//! per-case mutable data lives in [`crate::jt::state::TreeState`].

use std::sync::Arc;

use crate::bn::network::Network;
use crate::jt::mapping::{build_map, strides};
use crate::jt::moralize::moralize;
use crate::jt::potential::Potential;
use crate::jt::state::ArenaLayout;
use crate::jt::triangulate::{is_subset, maximal_cliques, triangulate, TriangulationHeuristic};
use crate::{Error, Result};

/// A clique: a maximal set of mutually-connected variables in the
/// triangulated moral graph, carrying a dense potential table.
#[derive(Clone, Debug)]
pub struct Clique {
    /// Sorted member variables.
    pub vars: Vec<usize>,
    /// Cardinalities aligned with `vars`.
    pub cards: Vec<usize>,
    /// Mixed-radix strides aligned with `vars` (last fastest).
    pub strides: Vec<usize>,
    /// Table length = Π cards.
    pub len: usize,
}

/// A separator: the intersection of two adjacent cliques.
#[derive(Clone, Debug)]
pub struct Separator {
    /// Endpoint cliques.
    pub a: usize,
    /// Endpoint cliques.
    pub b: usize,
    /// Sorted member variables (= vars(a) ∩ vars(b)).
    pub vars: Vec<usize>,
    /// Cardinalities aligned with `vars`.
    pub cards: Vec<usize>,
    /// Table length = Π cards.
    pub len: usize,
}

/// Precomputed projection maps for one separator edge — the paper's
/// "simplified" index mappings, computed once per network and reused by
/// every message of every test case.
///
/// Both representations are kept: per-entry maps (what the comparison
/// baselines from the literature use) and run-compressed maps (the
/// Fast-BNI hot path — see [`crate::jt::mapping::RunMap`] and
/// EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
pub struct EdgeMaps {
    /// Clique `a` entry → separator entry.
    pub from_a: Vec<u32>,
    /// Clique `b` entry → separator entry.
    pub from_b: Vec<u32>,
    /// Run-compressed `a` → separator projection.
    pub runs_a: crate::jt::mapping::RunMap,
    /// Run-compressed `b` → separator projection.
    pub runs_b: crate::jt::mapping::RunMap,
}

impl EdgeMaps {
    /// The per-entry map projecting from clique `c` (must be an endpoint).
    #[inline]
    pub fn from(&self, sep: &Separator, c: usize) -> &[u32] {
        if c == sep.a {
            &self.from_a
        } else {
            debug_assert_eq!(c, sep.b);
            &self.from_b
        }
    }

    /// The run-compressed map projecting from clique `c`.
    #[inline]
    pub fn runs_from(&self, sep: &Separator, c: usize) -> &crate::jt::mapping::RunMap {
        if c == sep.a {
            &self.runs_a
        } else {
            debug_assert_eq!(c, sep.b);
            &self.runs_b
        }
    }
}

/// Per-variable location info for evidence entry and queries.
#[derive(Clone, Debug)]
pub struct VarSlot {
    /// Smallest clique containing the variable.
    pub clique: usize,
    /// Stride of the variable inside that clique's table.
    pub stride: usize,
    /// Cardinality.
    pub card: usize,
}

/// The compiled junction tree (or forest, for disconnected moral graphs).
#[derive(Clone, Debug)]
pub struct JunctionTree {
    /// The source network (owned).
    pub net: Network,
    /// Cliques.
    pub cliques: Vec<Clique>,
    /// Separators (edges of the tree/forest).
    pub seps: Vec<Separator>,
    /// `adj[c]` = (neighbor clique, separator id) pairs.
    pub adj: Vec<Vec<(usize, usize)>>,
    /// Evidence/query slot per variable.
    pub var_slot: Vec<VarSlot>,
    /// Clique each CPT was multiplied into.
    pub cpt_home: Vec<usize>,
    /// Arena layout: (offset, len) per clique/separator table in one flat
    /// allocation (see [`crate::jt::state`] for the invariants). Shared by
    /// every [`crate::jt::state::TreeState`] of this tree via `Arc`.
    pub layout: Arc<ArenaLayout>,
    /// Flat prototype arena: clique ranges hold the CPT products,
    /// separator ranges hold all-ones. `TreeState::fresh`/`reset` are one
    /// memcpy of this.
    pub arena_proto: Vec<f64>,
    /// Per-edge index maps.
    pub edge_maps: Vec<EdgeMaps>,
    /// Heuristic used (recorded for reporting).
    pub heuristic: TriangulationHeuristic,
}

/// Union-find with path compression (for Kruskal).
struct Dsu {
    parent: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Self {
        Dsu { parent: (0..n).collect() }
    }
    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let r = self.find(self.parent[x]);
            self.parent[x] = r;
        }
        self.parent[x]
    }
    fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        self.parent[ra] = rb;
        true
    }
}

impl JunctionTree {
    /// Compile a network into a junction tree with the given triangulation
    /// heuristic.
    pub fn compile(net: &Network, heuristic: TriangulationHeuristic) -> Result<Self> {
        // Telemetry only: a trace span plus a compile-time histogram on
        // the global registry; the pipeline itself is untouched.
        let compile_span = crate::obs::trace::span("jt.compile");
        let compile_start = std::time::Instant::now();
        let all_cards = net.cards();
        let weights: Vec<f64> = all_cards.iter().map(|&c| (c as f64).ln()).collect();

        // 1-3. moralize, triangulate, maximal cliques
        let moral = moralize(net);
        let tri = triangulate(&moral, &weights, heuristic);
        let clique_sets = maximal_cliques(&tri.cliques);

        let cliques: Vec<Clique> = clique_sets
            .iter()
            .map(|vars| {
                let cards: Vec<usize> = vars.iter().map(|&v| all_cards[v]).collect();
                let len = cards.iter().product();
                let st = strides(&cards);
                Clique { vars: vars.clone(), cards, strides: st, len }
            })
            .collect();
        let m = cliques.len();

        // 4. maximum-weight spanning forest over the clique graph
        let mut var_cliques: Vec<Vec<usize>> = vec![Vec::new(); net.n()];
        for (ci, c) in cliques.iter().enumerate() {
            for &v in &c.vars {
                var_cliques[v].push(ci);
            }
        }
        let mut cand: std::collections::HashSet<(usize, usize)> = std::collections::HashSet::new();
        for list in &var_cliques {
            for (i, &a) in list.iter().enumerate() {
                for &b in &list[i + 1..] {
                    cand.insert((a.min(b), a.max(b)));
                }
            }
        }
        let mut edges: Vec<(usize, usize, usize)> = cand
            .into_iter()
            .map(|(a, b)| {
                let w = intersect_sorted(&cliques[a].vars, &cliques[b].vars).len();
                (a, b, w)
            })
            .collect();
        // max weight first; deterministic tie-break on (a, b)
        edges.sort_by(|x, y| y.2.cmp(&x.2).then(x.0.cmp(&y.0)).then(x.1.cmp(&y.1)));
        let mut dsu = Dsu::new(m);
        let mut seps: Vec<Separator> = Vec::new();
        let mut adj: Vec<Vec<(usize, usize)>> = vec![Vec::new(); m];
        for (a, b, _w) in edges {
            if dsu.union(a, b) {
                let vars = intersect_sorted(&cliques[a].vars, &cliques[b].vars);
                let cards: Vec<usize> = vars.iter().map(|&v| all_cards[v]).collect();
                let len = cards.iter().product();
                let sid = seps.len();
                adj[a].push((b, sid));
                adj[b].push((a, sid));
                seps.push(Separator { a, b, vars, cards, len });
            }
        }

        // 5. var slots: smallest clique containing each variable
        let mut var_slot = Vec::with_capacity(net.n());
        for v in 0..net.n() {
            let &home = var_cliques[v]
                .iter()
                .min_by_key(|&&c| cliques[c].len)
                .ok_or_else(|| Error::JunctionTree(format!("variable {v} not in any clique")))?;
            let c = &cliques[home];
            let pos = c.vars.binary_search(&v).unwrap();
            var_slot.push(VarSlot { clique: home, stride: c.strides[pos], card: c.cards[pos] });
        }

        // 6. arena layout + CPT assignment into the flat prototype
        let clique_lens: Vec<usize> = cliques.iter().map(|c| c.len).collect();
        let sep_lens: Vec<usize> = seps.iter().map(|s| s.len).collect();
        let layout = Arc::new(ArenaLayout::build(&clique_lens, &sep_lens));
        let mut arena_proto = vec![1.0f64; layout.total];
        let mut cpt_home = Vec::with_capacity(net.n());
        for v in 0..net.n() {
            let mut fam: Vec<usize> = net.parents(v).to_vec();
            fam.push(v);
            fam.sort_unstable();
            let home = (0..m)
                .filter(|&c| is_subset(&fam, &cliques[c].vars))
                .min_by_key(|&c| cliques[c].len)
                .ok_or_else(|| Error::JunctionTree(format!("family of variable {v} not covered by any clique")))?;
            cpt_home.push(home);
            let pot = Potential::from_cpt(net, v);
            let c = &cliques[home];
            let map = build_map(&c.vars, &c.cards, &pot.vars, &pot.cards);
            let data = &mut arena_proto[layout.clique_range(home)];
            for (i, x) in data.iter_mut().enumerate() {
                *x *= pot.data[map[i] as usize];
            }
        }

        // 7. per-edge index maps (the hoisted bottleneck computation)
        let edge_maps: Vec<EdgeMaps> = seps
            .iter()
            .map(|s| {
                let ca = &cliques[s.a];
                let cb = &cliques[s.b];
                EdgeMaps {
                    from_a: build_map(&ca.vars, &ca.cards, &s.vars, &s.cards),
                    from_b: build_map(&cb.vars, &cb.cards, &s.vars, &s.cards),
                    runs_a: crate::jt::mapping::build_run_map(&ca.vars, &ca.cards, &s.vars, &s.cards),
                    runs_b: crate::jt::mapping::build_run_map(&cb.vars, &cb.cards, &s.vars, &s.cards),
                }
            })
            .collect();

        let tree = JunctionTree {
            net: net.clone(),
            cliques,
            seps,
            adj,
            var_slot,
            cpt_home,
            layout,
            arena_proto,
            edge_maps,
            heuristic,
        };
        compile_span
            .note(&format!("cliques={} entries={}", tree.n_cliques(), tree.total_clique_entries()));
        crate::obs::global().histogram("fastbn_jt_compile_us").record(compile_start.elapsed());
        Ok(tree)
    }

    /// Prototype potentials of clique `c` (a slice of the flat arena).
    #[inline]
    pub fn proto_clique(&self, c: usize) -> &[f64] {
        &self.arena_proto[self.layout.clique_range(c)]
    }

    /// Number of cliques.
    pub fn n_cliques(&self) -> usize {
        self.cliques.len()
    }

    /// Total clique-table entries (the paper's state-space-size driver).
    pub fn total_clique_entries(&self) -> usize {
        self.cliques.iter().map(|c| c.len).sum()
    }

    /// Total separator-table entries.
    pub fn total_sep_entries(&self) -> usize {
        self.seps.iter().map(|s| s.len).sum()
    }

    /// Largest clique table.
    pub fn max_clique_entries(&self) -> usize {
        self.cliques.iter().map(|c| c.len).max().unwrap_or(0)
    }

    /// Treewidth witness: largest clique cardinality − 1.
    pub fn width(&self) -> usize {
        self.cliques.iter().map(|c| c.vars.len()).max().unwrap_or(1) - 1
    }

    /// Check the running-intersection property: for every variable, the
    /// cliques containing it induce a connected subtree.
    pub fn verify_rip(&self) -> Result<()> {
        for v in 0..self.net.n() {
            let members: Vec<usize> =
                (0..self.n_cliques()).filter(|&c| self.cliques[c].vars.binary_search(&v).is_ok()).collect();
            if members.is_empty() {
                return Err(Error::JunctionTree(format!("variable {v} in no clique")));
            }
            // BFS restricted to edges whose separator contains v
            let mut seen = std::collections::HashSet::new();
            let mut queue = std::collections::VecDeque::new();
            seen.insert(members[0]);
            queue.push_back(members[0]);
            while let Some(c) = queue.pop_front() {
                for &(nb, sid) in &self.adj[c] {
                    if self.seps[sid].vars.binary_search(&v).is_ok() && seen.insert(nb) {
                        queue.push_back(nb);
                    }
                }
            }
            if !members.iter().all(|c| seen.contains(c)) {
                return Err(Error::JunctionTree(format!("RIP violated for variable {v}")));
            }
        }
        Ok(())
    }

    /// Human-readable tree statistics.
    pub fn stats(&self) -> TreeStats {
        TreeStats {
            cliques: self.n_cliques(),
            seps: self.seps.len(),
            width: self.width(),
            total_clique_entries: self.total_clique_entries(),
            total_sep_entries: self.total_sep_entries(),
            max_clique_entries: self.max_clique_entries(),
        }
    }
}

/// Statistics of a compiled tree (see [`JunctionTree::stats`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TreeStats {
    pub cliques: usize,
    pub seps: usize,
    pub width: usize,
    pub total_clique_entries: usize,
    pub total_sep_entries: usize,
    pub max_clique_entries: usize,
}

impl std::fmt::Display for TreeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} cliques, {} seps, width {}, {} clique entries (max {}), {} sep entries",
            self.cliques, self.seps, self.width, self.total_clique_entries, self.max_clique_entries, self.total_sep_entries
        )
    }
}

/// Estimate the junction-tree cost of `net` without materializing any
/// clique table: run the graph-only pipeline prefix (moralize →
/// triangulate → maximal cliques) and return the summed clique
/// state-space sizes `Σ_C Π_{v∈C} card(v)` in `f64` — deliberately
/// overflow-free, so a treewidth blow-up reports a huge number instead
/// of exhausting memory on `compile`'s flat arena. The fleet registry
/// compares this against its `max_exact_cost` threshold to pick the
/// exact or approximate serving tier.
pub fn estimate_cost(net: &Network, heuristic: TriangulationHeuristic) -> f64 {
    let all_cards = net.cards();
    let weights: Vec<f64> = all_cards.iter().map(|&c| (c as f64).ln()).collect();
    let moral = moralize(net);
    let tri = triangulate(&moral, &weights, heuristic);
    maximal_cliques(&tri.cliques)
        .iter()
        .map(|vars| vars.iter().map(|&v| all_cards[v] as f64).product::<f64>())
        .sum()
}

/// Intersection of two sorted vertex lists.
pub fn intersect_sorted(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};

    #[test]
    fn intersect_sorted_works() {
        assert_eq!(intersect_sorted(&[1, 3, 5], &[2, 3, 5, 7]), vec![3, 5]);
        assert_eq!(intersect_sorted(&[], &[1]), Vec::<usize>::new());
    }

    #[test]
    fn asia_tree_shape() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        assert_eq!(jt.n_cliques(), 6);
        assert_eq!(jt.seps.len(), 5);
        assert!(jt.width() <= 2);
        jt.verify_rip().unwrap();
    }

    #[test]
    fn prototype_total_mass_is_one() {
        // product of all CPTs sums to 1 over the joint; distributed over a
        // forest, the product of per-tree masses must be 1. For a connected
        // tree: sum over all cliques of ... not directly; instead check the
        // single-clique case and the calibrated chain elsewhere. Here:
        // every clique table must be non-negative and non-trivial.
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        for c in 0..jt.n_cliques() {
            let data = jt.proto_clique(c);
            assert!(data.iter().all(|&x| x >= 0.0));
            assert!(data.iter().sum::<f64>() > 0.0);
        }
        // separator ranges of the prototype arena are all-ones
        for s in 0..jt.seps.len() {
            assert!(jt.arena_proto[jt.layout.sep_range(s)].iter().all(|&x| x == 1.0));
        }
    }

    #[test]
    fn every_cpt_assigned_within_home() {
        let net = embedded::mixed12();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        for v in 0..net.n() {
            let home = jt.cpt_home[v];
            let mut fam: Vec<usize> = net.parents(v).to_vec();
            fam.push(v);
            fam.sort_unstable();
            assert!(is_subset(&fam, &jt.cliques[home].vars));
        }
    }

    #[test]
    fn var_slot_points_into_containing_clique() {
        let net = embedded::mixed12();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        for v in 0..net.n() {
            let slot = &jt.var_slot[v];
            let c = &jt.cliques[slot.clique];
            assert!(c.vars.contains(&v));
            assert_eq!(slot.card, net.card(v));
        }
    }

    #[test]
    fn separators_are_intersections() {
        let net = embedded::mixed12();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        for s in &jt.seps {
            let expect = intersect_sorted(&jt.cliques[s.a].vars, &jt.cliques[s.b].vars);
            assert_eq!(s.vars, expect);
            assert!(!s.vars.is_empty(), "tree edges must share variables");
        }
    }

    #[test]
    fn edge_maps_have_clique_lengths() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        for (sid, s) in jt.seps.iter().enumerate() {
            assert_eq!(jt.edge_maps[sid].from_a.len(), jt.cliques[s.a].len);
            assert_eq!(jt.edge_maps[sid].from_b.len(), jt.cliques[s.b].len);
            for &m in &jt.edge_maps[sid].from_a {
                assert!((m as usize) < s.len);
            }
        }
    }

    #[test]
    fn rip_holds_on_random_networks() {
        for seed in 0..15 {
            let net = netgen::tiny_random(seed, 4 + (seed as usize % 5));
            for h in [
                TriangulationHeuristic::MinFill,
                TriangulationHeuristic::MinDegree,
                TriangulationHeuristic::MinWeight,
            ] {
                let jt = JunctionTree::compile(&net, h).unwrap();
                jt.verify_rip().unwrap();
            }
        }
    }

    #[test]
    fn forest_of_disconnected_network() {
        // two isolated variables -> 2 cliques, 0 separators
        use crate::bn::cpt::Cpt;
        use crate::bn::variable::Variable;
        let vars = vec![Variable::with_card("a", 2), Variable::with_card("b", 3)];
        let cpts = vec![
            Cpt::new(0, vec![], vec![0.4, 0.6], &[2, 3]).unwrap(),
            Cpt::new(1, vec![], vec![0.2, 0.3, 0.5], &[2, 3]).unwrap(),
        ];
        let net = Network::new("disc", vars, cpts).unwrap();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        assert_eq!(jt.n_cliques(), 2);
        assert_eq!(jt.seps.len(), 0);
        jt.verify_rip().unwrap();
    }

    #[test]
    fn estimate_cost_matches_compiled_clique_entries() {
        // the estimator runs only the graph prefix of the pipeline, so on a
        // compilable network it must agree exactly with the compiled tree
        for net in [embedded::asia(), embedded::mixed12()] {
            let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
            let cost = estimate_cost(&net, TriangulationHeuristic::MinFill);
            assert_eq!(cost, jt.total_clique_entries() as f64, "{}", net.name);
        }
    }

    #[test]
    fn stats_display() {
        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let s = jt.stats();
        assert_eq!(s.cliques, 6);
        assert!(format!("{s}").contains("6 cliques"));
    }
}
