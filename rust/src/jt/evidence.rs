//! Evidence: observed variable/state pairs and their entry into the tree.
//!
//! Evidence is absorbed by zeroing the clique-table entries that disagree
//! with each observation (a "finding" vector multiply). Each observation
//! touches exactly one clique — the variable's home slot — and the
//! subsequent propagation spreads it to the whole tree.

use crate::bn::network::Network;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::Result;

/// A set of observations `(variable, state)`, optionally with **soft
/// (likelihood) evidence**: per-variable weight vectors multiplied into
/// the home clique instead of hard 0/1 indicators — Pearl's virtual
/// evidence, the standard way to absorb noisy sensor readings.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Evidence {
    /// Observed pairs, sorted by variable id, at most one per variable.
    pub obs: Vec<(usize, usize)>,
    /// Soft findings `(variable, likelihood per state)`; weights must be
    /// non-negative and not all zero. Sorted by variable id.
    pub soft: Vec<(usize, Vec<f64>)>,
}

impl Evidence {
    /// Empty evidence (prior inference).
    pub fn none() -> Self {
        Evidence { obs: Vec::new(), soft: Vec::new() }
    }

    /// Build from `(variable id, state id)` pairs.
    pub fn from_ids(mut obs: Vec<(usize, usize)>) -> Self {
        obs.sort_unstable_by_key(|&(v, _)| v);
        obs.dedup_by_key(|&mut (v, _)| v);
        Evidence { obs, soft: Vec::new() }
    }

    /// Add a soft (likelihood) finding for `v`: `weights[s]` multiplies
    /// the probability mass of state `s`. Replaces any previous soft
    /// finding on the same variable.
    pub fn with_soft(mut self, v: usize, weights: Vec<f64>) -> crate::Result<Self> {
        if weights.iter().any(|&w| w < 0.0 || w.is_nan()) || weights.iter().all(|&w| w == 0.0) {
            return Err(crate::Error::msg(format!(
                "soft evidence for variable {v} must be non-negative and not all zero"
            )));
        }
        self.soft.retain(|&(var, _)| var != v);
        let pos = self.soft.partition_point(|&(var, _)| var < v);
        self.soft.insert(pos, (v, weights));
        Ok(self)
    }

    /// Build from `(variable name, state name)` pairs.
    pub fn from_pairs(net: &Network, pairs: &[(&str, &str)]) -> Result<Self> {
        let mut obs = Vec::with_capacity(pairs.len());
        for &(var, state) in pairs {
            obs.push(net.state_id(var, state)?);
        }
        Ok(Self::from_ids(obs))
    }

    /// Number of observed variables (hard findings only).
    pub fn len(&self) -> usize {
        self.obs.len()
    }

    /// True when no variable is observed (hard or soft).
    pub fn is_empty(&self) -> bool {
        self.obs.is_empty() && self.soft.is_empty()
    }

    /// The observed state of `v`, if any.
    pub fn get(&self, v: usize) -> Option<usize> {
        self.obs.binary_search_by_key(&v, |&(var, _)| var).ok().map(|i| self.obs[i].1)
    }

    /// Enter the findings: zero disagreeing entries for hard observations,
    /// multiply likelihood weights for soft ones — each in the variable's
    /// home clique.
    pub fn apply(&self, jt: &JunctionTree, state: &mut TreeState) {
        self.apply_lane(jt, state.data_mut(), 1, 0);
    }

    /// Enter the findings into lane `lane` of a lane-expanded arena
    /// (`data[i*lanes + b]` holds entry `i` of case `b` — see
    /// [`crate::jt::state::BatchState`]). `apply` is the `lanes = 1` case.
    pub fn apply_lane(&self, jt: &JunctionTree, data: &mut [f64], lanes: usize, lane: usize) {
        debug_assert!(lane < lanes);
        for &(v, obs_state) in &self.obs {
            let slot = &jt.var_slot[v];
            let r = jt.layout.clique_range(slot.clique);
            let tab = &mut data[r.start * lanes..r.end * lanes];
            let len = r.end - r.start;
            let stride = slot.stride;
            let card = slot.card;
            let block = stride * card;
            // entries where digit(v) != obs_state -> 0
            let mut base = 0usize;
            while base < len {
                for s in 0..card {
                    if s != obs_state {
                        let lo = base + s * stride;
                        for i in lo..lo + stride {
                            tab[i * lanes + lane] = 0.0;
                        }
                    }
                }
                base += block;
            }
        }
        for (v, weights) in &self.soft {
            let slot = &jt.var_slot[*v];
            debug_assert_eq!(weights.len(), slot.card);
            let r = jt.layout.clique_range(slot.clique);
            let tab = &mut data[r.start * lanes..r.end * lanes];
            let len = r.end - r.start;
            let stride = slot.stride;
            let block = stride * slot.card;
            let mut base = 0usize;
            while base < len {
                for (s, &w) in weights.iter().enumerate() {
                    if w != 1.0 {
                        let lo = base + s * stride;
                        for i in lo..lo + stride {
                            tab[i * lanes + lane] *= w;
                        }
                    }
                }
                base += block;
            }
        }
    }
}

/// `Display` shows `v3=1, v7=0` style pairs (ids, not names — names need
/// the network; use [`Evidence::describe`] for those).
impl std::fmt::Display for Evidence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self.obs.iter().map(|(v, s)| format!("v{v}={s}")).collect();
        write!(f, "{}", parts.join(", "))
    }
}

impl Evidence {
    /// Human-readable description using network names.
    pub fn describe(&self, net: &Network) -> String {
        let parts: Vec<String> = self
            .obs
            .iter()
            .map(|&(v, s)| format!("{}={}", net.vars[v].name, net.vars[v].states[s]))
            .collect();
        parts.join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn from_pairs_resolves_names() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes"), ("xray", "no")]).unwrap();
        assert_eq!(ev.len(), 2);
        let smoke = net.var_id("smoke").unwrap();
        assert_eq!(ev.get(smoke), Some(0));
        assert_eq!(ev.get(net.var_id("asia").unwrap()), None);
        assert!(Evidence::from_pairs(&net, &[("bogus", "yes")]).is_err());
        assert!(Evidence::from_pairs(&net, &[("smoke", "bogus")]).is_err());
    }

    #[test]
    fn from_ids_sorts_and_dedups() {
        let ev = Evidence::from_ids(vec![(5, 1), (2, 0), (5, 0)]);
        assert_eq!(ev.obs, vec![(2, 0), (5, 1)]);
    }

    #[test]
    fn apply_zeroes_only_disagreeing_entries() {
        let net = embedded::asia();
        let jt = crate::jt::tree::JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut st = crate::jt::state::TreeState::fresh(&jt);
        let smoke = net.var_id("smoke").unwrap();
        let ev = Evidence::from_ids(vec![(smoke, 0)]);
        ev.apply(&jt, &mut st);

        let slot = &jt.var_slot[smoke];
        let data = st.clique(slot.clique);
        for (i, &x) in data.iter().enumerate() {
            let digit = (i / slot.stride) % slot.card;
            if digit != 0 {
                assert_eq!(x, 0.0, "entry {i} should be zeroed");
            } else {
                assert_eq!(x, jt.proto_clique(slot.clique)[i], "entry {i} should be untouched");
            }
        }
        // other cliques untouched
        for c in 0..jt.n_cliques() {
            if c != slot.clique {
                assert_eq!(st.clique(c), jt.proto_clique(c));
            }
        }
    }

    #[test]
    fn apply_lane_touches_only_its_lane() {
        let net = embedded::asia();
        let jt = crate::jt::tree::JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut bs = crate::jt::state::BatchState::fresh(&jt, 3);
        let smoke = net.var_id("smoke").unwrap();
        let ev = Evidence::from_ids(vec![(smoke, 0)]);
        let lanes = bs.lanes();
        ev.apply_lane(&jt, bs.data_mut(), lanes, 1);
        let slot = &jt.var_slot[smoke];
        // lane 1 mirrors the single-case apply; lanes 0 and 2 untouched
        let mut st = crate::jt::state::TreeState::fresh(&jt);
        ev.apply(&jt, &mut st);
        assert_eq!(bs.lane_of_clique(slot.clique, 1), st.clique(slot.clique));
        for lane in [0usize, 2] {
            for c in 0..jt.n_cliques() {
                assert_eq!(bs.lane_of_clique(c, lane), jt.proto_clique(c), "lane {lane} clique {c}");
            }
        }
    }

    #[test]
    fn describe_uses_names() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        assert_eq!(ev.describe(&net), "smoke=yes");
    }

    #[test]
    fn soft_evidence_validation() {
        let ev = Evidence::none();
        assert!(ev.clone().with_soft(0, vec![0.5, -0.1]).is_err());
        assert!(ev.clone().with_soft(0, vec![0.0, 0.0]).is_err());
        assert!(ev.clone().with_soft(0, vec![f64::NAN, 1.0]).is_err());
        let ok = ev.with_soft(0, vec![2.0, 1.0]).unwrap();
        assert!(!ok.is_empty());
        // replacing an existing soft finding
        let ok = ok.with_soft(0, vec![1.0, 3.0]).unwrap();
        assert_eq!(ok.soft.len(), 1);
        assert_eq!(ok.soft[0].1, vec![1.0, 3.0]);
    }

    #[test]
    fn soft_evidence_multiplies_home_clique() {
        let net = embedded::asia();
        let jt = crate::jt::tree::JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut st = crate::jt::state::TreeState::fresh(&jt);
        let smoke = net.var_id("smoke").unwrap();
        let ev = Evidence::none().with_soft(smoke, vec![3.0, 0.5]).unwrap();
        ev.apply(&jt, &mut st);
        let slot = &jt.var_slot[smoke];
        let data = st.clique(slot.clique);
        for (i, &x) in data.iter().enumerate() {
            let digit = (i / slot.stride) % slot.card;
            let w = if digit == 0 { 3.0 } else { 0.5 };
            assert!((x - jt.proto_clique(slot.clique)[i] * w).abs() < 1e-12, "entry {i}");
        }
    }

    #[test]
    fn hard_evidence_is_extreme_soft_evidence() {
        // P(v | hard e) == P(v | soft e with indicator weights)
        use crate::engine::{EngineConfig, EngineKind};
        use std::sync::Arc;
        let net = embedded::asia();
        let jt = Arc::new(crate::jt::tree::JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = crate::jt::state::TreeState::fresh(&jt);
        let smoke = net.var_id("smoke").unwrap();
        let hard = Evidence::from_ids(vec![(smoke, 0)]);
        let soft = Evidence::none().with_soft(smoke, vec![1.0, 0.0]).unwrap();
        let a = engine.infer(&mut state, &hard).unwrap();
        let b = engine.infer(&mut state, &soft).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn soft_evidence_bayes_update_matches_hand_computation() {
        // virtual evidence on smoke with likelihood ratio 4:1 ->
        // posterior odds = prior odds * 4 (prior is 50/50)
        use crate::engine::{EngineConfig, EngineKind};
        use std::sync::Arc;
        let net = embedded::asia();
        let jt = Arc::new(crate::jt::tree::JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig { threads: 2, ..Default::default() });
        let mut state = crate::jt::state::TreeState::fresh(&jt);
        let smoke = net.var_id("smoke").unwrap();
        let ev = Evidence::none().with_soft(smoke, vec![4.0, 1.0]).unwrap();
        let post = engine.infer(&mut state, &ev).unwrap();
        assert!((post.probs[smoke][0] - 0.8).abs() < 1e-9, "got {}", post.probs[smoke][0]);
        // downstream propagation: P(lung | soft) = .8*.1 + .2*.01
        let lung = net.var_id("lung").unwrap();
        assert!((post.probs[lung][0] - (0.8 * 0.1 + 0.2 * 0.01)).abs() < 1e-9);
    }
}
