//! Sequential message passing (collect + distribute), the reference
//! implementation all parallel engines must agree with.
//!
//! One message `from → to` through separator `sep` is the classic Hugin
//! update:
//!
//! 1. **marginalization**: `new_sep[j] = Σ_{i: map(i)=j} clique_from[i]`;
//! 2. scaling: `new_sep /= Σ new_sep` (underflow protection on deep trees;
//!    the scale factor is accumulated into `log_z`, so `P(e)` is exact);
//! 3. **reduction**: `ratio[j] = new_sep[j] / old_sep[j]` (0/0 → 0);
//! 4. **extension**: `clique_to[i] *= ratio[map(i)]`.
//!
//! The [`MapMode`] parameter selects the index-mapping strategy — the
//! bottleneck the paper simplifies — so the same code path can run in
//! "naive" (per-entry div/mod, the UnBBayes-style baseline) or "cached"
//! (precomputed per-edge maps) mode. See `benches/ablation.rs`.

use crate::jt::mapping::{projection_strides, strides};
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// Index-mapping strategy for the table operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MapMode {
    /// Precomputed per-edge maps (Fast-BNI).
    #[default]
    Cached,
    /// Incremental odometer, no materialized map (memory-lean middle
    /// ground; ablation point).
    Odometer,
    /// Per-entry div/mod chains recomputed every message (naive baseline).
    DivMod,
}

/// Reusable scratch buffers for one propagation pass.
#[derive(Debug, Default)]
pub struct Scratch {
    /// New separator values (capacity = max separator length).
    pub new_sep: Vec<f64>,
    /// Ratio `new/old` (same capacity).
    pub ratio: Vec<f64>,
}

impl Scratch {
    /// Scratch sized for a tree. A separator-free tree (single-clique or
    /// fully disconnected network) legitimately gets zero-length buffers:
    /// no message is ever sent, so the buffers are never sliced — the
    /// regression tests in `tests/parallel_consistency.rs` pin that path
    /// through every engine.
    pub fn for_tree(jt: &JunctionTree) -> Self {
        let cap = jt.seps.iter().map(|s| s.len).max().unwrap_or(0);
        Scratch { new_sep: vec![0.0; cap], ratio: vec![0.0; cap] }
    }
}

/// Send one message sequentially. Returns the separator mass before
/// scaling (0.0 signals inconsistent evidence).
pub fn send_message(
    jt: &JunctionTree,
    state: &mut TreeState,
    msg: Msg,
    mode: MapMode,
    scratch: &mut Scratch,
) -> f64 {
    let sep_meta = &jt.seps[msg.sep];
    let sep_len = sep_meta.len;
    let new_sep = &mut scratch.new_sep[..sep_len];
    ops::zero(new_sep);

    // 1. marginalization: clique_from -> new_sep
    {
        let src = state.clique(msg.from);
        match mode {
            MapMode::Cached => {
                let rm = jt.edge_maps[msg.sep].runs_from(sep_meta, msg.from);
                ops::marg_runs(src, rm, new_sep);
            }
            MapMode::Odometer => {
                let c = &jt.cliques[msg.from];
                let ps = projection_strides(&c.vars, &sep_meta.vars, &sep_meta.cards);
                ops::marg_odometer(src, &c.cards, &ps, new_sep);
            }
            MapMode::DivMod => {
                let c = &jt.cliques[msg.from];
                let ps = projection_strides(&c.vars, &sep_meta.vars, &sep_meta.cards);
                let ss = strides(&c.cards);
                ops::marg_divmod(src, &c.cards, &ss, &ps, new_sep);
            }
        }
    }

    // 2. scale
    let mass = ops::sum(new_sep);
    if mass == 0.0 {
        return 0.0;
    }
    ops::scale(new_sep, 1.0 / mass);
    state.log_z += mass.ln();

    // 3. reduction: ratio = new / old; store new into the separator
    let ratio = &mut scratch.ratio[..sep_len];
    {
        let old_sep = state.sep_mut(msg.sep);
        ops::ratio(new_sep, old_sep, ratio);
        old_sep.copy_from_slice(new_sep);
    }

    // 4. extension: clique_to *= ratio[map]
    {
        let dst = state.clique_mut(msg.to);
        match mode {
            MapMode::Cached => {
                let rm = jt.edge_maps[msg.sep].runs_from(sep_meta, msg.to);
                ops::extend_runs(dst, rm, ratio);
            }
            MapMode::Odometer => {
                let c = &jt.cliques[msg.to];
                let ps = projection_strides(&c.vars, &sep_meta.vars, &sep_meta.cards);
                ops::extend_odometer(dst, &c.cards, &ps, ratio);
            }
            MapMode::DivMod => {
                let c = &jt.cliques[msg.to];
                let ps = projection_strides(&c.vars, &sep_meta.vars, &sep_meta.cards);
                let ss = strides(&c.cards);
                ops::extend_divmod(dst, &c.cards, &ss, &ps, ratio);
            }
        }
    }
    mass
}

/// Collect phase: leaves → roots, layer by layer. Finishes by folding each
/// root's residual mass into `log_z`, after which `state.log_z = ln P(e)`.
pub fn collect(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut TreeState,
    mode: MapMode,
    scratch: &mut Scratch,
) -> Result<()> {
    for layer in &sched.up_layers {
        for &msg in layer {
            if send_message(jt, state, msg, mode, scratch) == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
        }
    }
    for &root in &sched.roots {
        let data = state.clique_mut(root);
        let mass = ops::sum(data);
        if mass == 0.0 {
            return Err(Error::InconsistentEvidence);
        }
        ops::scale(data, 1.0 / mass);
        state.log_z += mass.ln();
    }
    Ok(())
}

/// Distribute phase: roots → leaves, layer by layer. Downward scale
/// factors do not contribute evidence mass, so `log_z` is preserved.
pub fn distribute(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut TreeState,
    mode: MapMode,
    scratch: &mut Scratch,
) -> Result<()> {
    let z = state.log_z;
    for layer in &sched.down_layers {
        for &msg in layer {
            if send_message(jt, state, msg, mode, scratch) == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
        }
    }
    state.log_z = z;
    Ok(())
}

/// Full calibration: reset → evidence → collect → distribute.
pub fn calibrate(
    jt: &JunctionTree,
    sched: &Schedule,
    state: &mut TreeState,
    ev: &crate::jt::evidence::Evidence,
    mode: MapMode,
    scratch: &mut Scratch,
) -> Result<()> {
    state.reset(jt);
    ev.apply(jt, state);
    collect(jt, sched, state, mode, scratch)?;
    distribute(jt, sched, state, mode, scratch)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::evidence::Evidence;
    use crate::jt::schedule::RootStrategy;
    use crate::jt::triangulate::TriangulationHeuristic;

    fn setup(net: &crate::bn::network::Network) -> (JunctionTree, Schedule, TreeState, Scratch) {
        let jt = JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let state = TreeState::fresh(&jt);
        let scratch = Scratch::for_tree(&jt);
        (jt, sched, state, scratch)
    }

    #[test]
    fn no_evidence_log_z_is_zero() {
        let net = embedded::asia();
        let (jt, sched, mut state, mut scratch) = setup(&net);
        calibrate(&jt, &sched, &mut state, &Evidence::none(), MapMode::Cached, &mut scratch).unwrap();
        assert!(state.log_z.abs() < 1e-9, "ln P() = {} should be 0", state.log_z);
    }

    #[test]
    fn log_z_matches_hand_computed_evidence_probability() {
        // P(smoke=yes) = 0.5
        let net = embedded::asia();
        let (jt, sched, mut state, mut scratch) = setup(&net);
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        calibrate(&jt, &sched, &mut state, &ev, MapMode::Cached, &mut scratch).unwrap();
        assert!((state.log_z.exp() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn calibration_makes_neighboring_cliques_consistent() {
        // after calibrate, both endpoints of every separator must agree on
        // the separator marginal
        let net = embedded::asia();
        let (jt, sched, mut state, mut scratch) = setup(&net);
        let ev = Evidence::from_pairs(&net, &[("xray", "yes")]).unwrap();
        calibrate(&jt, &sched, &mut state, &ev, MapMode::Cached, &mut scratch).unwrap();
        for (sid, sep) in jt.seps.iter().enumerate() {
            let mut from_a = vec![0.0; sep.len];
            let mut from_b = vec![0.0; sep.len];
            ops::marg_with_map(state.clique(sep.a), &jt.edge_maps[sid].from_a, &mut from_a);
            ops::marg_with_map(state.clique(sep.b), &jt.edge_maps[sid].from_b, &mut from_b);
            let sa = ops::sum(&from_a);
            let sb = ops::sum(&from_b);
            for j in 0..sep.len {
                assert!(
                    (from_a[j] / sa - from_b[j] / sb).abs() < 1e-9,
                    "sep {sid} entry {j}: {} vs {}",
                    from_a[j] / sa,
                    from_b[j] / sb
                );
            }
        }
    }

    #[test]
    fn map_modes_agree() {
        let net = embedded::mixed12();
        let (jt, sched, _, mut scratch) = setup(&net);
        let ev = Evidence::from_ids(vec![(0, 0), (5, 1)]);
        let mut results = Vec::new();
        for mode in [MapMode::Cached, MapMode::Odometer, MapMode::DivMod] {
            let mut state = TreeState::fresh(&jt);
            calibrate(&jt, &sched, &mut state, &ev, mode, &mut scratch).unwrap();
            results.push(state);
        }
        for other in &results[1..] {
            assert!((results[0].log_z - other.log_z).abs() < 1e-9);
            for (x, y) in results[0].data().iter().zip(other.data()) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn impossible_evidence_is_detected() {
        // either = no but xray = yes is possible; need truly impossible:
        // either=no AND lung=yes (either is the OR of lung and tub)
        let net = embedded::asia();
        let (jt, sched, mut state, mut scratch) = setup(&net);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let r = calibrate(&jt, &sched, &mut state, &ev, MapMode::Cached, &mut scratch);
        assert!(matches!(r, Err(Error::InconsistentEvidence)));
    }

    #[test]
    fn root_strategy_does_not_change_results() {
        let net = embedded::mixed12();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let ev = Evidence::from_ids(vec![(3, 0)]);
        let mut scratch = Scratch::for_tree(&jt);
        let mut outs = Vec::new();
        for strat in [RootStrategy::Center, RootStrategy::First, RootStrategy::Fixed(0)] {
            let sched = Schedule::build(&jt, strat);
            let mut state = TreeState::fresh(&jt);
            calibrate(&jt, &sched, &mut state, &ev, MapMode::Cached, &mut scratch).unwrap();
            outs.push(state.log_z);
        }
        assert!((outs[0] - outs[1]).abs() < 1e-9);
        assert!((outs[0] - outs[2]).abs() < 1e-9);
    }
}
