//! Dense potential tables over sets of discrete variables.
//!
//! A [`Potential`] maps every joint configuration of its variables to a
//! non-negative real. Layout is row-major over the variable list with the
//! **last variable varying fastest**; variable lists are kept sorted by
//! `VarId` so two potentials over the same set share a layout.
//!
//! This type is the *general* (metadata-carrying) interface used for
//! construction, queries, tests and the brute-force oracle. The inference
//! hot path works on raw `&[f64]` slices plus precomputed index maps — see
//! [`crate::jt::ops`] and [`crate::jt::mapping`].

use crate::bn::network::Network;
use crate::bn::variable::VarId;
use crate::jt::mapping::{build_map, Odometer};

/// A dense table over a sorted set of discrete variables.
#[derive(Clone, Debug, PartialEq)]
pub struct Potential {
    /// Variable ids, strictly ascending.
    pub vars: Vec<VarId>,
    /// `cards[i]` = cardinality of `vars[i]`.
    pub cards: Vec<usize>,
    /// Row-major values, `vars.last()` fastest; `len = Π cards`.
    pub data: Vec<f64>,
}

impl Potential {
    /// A constant-1 potential (multiplicative identity) over `vars`.
    pub fn ones(mut vars: Vec<VarId>, all_cards: &[usize]) -> Self {
        vars.sort_unstable();
        vars.dedup();
        let cards: Vec<usize> = vars.iter().map(|&v| all_cards[v]).collect();
        let len: usize = cards.iter().product();
        Potential { vars, cards, data: vec![1.0; len] }
    }

    /// The empty-scope potential holding a single scalar.
    pub fn scalar(value: f64) -> Self {
        Potential { vars: vec![], cards: vec![], data: vec![value] }
    }

    /// Convert the CPT of variable `v` into a potential over its family
    /// `{v} ∪ parents(v)` (sorted).
    pub fn from_cpt(net: &Network, v: VarId) -> Self {
        let cpt = &net.cpts[v];
        let all_cards = net.cards();
        let mut fam: Vec<VarId> = cpt.parents.clone();
        fam.push(v);
        let mut pot = Potential::ones(fam, &all_cards);

        // CPT index order is [parents..., child] (child fastest); the
        // potential is over sorted vars. Walk the potential's entries with
        // an odometer and compute the CPT index from per-variable strides.
        let mut cpt_stride = vec![0usize; pot.vars.len()];
        // child contributes stride 1
        let child_pos = pot.vars.binary_search(&v).unwrap();
        cpt_stride[child_pos] = 1;
        let mut acc = all_cards[v];
        for &p in cpt.parents.iter().rev() {
            let pos = pot.vars.binary_search(&p).unwrap();
            cpt_stride[pos] = acc;
            acc *= all_cards[p];
        }
        let mut odo = Odometer::new(&pot.cards);
        for slot in pot.data.iter_mut() {
            let mut idx = 0usize;
            for (d, &s) in odo.digits().iter().zip(&cpt_stride) {
                idx += d * s;
            }
            *slot = cpt.probs[idx];
            odo.step();
        }
        pot
    }

    /// Number of entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the scope is empty (scalar potential).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.vars.is_empty()
    }

    /// Entry index for a full assignment (indexed by `VarId`).
    pub fn index_of(&self, assignment: &[usize]) -> usize {
        let mut idx = 0usize;
        for (i, &v) in self.vars.iter().enumerate() {
            debug_assert!(assignment[v] < self.cards[i]);
            idx = idx * self.cards[i] + assignment[v];
        }
        idx
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Normalize to sum 1; returns the pre-normalization sum (0 if the
    /// table was all zero, in which case it is left untouched).
    pub fn normalize(&mut self) -> f64 {
        let s = self.sum();
        if s > 0.0 {
            let inv = 1.0 / s;
            for x in &mut self.data {
                *x *= inv;
            }
        }
        s
    }

    /// Multiply a potential over a **subset** of this scope into this one
    /// (table *extension* in the paper's terminology).
    pub fn multiply_in(&mut self, sub: &Potential) {
        debug_assert!(sub.vars.iter().all(|v| self.vars.contains(v)), "multiply_in requires a sub-scope");
        let map = build_map(&self.vars, &self.cards, &sub.vars, &sub.cards);
        for (i, x) in self.data.iter_mut().enumerate() {
            *x *= sub.data[map[i] as usize];
        }
    }

    /// Marginalize onto a subset of the scope (sum out the rest).
    pub fn marginalize_onto(&self, keep: &[VarId]) -> Potential {
        let mut keep: Vec<VarId> = keep.iter().copied().filter(|v| self.vars.contains(v)).collect();
        keep.sort_unstable();
        keep.dedup();
        let cards: Vec<usize> = keep
            .iter()
            .map(|v| self.cards[self.vars.binary_search(v).unwrap()])
            .collect();
        let len: usize = cards.iter().product();
        let mut out = Potential { vars: keep, cards, data: vec![0.0; len] };
        let map = build_map(&self.vars, &self.cards, &out.vars, &out.cards);
        for (i, &x) in self.data.iter().enumerate() {
            out.data[map[i] as usize] += x;
        }
        out
    }

    /// Restrict a variable to one state: zero out all disagreeing entries
    /// (evidence entry; the paper's table *reduction* acts on the result).
    pub fn reduce(&mut self, v: VarId, state: usize) {
        let pos = match self.vars.binary_search(&v) {
            Ok(p) => p,
            Err(_) => return,
        };
        let card = self.cards[pos];
        let stride: usize = self.cards[pos + 1..].iter().product();
        let block = stride * card;
        for chunk in self.data.chunks_mut(block) {
            for s in 0..card {
                if s != state {
                    for x in &mut chunk[s * stride..(s + 1) * stride] {
                        *x = 0.0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn ones_and_scalar() {
        let p = Potential::ones(vec![2, 0], &[2, 3, 4]);
        assert_eq!(p.vars, vec![0, 2]);
        assert_eq!(p.cards, vec![2, 4]);
        assert_eq!(p.len(), 8);
        assert!(p.data.iter().all(|&x| x == 1.0));
        let s = Potential::scalar(3.5);
        assert_eq!(s.len(), 1);
        assert_eq!(s.sum(), 3.5);
    }

    #[test]
    fn from_cpt_root_variable() {
        let net = embedded::asia();
        let a = net.var_id("asia").unwrap();
        let p = Potential::from_cpt(&net, a);
        assert_eq!(p.vars, vec![a]);
        assert_eq!(p.data, vec![0.01, 0.99]);
    }

    #[test]
    fn from_cpt_child_variable_matches_rows() {
        let net = embedded::asia();
        let (tub, asia) = (net.var_id("tub").unwrap(), net.var_id("asia").unwrap());
        let p = Potential::from_cpt(&net, tub);
        // vars sorted: asia < tub (ids follow declaration order: asia=0, tub=1)
        assert_eq!(p.vars, vec![asia, tub]);
        // P(tub=yes|asia=yes)=0.05 etc. Entry (asia=yes, tub=yes) = index 0.
        assert_eq!(p.data, vec![0.05, 0.95, 0.01, 0.99]);
    }

    #[test]
    fn from_cpt_two_parents_or_gate() {
        let net = embedded::asia();
        let either = net.var_id("either").unwrap();
        let lung = net.var_id("lung").unwrap();
        let tub = net.var_id("tub").unwrap();
        let p = Potential::from_cpt(&net, either);
        // P(either=yes | lung, tub) = OR
        let mut assignment = vec![0usize; net.n()];
        for ls in 0..2 {
            for ts in 0..2 {
                for es in 0..2 {
                    assignment[lung] = ls;
                    assignment[tub] = ts;
                    assignment[either] = es;
                    let want = if ls == 0 || ts == 0 {
                        if es == 0 { 1.0 } else { 0.0 }
                    } else if es == 0 {
                        0.0
                    } else {
                        1.0
                    };
                    assert_eq!(p.data[p.index_of(&assignment)], want);
                }
            }
        }
    }

    #[test]
    fn marginalize_inverts_structure() {
        let net = embedded::asia();
        let tub = net.var_id("tub").unwrap();
        let asia = net.var_id("asia").unwrap();
        let joint = {
            // P(asia) * P(tub|asia)
            let mut p = Potential::from_cpt(&net, tub);
            p.multiply_in(&Potential::from_cpt(&net, asia));
            p
        };
        // marginal over asia recovers the prior
        let m = joint.marginalize_onto(&[asia]);
        assert!((m.data[0] - 0.01).abs() < 1e-12);
        assert!((m.data[1] - 0.99).abs() < 1e-12);
        // marginal over tub: P(tub=yes) = .01*.05 + .99*.01
        let m = joint.marginalize_onto(&[tub]);
        assert!((m.data[0] - (0.01 * 0.05 + 0.99 * 0.01)).abs() < 1e-12);
    }

    #[test]
    fn marginalize_onto_empty_gives_total() {
        let net = embedded::asia();
        let p = Potential::from_cpt(&net, net.var_id("asia").unwrap());
        let s = p.marginalize_onto(&[]);
        assert_eq!(s.len(), 1);
        assert!((s.data[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reduce_zeroes_disagreeing_entries() {
        let mut p = Potential::ones(vec![0, 1], &[2, 3]);
        p.reduce(1, 2);
        // entries with var1 != 2 are zero
        assert_eq!(p.data, vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        // reducing a variable not in scope is a no-op
        let before = p.clone();
        p.reduce(7, 0);
        assert_eq!(p, before);
    }

    #[test]
    fn normalize_handles_zero_table() {
        let mut p = Potential { vars: vec![0], cards: vec![2], data: vec![0.0, 0.0] };
        assert_eq!(p.normalize(), 0.0);
        assert_eq!(p.data, vec![0.0, 0.0]);
        let mut q = Potential { vars: vec![0], cards: vec![2], data: vec![1.0, 3.0] };
        assert_eq!(q.normalize(), 4.0);
        assert!((q.data[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn multiply_in_scalar_is_uniform_scale() {
        let mut p = Potential::ones(vec![0], &[3]);
        p.multiply_in(&Potential::scalar(0.5));
        assert_eq!(p.data, vec![0.5; 3]);
    }
}
