//! Index mappings between clique and separator tables.
//!
//! "The key step to the potential table operations is to find the index
//! mappings between the original and the updated tables" (§2). For a
//! clique table over variables `C` and a separator table over `S ⊆ C`,
//! entry `i` of the clique projects to entry `proj(i)` of the separator by
//! keeping only the digits of `S` in the mixed-radix decomposition of `i`.
//!
//! Three strategies are implemented, in increasing order of the
//! "bottleneck simplification" the paper applies:
//!
//! * [`project_divmod`] — recompute each projection with div/mod chains
//!   (what a naive implementation, e.g. UnBBayes, does per entry per
//!   message);
//! * [`Odometer`] — walk entries in order while maintaining the digit
//!   vector and projected index incrementally (O(1) amortized per entry,
//!   no divisions);
//! * [`build_map`] — materialize the projection once per (clique,
//!   separator) edge as a `Vec<u32>` and reuse it for every message of
//!   every test case (the maps depend only on the tree, not the evidence).
//!
//! All three must agree; property tests in this module and in
//! `rust/tests/` check them against each other.

use crate::bn::variable::VarId;

/// Mixed-radix strides of `vars`/`cards` (last variable fastest).
/// `strides[i]` is the step in flat index per unit of digit `i`.
pub fn strides(cards: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; cards.len()];
    for i in (0..cards.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * cards[i + 1];
    }
    s
}

/// For each position in `src_vars`, the stride it contributes to the
/// destination index (0 when the variable is not in `dst_vars`).
pub fn projection_strides(
    src_vars: &[VarId],
    dst_vars: &[VarId],
    dst_cards: &[usize],
) -> Vec<usize> {
    let dst_strides = strides(dst_cards);
    src_vars
        .iter()
        .map(|v| match dst_vars.binary_search(v) {
            Ok(p) => dst_strides[p],
            Err(_) => 0,
        })
        .collect()
}

/// Project a single flat index with div/mod chains (the naive strategy).
#[inline]
pub fn project_divmod(
    src_cards: &[usize],
    src_strides: &[usize],
    proj_strides: &[usize],
    idx: usize,
) -> usize {
    let mut out = 0usize;
    for i in 0..src_cards.len() {
        let digit = (idx / src_strides[i]) % src_cards[i];
        out += digit * proj_strides[i];
    }
    out
}

/// Incremental mixed-radix counter over a card vector, tracking one or two
/// projected indices without any division.
pub struct Odometer {
    cards: Vec<usize>,
    digits: Vec<usize>,
}

impl Odometer {
    /// Counter positioned at entry 0.
    pub fn new(cards: &[usize]) -> Self {
        Odometer { cards: cards.to_vec(), digits: vec![0; cards.len()] }
    }

    /// Current digit vector.
    #[inline]
    pub fn digits(&self) -> &[usize] {
        &self.digits
    }

    /// Advance to the next entry (wraps at the end).
    #[inline]
    pub fn step(&mut self) {
        for i in (0..self.cards.len()).rev() {
            self.digits[i] += 1;
            if self.digits[i] < self.cards[i] {
                return;
            }
            self.digits[i] = 0;
        }
    }
}

/// Incremental projection: walks `0..Π src_cards` in order, yielding the
/// projected destination index per step with O(1) amortized updates.
pub struct ProjectedOdometer {
    cards: Vec<usize>,
    digits: Vec<usize>,
    proj_strides: Vec<usize>,
    /// `wrap_delta[i]` = amount subtracted from the projection when digit
    /// `i` wraps from `cards[i]-1` back to 0: `(cards[i]-1) * proj_strides[i]`.
    wrap_delta: Vec<usize>,
    current: usize,
}

impl ProjectedOdometer {
    /// Build from source cards and per-position projection strides
    /// (see [`projection_strides`]).
    pub fn new(src_cards: &[usize], proj_strides: &[usize]) -> Self {
        debug_assert_eq!(src_cards.len(), proj_strides.len());
        let wrap_delta = src_cards
            .iter()
            .zip(proj_strides)
            .map(|(&c, &s)| (c - 1) * s)
            .collect();
        ProjectedOdometer {
            cards: src_cards.to_vec(),
            digits: vec![0; src_cards.len()],
            proj_strides: proj_strides.to_vec(),
            wrap_delta,
            current: 0,
        }
    }

    /// Projected destination index of the current source entry.
    #[inline]
    pub fn current(&self) -> usize {
        self.current
    }

    /// Advance one source entry.
    #[inline]
    pub fn step(&mut self) {
        for i in (0..self.cards.len()).rev() {
            self.digits[i] += 1;
            if self.digits[i] < self.cards[i] {
                self.current += self.proj_strides[i];
                return;
            }
            self.digits[i] = 0;
            self.current -= self.wrap_delta[i];
        }
    }

    /// Jump to an arbitrary source entry (used to start mid-table when a
    /// parallel chunk begins at `idx`).
    pub fn seek(&mut self, src_strides: &[usize], idx: usize) {
        let mut out = 0usize;
        for i in 0..self.cards.len() {
            let digit = (idx / src_strides[i]) % self.cards[i];
            self.digits[i] = digit;
            out += digit * self.proj_strides[i];
        }
        self.current = out;
    }
}

/// Materialize the full projection map `src index → dst index` (u32 —
/// separator tables beyond 2³² entries are far outside feasible JT sizes).
pub fn build_map(
    src_vars: &[VarId],
    src_cards: &[usize],
    dst_vars: &[VarId],
    dst_cards: &[usize],
) -> Vec<u32> {
    let len: usize = src_cards.iter().product();
    let proj = projection_strides(src_vars, dst_vars, dst_cards);
    let mut odo = ProjectedOdometer::new(src_cards, &proj);
    let mut map = Vec::with_capacity(len);
    for _ in 0..len {
        map.push(odo.current() as u32);
        odo.step();
    }
    map
}

/// Run-compressed projection map (the §Perf "bottleneck simplification"
/// beyond the paper's): the projected index is constant over contiguous
/// runs of `run_len = Π` (cards of source variables *after* the last
/// destination variable). Storing one `u32` per run instead of per entry
/// shrinks map traffic by `run_len`× and turns marginalization/extension
/// inner loops into contiguous (vectorizable) slice ops.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunMap {
    /// `map[r]` = destination index of run `r`.
    pub map: Vec<u32>,
    /// Entries per run (≥ 1). Source length = `map.len() * run_len`.
    pub run_len: usize,
}

/// Build the run-compressed projection (see [`RunMap`]).
pub fn build_run_map(
    src_vars: &[VarId],
    src_cards: &[usize],
    dst_vars: &[VarId],
    dst_cards: &[usize],
) -> RunMap {
    let last_dst_pos = src_vars.iter().rposition(|v| dst_vars.binary_search(v).is_ok());
    match last_dst_pos {
        None => {
            // destination scope is empty (or disjoint): one run, index 0
            let len: usize = src_cards.iter().product();
            RunMap { map: vec![0], run_len: len.max(1) }
        }
        Some(p) => {
            let run_len: usize = src_cards[p + 1..].iter().product::<usize>().max(1);
            let prefix_vars = &src_vars[..=p];
            let prefix_cards = &src_cards[..=p];
            RunMap { map: build_map(prefix_vars, prefix_cards, dst_vars, dst_cards), run_len }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn strides_last_fastest() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn projection_onto_self_is_identity() {
        let vars = [0usize, 2, 5];
        let cards = [2usize, 3, 2];
        let map = build_map(&vars, &cards, &vars, &cards);
        let expect: Vec<u32> = (0..12u32).collect();
        assert_eq!(map, expect);
    }

    #[test]
    fn projection_onto_empty_is_zero() {
        let map = build_map(&[1, 2], &[2, 3], &[], &[]);
        assert!(map.iter().all(|&m| m == 0));
        assert_eq!(map.len(), 6);
    }

    #[test]
    fn divmod_matches_map_small() {
        let src_vars = [0usize, 1, 3];
        let src_cards = [2usize, 3, 4];
        let dst_vars = [1usize, 3];
        let dst_cards = [3usize, 4];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let ss = strides(&src_cards);
        let ps = projection_strides(&src_vars, &dst_vars, &dst_cards);
        for i in 0..24 {
            assert_eq!(map[i] as usize, project_divmod(&src_cards, &ss, &ps, i));
        }
    }

    #[test]
    fn all_strategies_agree_randomized() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            // random source scope of 1..5 vars with cards 1..5
            let k = rng.range(1, 4);
            let mut src_vars: Vec<usize> = (0..10).collect();
            rng.shuffle(&mut src_vars);
            src_vars.truncate(k);
            src_vars.sort_unstable();
            let src_cards: Vec<usize> = (0..k).map(|_| rng.range(1, 4)).collect();
            // random subset as destination
            let keep: Vec<bool> = (0..k).map(|_| rng.chance(0.6)).collect();
            let dst_vars: Vec<usize> =
                src_vars.iter().zip(&keep).filter(|&(_, &k)| k).map(|(&v, _)| v).collect();
            let dst_cards: Vec<usize> =
                src_cards.iter().zip(&keep).filter(|&(_, &k)| k).map(|(&c, _)| c).collect();

            let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
            let ss = strides(&src_cards);
            let ps = projection_strides(&src_vars, &dst_vars, &dst_cards);
            let len: usize = src_cards.iter().product();
            let dst_len: usize = dst_cards.iter().product();
            let mut odo = ProjectedOdometer::new(&src_cards, &ps);
            for i in 0..len {
                let dm = project_divmod(&src_cards, &ss, &ps, i);
                assert_eq!(map[i] as usize, dm);
                assert_eq!(odo.current(), dm);
                assert!(dm < dst_len.max(1));
                odo.step();
            }
        }
    }

    #[test]
    fn seek_matches_sequential_walk() {
        let src_cards = [3usize, 2, 4];
        let ps = [8usize, 0, 1]; // project onto vars 0 and 2, dst cards (3,4)... strides (4,1)*? arbitrary but consistent
        let ss = strides(&src_cards);
        let mut walker = ProjectedOdometer::new(&src_cards, &ps);
        for i in 0..24 {
            let mut seeker = ProjectedOdometer::new(&src_cards, &ps);
            seeker.seek(&ss, i);
            assert_eq!(seeker.current(), walker.current(), "at {i}");
            walker.step();
        }
    }

    #[test]
    fn run_map_expands_to_entry_map() {
        let mut rng = Rng::new(123);
        for _ in 0..40 {
            let k = rng.range(1, 4);
            let mut src_vars: Vec<usize> = (0..10).collect();
            rng.shuffle(&mut src_vars);
            src_vars.truncate(k);
            src_vars.sort_unstable();
            let src_cards: Vec<usize> = (0..k).map(|_| rng.range(1, 4)).collect();
            let keep: Vec<bool> = (0..k).map(|_| rng.chance(0.5)).collect();
            let dst_vars: Vec<usize> =
                src_vars.iter().zip(&keep).filter(|&(_, &kp)| kp).map(|(&v, _)| v).collect();
            let dst_cards: Vec<usize> =
                src_cards.iter().zip(&keep).filter(|&(_, &kp)| kp).map(|(&c, _)| c).collect();
            let entry = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
            let rm = build_run_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
            assert_eq!(rm.map.len() * rm.run_len, entry.len(), "size mismatch");
            for (i, &e) in entry.iter().enumerate() {
                assert_eq!(rm.map[i / rm.run_len], e, "entry {i}");
            }
        }
    }

    #[test]
    fn run_map_empty_destination() {
        let rm = build_run_map(&[1, 2], &[3, 4], &[], &[]);
        assert_eq!(rm.run_len, 12);
        assert_eq!(rm.map, vec![0]);
    }

    #[test]
    fn run_map_trailing_destination_has_unit_runs() {
        // dst is the LAST src var -> run_len = 1
        let rm = build_run_map(&[0, 1], &[2, 3], &[1], &[3]);
        assert_eq!(rm.run_len, 1);
        assert_eq!(rm.map.len(), 6);
    }

    #[test]
    fn run_map_leading_destination_has_long_runs() {
        // dst is the FIRST src var -> run_len = product of the rest
        let rm = build_run_map(&[0, 1, 2], &[2, 3, 4], &[0], &[2]);
        assert_eq!(rm.run_len, 12);
        assert_eq!(rm.map, vec![0, 1]);
    }

    #[test]
    fn projection_counts_preimages_evenly() {
        // every destination entry must have the same number of sources
        let src_vars = [0usize, 1, 2];
        let src_cards = [2usize, 3, 4];
        let dst_vars = [1usize];
        let dst_cards = [3usize];
        let map = build_map(&src_vars, &src_cards, &dst_vars, &dst_cards);
        let mut counts = [0usize; 3];
        for &m in &map {
            counts[m as usize] += 1;
        }
        assert_eq!(counts, [8, 8, 8]);
    }
}
