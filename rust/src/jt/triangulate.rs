//! Triangulation of the moral graph and maximal-clique extraction.
//!
//! Exact minimum-fill triangulation is NP-hard; like the paper's pipeline
//! (and every practical JT implementation) we use greedy elimination
//! heuristics. The elimination order determines the clique-size
//! distribution, which in turn drives every cost the paper measures.

use std::collections::HashSet;

use crate::jt::moralize::UGraph;

/// Greedy elimination heuristic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TriangulationHeuristic {
    /// Eliminate the vertex introducing the fewest fill-in edges
    /// (ties: smaller weighted clique, then smaller index). The default —
    /// matches FastBN's choice.
    MinFill,
    /// Eliminate the vertex of minimum degree (ties: smaller index).
    MinDegree,
    /// Eliminate the vertex minimizing the log-state-space of the clique
    /// it would form ("min-weight").
    MinWeight,
}

impl std::str::FromStr for TriangulationHeuristic {
    type Err = crate::Error;
    fn from_str(s: &str) -> crate::Result<Self> {
        match s {
            "min-fill" | "minfill" => Ok(Self::MinFill),
            "min-degree" | "mindegree" => Ok(Self::MinDegree),
            "min-weight" | "minweight" => Ok(Self::MinWeight),
            other => Err(crate::Error::msg(format!("unknown heuristic {other:?}"))),
        }
    }
}

/// Result of triangulation: the elimination order, the filled (chordal)
/// graph, and the elimination cliques (one per vertex, not yet maximal).
pub struct Triangulation {
    /// Vertices in elimination order.
    pub order: Vec<usize>,
    /// The chordal graph (moral + fill edges).
    pub filled: UGraph,
    /// `cliques[i]` = sorted `{order[i]} ∪ N(order[i])` at elimination time.
    pub cliques: Vec<Vec<usize>>,
}

/// Triangulate `g` (consumed as a working copy) with the given heuristic.
/// `weights[v]` is the log-cardinality of `v`, used by `MinWeight` and for
/// tie-breaking in `MinFill`.
pub fn triangulate(g: &UGraph, weights: &[f64], heuristic: TriangulationHeuristic) -> Triangulation {
    let n = g.n();
    let mut work: Vec<HashSet<usize>> = g.adj.iter().map(|l| l.iter().copied().collect()).collect();
    let mut filled = g.clone();
    let mut alive: Vec<bool> = vec![true; n];
    let mut order = Vec::with_capacity(n);
    let mut cliques = Vec::with_capacity(n);

    // Score of eliminating v under the heuristic (lower is better).
    let score = |work: &Vec<HashSet<usize>>, v: usize, heuristic: TriangulationHeuristic| -> (f64, f64) {
        match heuristic {
            TriangulationHeuristic::MinDegree => (work[v].len() as f64, 0.0),
            TriangulationHeuristic::MinWeight => {
                let w: f64 = work[v].iter().map(|&u| weights[u]).sum::<f64>() + weights[v];
                (w, work[v].len() as f64)
            }
            TriangulationHeuristic::MinFill => {
                let neigh: Vec<usize> = work[v].iter().copied().collect();
                let mut fill = 0usize;
                for (i, &a) in neigh.iter().enumerate() {
                    for &b in &neigh[i + 1..] {
                        if !work[a].contains(&b) {
                            fill += 1;
                        }
                    }
                }
                let w: f64 = neigh.iter().map(|&u| weights[u]).sum::<f64>() + weights[v];
                (fill as f64, w)
            }
        }
    };

    for _ in 0..n {
        // pick the best alive vertex
        let mut best: Option<(usize, (f64, f64))> = None;
        for v in 0..n {
            if !alive[v] {
                continue;
            }
            let s = score(&work, v, heuristic);
            let better = match &best {
                None => true,
                Some((bv, bs)) => s < *bs || (s == *bs && v < *bv),
            };
            if better {
                best = Some((v, s));
            }
        }
        let (v, _) = best.expect("there is always an alive vertex");

        // record elimination clique
        let mut clique: Vec<usize> = work[v].iter().copied().collect();
        clique.push(v);
        clique.sort_unstable();
        cliques.push(clique);

        // connect neighbors (fill-in)
        let neigh: Vec<usize> = work[v].iter().copied().collect();
        for (i, &a) in neigh.iter().enumerate() {
            for &b in &neigh[i + 1..] {
                if work[a].insert(b) {
                    work[b].insert(a);
                    filled.add_edge(a, b);
                }
            }
        }
        // remove v
        for &u in &neigh {
            work[u].remove(&v);
        }
        work[v].clear();
        alive[v] = false;
        order.push(v);
    }

    Triangulation { order, filled, cliques }
}

/// Filter elimination cliques down to the maximal ones (no clique contained
/// in another). Quadratic subset filtering — runs once per network.
pub fn maximal_cliques(elim_cliques: &[Vec<usize>]) -> Vec<Vec<usize>> {
    // sort by size descending so containers come first
    let mut sorted: Vec<&Vec<usize>> = elim_cliques.iter().collect();
    sorted.sort_by_key(|c| std::cmp::Reverse(c.len()));
    let mut keep: Vec<Vec<usize>> = Vec::new();
    'next: for cand in sorted {
        for k in &keep {
            if is_subset(cand, k) {
                continue 'next;
            }
        }
        keep.push(cand.clone());
    }
    keep
}

/// `a ⊆ b` for sorted slices.
pub fn is_subset(a: &[usize], b: &[usize]) -> bool {
    if a.len() > b.len() {
        return false;
    }
    let mut it = b.iter();
    'outer: for x in a {
        for y in it.by_ref() {
            if y == x {
                continue 'outer;
            }
            if y > x {
                return false;
            }
        }
        return false;
    }
    true
}

/// Verify chordality of `g` given a perfect elimination order — used by
/// tests to check the triangulation output.
pub fn is_chordal_with_order(g: &UGraph, order: &[usize]) -> bool {
    let n = g.n();
    let mut pos = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }
    for &v in order {
        // later neighbors of v must form a clique
        let later: Vec<usize> = g.adj[v].iter().copied().filter(|&u| pos[u] > pos[v]).collect();
        for (i, &a) in later.iter().enumerate() {
            for &b in &later[i + 1..] {
                if !g.has_edge(a, b) {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::moralize::moralize;

    fn log_cards(net: &crate::bn::network::Network) -> Vec<f64> {
        net.cards().iter().map(|&c| (c as f64).ln()).collect()
    }

    #[test]
    fn subset_check() {
        assert!(is_subset(&[1, 3], &[1, 2, 3]));
        assert!(is_subset(&[], &[1]));
        assert!(!is_subset(&[1, 4], &[1, 2, 3]));
        assert!(!is_subset(&[1, 2, 3], &[1, 2]));
        assert!(is_subset(&[2], &[2]));
    }

    #[test]
    fn cycle4_gets_fill_edge() {
        // 4-cycle needs exactly one chord
        let mut g = UGraph::new(4);
        for (a, b) in [(0, 1), (1, 2), (2, 3), (3, 0)] {
            g.add_edge(a, b);
        }
        for h in [
            TriangulationHeuristic::MinFill,
            TriangulationHeuristic::MinDegree,
            TriangulationHeuristic::MinWeight,
        ] {
            let t = triangulate(&g, &[1.0; 4], h);
            assert_eq!(t.filled.n_edges(), 5, "{h:?}");
            assert!(is_chordal_with_order(&t.filled, &t.order), "{h:?}");
        }
    }

    #[test]
    fn chordal_graph_gets_no_fill() {
        // a triangle + pendant is already chordal
        let mut g = UGraph::new(4);
        for (a, b) in [(0, 1), (1, 2), (0, 2), (2, 3)] {
            g.add_edge(a, b);
        }
        let t = triangulate(&g, &[1.0; 4], TriangulationHeuristic::MinFill);
        assert_eq!(t.filled.n_edges(), g.n_edges());
    }

    #[test]
    fn asia_cliques_match_literature() {
        // The Asia JT famously has 6 cliques, all of size ≤ 3.
        let net = embedded::asia();
        let g = moralize(&net);
        let t = triangulate(&g, &log_cards(&net), TriangulationHeuristic::MinFill);
        assert!(is_chordal_with_order(&t.filled, &t.order));
        let cliques = maximal_cliques(&t.cliques);
        assert_eq!(cliques.len(), 6);
        assert!(cliques.iter().all(|c| c.len() <= 3));
    }

    #[test]
    fn maximal_cliques_have_no_containment() {
        let net = embedded::mixed12();
        let g = moralize(&net);
        for h in [
            TriangulationHeuristic::MinFill,
            TriangulationHeuristic::MinDegree,
            TriangulationHeuristic::MinWeight,
        ] {
            let t = triangulate(&g, &log_cards(&net), h);
            let cliques = maximal_cliques(&t.cliques);
            for (i, a) in cliques.iter().enumerate() {
                for (j, b) in cliques.iter().enumerate() {
                    if i != j {
                        assert!(!is_subset(a, b), "clique {a:?} ⊆ {b:?}");
                    }
                }
            }
            // every vertex appears in some clique
            let mut seen = vec![false; net.n()];
            for c in &cliques {
                for &v in c {
                    seen[v] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn every_family_is_covered_by_filled_graph_cliques() {
        // moralization + triangulation must keep each family together
        let net = embedded::asia();
        let g = moralize(&net);
        let t = triangulate(&g, &log_cards(&net), TriangulationHeuristic::MinFill);
        let cliques = maximal_cliques(&t.cliques);
        for v in 0..net.n() {
            let mut fam: Vec<usize> = net.parents(v).to_vec();
            fam.push(v);
            fam.sort_unstable();
            assert!(
                cliques.iter().any(|c| is_subset(&fam, c)),
                "family of {v} not contained in any clique"
            );
        }
    }

    #[test]
    fn heuristic_parses_from_str() {
        assert_eq!("min-fill".parse::<TriangulationHeuristic>().unwrap(), TriangulationHeuristic::MinFill);
        assert_eq!("mindegree".parse::<TriangulationHeuristic>().unwrap(), TriangulationHeuristic::MinDegree);
        assert!("bogus".parse::<TriangulationHeuristic>().is_err());
    }
}
