//! The paper's inter-clique machinery: BFS leveling + root selection.
//!
//! §2: *"Our traversal method views all the cliques and separators as
//! nodes of the tree and marks the layer where each of them is located"* —
//! [`Schedule::build`] roots the tree (forest) and records, per depth
//! layer, the set of messages whose dependencies are satisfied, so all
//! messages of a layer can run concurrently.
//!
//! *"We employ a root selection strategy to construct a more balanced tree
//! with the minimal number of layers"* — [`RootStrategy::Center`] picks the
//! tree center (midpoint of a diameter path), which minimizes tree height
//! and hence the number of parallel-region invocations.

use crate::jt::tree::JunctionTree;

/// How to pick the root clique of each tree in the forest.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RootStrategy {
    /// Tree center — minimal height (the paper's strategy, default).
    #[default]
    Center,
    /// First clique of each component (the naive baseline ablated in
    /// `benches/ablation.rs`).
    First,
    /// A fixed clique id (single-tree networks only; useful in tests).
    Fixed(usize),
}

/// One message: clique `from` sends to clique `to` through separator `sep`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Msg {
    pub from: usize,
    pub to: usize,
    pub sep: usize,
}

/// A rooted traversal schedule over the junction forest.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Root clique of each component.
    pub roots: Vec<usize>,
    /// `parent[c]` = (parent clique, separator) or None for roots.
    pub parent: Vec<Option<(usize, usize)>>,
    /// `children[c]` = (child clique, separator) pairs.
    pub children: Vec<Vec<(usize, usize)>>,
    /// BFS depth per clique (roots at 0).
    pub depth: Vec<usize>,
    /// `levels[d]` = cliques at depth `d`.
    pub levels: Vec<Vec<usize>>,
    /// Collect-phase layers, deepest first: `up_layers[i]` holds all
    /// messages from depth `height-i` cliques to their parents.
    pub up_layers: Vec<Vec<Msg>>,
    /// Distribute-phase layers, shallowest first.
    pub down_layers: Vec<Vec<Msg>>,
}

impl Schedule {
    /// Build the schedule for a tree under a root strategy.
    pub fn build(jt: &JunctionTree, strategy: RootStrategy) -> Schedule {
        let m = jt.n_cliques();
        let mut comp = vec![usize::MAX; m];
        let mut comps: Vec<Vec<usize>> = Vec::new();
        for start in 0..m {
            if comp[start] != usize::MAX {
                continue;
            }
            let id = comps.len();
            let mut members = Vec::new();
            let mut queue = std::collections::VecDeque::new();
            comp[start] = id;
            queue.push_back(start);
            while let Some(c) = queue.pop_front() {
                members.push(c);
                for &(nb, _) in &jt.adj[c] {
                    if comp[nb] == usize::MAX {
                        comp[nb] = id;
                        queue.push_back(nb);
                    }
                }
            }
            comps.push(members);
        }

        let roots: Vec<usize> = comps
            .iter()
            .map(|members| match strategy {
                RootStrategy::First => members[0],
                RootStrategy::Fixed(r) => {
                    assert!(members.contains(&r) || comps.len() > 1, "fixed root must be a clique id");
                    if members.contains(&r) {
                        r
                    } else {
                        members[0]
                    }
                }
                RootStrategy::Center => tree_center(jt, members),
            })
            .collect();

        // BFS from the roots
        let mut parent: Vec<Option<(usize, usize)>> = vec![None; m];
        let mut children: Vec<Vec<(usize, usize)>> = vec![Vec::new(); m];
        let mut depth = vec![usize::MAX; m];
        let mut queue = std::collections::VecDeque::new();
        for &r in &roots {
            depth[r] = 0;
            queue.push_back(r);
        }
        let mut order = Vec::with_capacity(m);
        while let Some(c) = queue.pop_front() {
            order.push(c);
            for &(nb, sid) in &jt.adj[c] {
                if depth[nb] == usize::MAX {
                    depth[nb] = depth[c] + 1;
                    parent[nb] = Some((c, sid));
                    children[c].push((nb, sid));
                    queue.push_back(nb);
                }
            }
        }
        debug_assert_eq!(order.len(), m);

        let height = depth.iter().copied().max().unwrap_or(0);
        let mut levels: Vec<Vec<usize>> = vec![Vec::new(); height + 1];
        for c in 0..m {
            levels[depth[c]].push(c);
        }

        // collect: messages from depth d to d-1, for d = height .. 1
        let mut up_layers = Vec::with_capacity(height);
        for d in (1..=height).rev() {
            let layer: Vec<Msg> = levels[d]
                .iter()
                .filter_map(|&c| parent[c].map(|(p, sid)| Msg { from: c, to: p, sep: sid }))
                .collect();
            up_layers.push(layer);
        }
        // distribute: messages from depth d to d+1, for d = 0 .. height-1
        let mut down_layers = Vec::with_capacity(height);
        for d in 0..height {
            let layer: Vec<Msg> = levels[d]
                .iter()
                .flat_map(|&c| children[c].iter().map(move |&(ch, sid)| Msg { from: c, to: ch, sep: sid }))
                .collect();
            down_layers.push(layer);
        }

        Schedule { roots, parent, children, depth, levels, up_layers, down_layers }
    }

    /// Tree height (number of message layers per phase).
    pub fn height(&self) -> usize {
        self.up_layers.len()
    }

    /// Total number of messages per phase (= #separators).
    pub fn n_messages(&self) -> usize {
        self.up_layers.iter().map(|l| l.len()).sum()
    }
}

/// Center of one tree component: run BFS from an arbitrary member to find
/// the farthest clique `u`, BFS again from `u` to find the diameter path,
/// return its midpoint — the vertex minimizing eccentricity, i.e. the root
/// of minimal height.
fn tree_center(jt: &JunctionTree, members: &[usize]) -> usize {
    let u = bfs_farthest(jt, members[0]).0;
    let (_v, path) = bfs_farthest_with_path(jt, u);
    path[path.len() / 2]
}

fn bfs_farthest(jt: &JunctionTree, start: usize) -> (usize, usize) {
    let mut dist = std::collections::HashMap::new();
    dist.insert(start, 0usize);
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(start);
    let mut far = (start, 0usize);
    while let Some(c) = queue.pop_front() {
        let d = dist[&c];
        if d > far.1 || (d == far.1 && c < far.0) {
            far = (c, d);
        }
        for &(nb, _) in &jt.adj[c] {
            if !dist.contains_key(&nb) {
                dist.insert(nb, d + 1);
                queue.push_back(nb);
            }
        }
    }
    far
}

fn bfs_farthest_with_path(jt: &JunctionTree, start: usize) -> (usize, Vec<usize>) {
    let mut prev: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    let mut dist = std::collections::HashMap::new();
    dist.insert(start, 0usize);
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(start);
    let mut far = (start, 0usize);
    while let Some(c) = queue.pop_front() {
        let d = dist[&c];
        if d > far.1 || (d == far.1 && c < far.0) {
            far = (c, d);
        }
        for &(nb, _) in &jt.adj[c] {
            if !dist.contains_key(&nb) {
                dist.insert(nb, d + 1);
                prev.insert(nb, c);
                queue.push_back(nb);
            }
        }
    }
    // reconstruct path start -> far.0
    let mut path = vec![far.0];
    let mut cur = far.0;
    while let Some(&p) = prev.get(&cur) {
        path.push(p);
        cur = p;
    }
    path.reverse();
    (far.0, path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};
    use crate::jt::triangulate::TriangulationHeuristic;
    use crate::jt::tree::JunctionTree;

    fn compile(net: &crate::bn::network::Network) -> JunctionTree {
        JunctionTree::compile(net, TriangulationHeuristic::MinFill).unwrap()
    }

    #[test]
    fn schedule_covers_all_messages_once() {
        let jt = compile(&embedded::asia());
        let s = Schedule::build(&jt, RootStrategy::Center);
        assert_eq!(s.n_messages(), jt.seps.len());
        // every separator appears exactly once per phase
        let mut seen = vec![0usize; jt.seps.len()];
        for layer in &s.up_layers {
            for m in layer {
                seen[m.sep] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn up_layers_respect_dependencies() {
        // a clique must send to its parent only after all its children sent
        let jt = compile(&embedded::mixed12());
        let s = Schedule::build(&jt, RootStrategy::Center);
        let mut sent = vec![false; jt.n_cliques()];
        for layer in &s.up_layers {
            for m in layer {
                // all children of m.from must have sent already
                for &(ch, _) in &s.children[m.from] {
                    assert!(sent[ch], "clique {} sent before child {}", m.from, ch);
                }
            }
            for m in layer {
                sent[m.from] = true;
            }
        }
    }

    #[test]
    fn down_layers_respect_dependencies() {
        let jt = compile(&embedded::mixed12());
        let s = Schedule::build(&jt, RootStrategy::Center);
        let mut received = vec![false; jt.n_cliques()];
        for &r in &s.roots {
            received[r] = true;
        }
        for layer in &s.down_layers {
            for m in layer {
                assert!(received[m.from], "clique {} sends down before receiving", m.from);
            }
            for m in layer {
                received[m.to] = true;
            }
        }
        assert!(received.iter().all(|&r| r));
    }

    #[test]
    fn center_root_minimizes_height() {
        for seed in 0..10 {
            let net = netgen::tiny_random(seed + 100, 8);
            let jt = compile(&net);
            let center = Schedule::build(&jt, RootStrategy::Center);
            // center height must be <= height from any fixed root
            for r in 0..jt.n_cliques() {
                let fixed = Schedule::build(&jt, RootStrategy::Fixed(r));
                assert!(
                    center.height() <= fixed.height(),
                    "seed {seed}: center {} > fixed({r}) {}",
                    center.height(),
                    fixed.height()
                );
            }
        }
    }

    #[test]
    fn depths_are_bfs_consistent() {
        let jt = compile(&embedded::asia());
        let s = Schedule::build(&jt, RootStrategy::First);
        for c in 0..jt.n_cliques() {
            match s.parent[c] {
                None => assert_eq!(s.depth[c], 0),
                Some((p, _)) => assert_eq!(s.depth[c], s.depth[p] + 1),
            }
        }
    }

    #[test]
    fn forest_has_one_root_per_component() {
        use crate::bn::cpt::Cpt;
        use crate::bn::network::Network;
        use crate::bn::variable::Variable;
        let vars = vec![Variable::with_card("a", 2), Variable::with_card("b", 2)];
        let cpts = vec![
            Cpt::new(0, vec![], vec![0.5, 0.5], &[2, 2]).unwrap(),
            Cpt::new(1, vec![], vec![0.5, 0.5], &[2, 2]).unwrap(),
        ];
        let net = Network::new("two", vars, cpts).unwrap();
        let jt = compile(&net);
        let s = Schedule::build(&jt, RootStrategy::Center);
        assert_eq!(s.roots.len(), 2);
        assert_eq!(s.height(), 0);
    }
}
