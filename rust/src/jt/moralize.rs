//! Moralization: DAG → undirected moral graph.
//!
//! The moral graph connects every variable to its parents and "marries"
//! co-parents (connects every pair of parents of a common child), then
//! drops edge directions. Triangulating this graph yields the cliques of
//! the junction tree.

use crate::bn::network::Network;

/// Undirected graph as sorted adjacency lists (no self-loops, no dups).
#[derive(Clone, Debug, Default)]
pub struct UGraph {
    /// `adj[v]` = sorted neighbor list of `v`.
    pub adj: Vec<Vec<usize>>,
}

impl UGraph {
    /// Empty graph on `n` vertices.
    pub fn new(n: usize) -> Self {
        UGraph { adj: vec![Vec::new(); n] }
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.adj.len()
    }

    /// Insert an undirected edge (idempotent).
    pub fn add_edge(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        if let Err(pos) = self.adj[a].binary_search(&b) {
            self.adj[a].insert(pos, b);
        }
        if let Err(pos) = self.adj[b].binary_search(&a) {
            self.adj[b].insert(pos, a);
        }
    }

    /// Edge membership test.
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.adj[a].binary_search(&b).is_ok()
    }

    /// Total number of undirected edges.
    pub fn n_edges(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    /// Connected-component label per vertex (labels are 0..k, BFS order).
    pub fn components(&self) -> Vec<usize> {
        let n = self.n();
        let mut comp = vec![usize::MAX; n];
        let mut next = 0usize;
        let mut queue = std::collections::VecDeque::new();
        for start in 0..n {
            if comp[start] != usize::MAX {
                continue;
            }
            comp[start] = next;
            queue.push_back(start);
            while let Some(v) = queue.pop_front() {
                for &w in &self.adj[v] {
                    if comp[w] == usize::MAX {
                        comp[w] = next;
                        queue.push_back(w);
                    }
                }
            }
            next += 1;
        }
        comp
    }
}

/// Build the moral graph of a network.
pub fn moralize(net: &Network) -> UGraph {
    let mut g = UGraph::new(net.n());
    for v in 0..net.n() {
        let parents = net.parents(v);
        for &p in parents {
            g.add_edge(v, p);
        }
        // marry co-parents
        for (i, &p) in parents.iter().enumerate() {
            for &q in &parents[i + 1..] {
                g.add_edge(p, q);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn ugraph_basics() {
        let mut g = UGraph::new(4);
        g.add_edge(0, 1);
        g.add_edge(1, 0); // duplicate
        g.add_edge(2, 2); // self-loop ignored
        assert_eq!(g.n_edges(), 1);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    fn sprinkler_moral_marries_coparents() {
        // sprinkler and rain are co-parents of wetgrass -> married
        let net = embedded::sprinkler();
        let g = moralize(&net);
        let s = net.var_id("sprinkler").unwrap();
        let r = net.var_id("rain").unwrap();
        assert!(g.has_edge(s, r));
        // cloudy-wetgrass not adjacent
        let c = net.var_id("cloudy").unwrap();
        let w = net.var_id("wetgrass").unwrap();
        assert!(!g.has_edge(c, w));
        // 4 directed arcs + 1 marriage
        assert_eq!(g.n_edges(), 5);
    }

    #[test]
    fn asia_moral_edge_count() {
        // asia has 8 arcs; marriages: (lung,tub) for either, (bronc,either)
        // for dysp -> 10 moral edges
        let net = embedded::asia();
        let g = moralize(&net);
        assert_eq!(g.n_edges(), 10);
    }

    #[test]
    fn components_of_disconnected_graph() {
        let mut g = UGraph::new(5);
        g.add_edge(0, 1);
        g.add_edge(3, 4);
        let comp = g.components();
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[3], comp[4]);
        assert_ne!(comp[0], comp[2]);
        assert_ne!(comp[2], comp[3]);
    }
}
