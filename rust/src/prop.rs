//! Minimal property-based testing framework.
//!
//! `proptest` is not available in this offline environment, so this module
//! provides the subset the test suite needs: seeded generators, a
//! check-N-cases runner with failure reporting, and simple input shrinking
//! for integer-tuple parameters. Every failure report includes the case
//! seed so it can be replayed deterministically.
//!
//! ```
//! use fastbn::prop::{forall, Config};
//!
//! forall(Config::cases(50), |rng| {
//!     let n = rng.range(1, 100);
//!     let xs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     // property: sorting is idempotent
//!     let mut again = sorted.clone();
//!     again.sort_unstable();
//!     if again == sorted { Ok(()) } else { Err("sort not idempotent".into()) }
//! });
//! ```

use crate::rng::Rng;

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
    /// Name shown in failure reports.
    pub name: &'static str,
}

impl Config {
    /// `cases` random cases with the default base seed.
    pub fn cases(cases: usize) -> Self {
        Config { cases, base_seed: default_seed(), name: "property" }
    }

    /// Set the report name.
    pub fn named(mut self, name: &'static str) -> Self {
        self.name = name;
        self
    }

    /// Set the base seed.
    pub fn seeded(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }
}

const fn default_seed() -> u64 {
    0x5EED_F00D
}

/// Run `prop` on `config.cases` seeded generators; panic with the failing
/// seed on the first `Err`.
pub fn forall(config: Config, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for i in 0..config.cases {
        let seed = config.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {:?} failed on case {}/{} (replay seed: {:#x}): {}",
                config.name,
                i + 1,
                config.cases,
                seed,
                msg
            );
        }
    }
}

/// Run `prop` over an explicit list of seeds (for regression pinning).
pub fn forall_seeds(name: &str, seeds: &[u64], prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for &seed in seeds {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed (replay seed: {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper: build an `Err` with context when `cond` is false.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

/// Approximate equality helper for property bodies.
pub fn ensure_close(a: f64, b: f64, tol: f64, label: &str) -> Result<(), String> {
    ensure((a - b).abs() <= tol, || format!("{label}: {a} vs {b} (tol {tol})"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        forall(Config::cases(25), |_rng| {
            **counter.borrow_mut() += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        forall(Config::cases(10).named("always-fails"), |_rng| Err("boom".into()));
    }

    #[test]
    fn seeds_are_deterministic_across_runs() {
        let first = std::cell::RefCell::new(Vec::new());
        forall(Config::cases(5).seeded(7), |rng| {
            first.borrow_mut().push(rng.next_u64());
            Ok(())
        });
        let second = std::cell::RefCell::new(Vec::new());
        forall(Config::cases(5).seeded(7), |rng| {
            second.borrow_mut().push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first.into_inner(), second.into_inner());
    }

    #[test]
    fn ensure_helpers() {
        assert!(ensure(true, || "x".into()).is_ok());
        assert!(ensure(false, || "x".into()).is_err());
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9, "v").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-9, "v").is_err());
    }

    #[test]
    fn forall_seeds_runs_each() {
        let seen = std::cell::RefCell::new(Vec::new());
        forall_seeds("pin", &[1, 2, 3], |rng| {
            seen.borrow_mut().push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.borrow().len(), 3);
    }
}
