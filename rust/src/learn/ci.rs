//! Conditional-independence testing: the G² (likelihood-ratio) test.
//!
//! One test asks whether `X ⊥ Y | Z` in the data. The contingency table
//! over `(Z-configuration, X, Y)` is assembled in **one pass** over the
//! rows (the column-major [`crate::learn::Dataset`] makes that pass touch
//! only the tested columns), then
//!
//! ```text
//! G² = 2 Σ_{z,x,y} n_xyz · ln( n_xyz · n_z / (n_xz · n_yz) )
//! ```
//!
//! is referred to a chi-squared upper tail. Degrees of freedom are
//! **adaptive** (the bnlearn/Tetrad convention): each non-empty
//! Z-stratum contributes `(rx−1)(ry−1)` where `rx`/`ry` count the X/Y
//! values actually observed in that stratum. This matters beyond small-
//! sample hygiene: a variable that is a *deterministic* function of the
//! conditioning set (asia's `either` given `{lung, tub}`) shows zero
//! variance in every stratum, and the classical fixed dof would turn that
//! structural zero into "independent", deleting true edges. An adaptive
//! dof of **zero** instead marks the test *uninformative* — it cannot
//! support independence, and the edge survives to be tested elsewhere.
//!
//! Scratch buffers (contingency table, margin vectors) live in
//! [`CiScratch`] so the PC driver can keep one per worker and run an
//! entire level of tests with no steady-state allocation beyond the
//! per-test conditioning-column list.

use crate::learn::data::Dataset;

/// Reusable per-worker scratch: the contingency table plus the
/// per-stratum X/Y margin buffers, so the hot parallel CI loop's only
/// steady-state allocation is the tiny per-test `zcols` slice list.
#[derive(Default)]
pub struct CiScratch {
    counts: Vec<u32>,
    n_x: Vec<u32>,
    n_y: Vec<u32>,
}

/// Outcome of one G² test.
#[derive(Clone, Copy, Debug)]
pub struct CiOutcome {
    /// `p > alpha` with informative (non-zero) degrees of freedom.
    pub independent: bool,
    /// Upper-tail p-value (0.0 when the test was uninformative).
    pub p: f64,
    /// The G² statistic.
    pub g2: f64,
    /// Adaptive degrees of freedom (0 ⇒ uninformative).
    pub dof: usize,
}

/// Run `X ⊥ Y | Z` on the dataset at significance `alpha`.
pub fn g_squared(data: &Dataset, x: usize, y: usize, zs: &[usize], alpha: f64, scratch: &mut CiScratch) -> CiOutcome {
    let cx = data.card(x);
    let cy = data.card(y);
    let nz: usize = zs.iter().map(|&z| data.card(z)).product();
    let table = nz * cx * cy;
    if scratch.counts.len() < table {
        scratch.counts.resize(table, 0);
    }
    let counts = &mut scratch.counts[..table];
    counts.fill(0);

    // one pass: row -> (z-config, x, y) cell
    let col_x = data.col(x);
    let col_y = data.col(y);
    let zcols: Vec<(&[u32], usize)> = zs.iter().map(|&z| (data.col(z), data.card(z))).collect();
    for r in 0..data.n_rows() {
        let mut zi = 0usize;
        for (zc, card) in &zcols {
            zi = zi * card + zc[r] as usize;
        }
        counts[(zi * cx + col_x[r] as usize) * cy + col_y[r] as usize] += 1;
    }

    // per-stratum margins, statistic, and adaptive dof
    let mut g2 = 0.0f64;
    let mut dof = 0usize;
    if scratch.n_x.len() < cx {
        scratch.n_x.resize(cx, 0);
    }
    if scratch.n_y.len() < cy {
        scratch.n_y.resize(cy, 0);
    }
    let n_x = &mut scratch.n_x[..cx];
    let n_y = &mut scratch.n_y[..cy];
    for zi in 0..nz {
        let cell = &counts[zi * cx * cy..(zi + 1) * cx * cy];
        let n_z: u64 = cell.iter().map(|&c| c as u64).sum();
        if n_z == 0 {
            continue;
        }
        for (a, nx) in n_x.iter_mut().enumerate() {
            *nx = cell[a * cy..(a + 1) * cy].iter().sum();
        }
        for (b, ny) in n_y.iter_mut().enumerate() {
            *ny = (0..cx).map(|a| cell[a * cy + b]).sum();
        }
        let rx = n_x.iter().filter(|&&v| v > 0).count();
        let ry = n_y.iter().filter(|&&v| v > 0).count();
        dof += rx.saturating_sub(1) * ry.saturating_sub(1);
        for a in 0..cx {
            for b in 0..cy {
                let o = cell[a * cy + b];
                if o > 0 {
                    g2 += o as f64 * (o as f64 * n_z as f64 / (n_x[a] as f64 * n_y[b] as f64)).ln();
                }
            }
        }
    }
    g2 *= 2.0;
    if dof == 0 {
        // uninformative: zero effective variation, cannot claim independence
        return CiOutcome { independent: false, p: 0.0, g2, dof };
    }
    let p = chi2_sf(g2, dof);
    CiOutcome { independent: p > alpha, p, g2, dof }
}

/// Chi-squared survival function `P(X ≥ x)` with `dof` degrees of
/// freedom: the regularized upper incomplete gamma `Q(dof/2, x/2)`.
pub fn chi2_sf(x: f64, dof: usize) -> f64 {
    gammq(dof as f64 / 2.0, x / 2.0)
}

/// `ln Γ(x)` via the Lanczos approximation (Numerical Recipes g=5, n=6 —
/// |ε| < 2e-10 for x > 0, far below what a p-value threshold needs).
fn gammln(x: f64) -> f64 {
    const COF: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let mut tmp = x + 5.5;
    tmp -= (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for c in COF {
        y += 1.0;
        ser += c / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Regularized upper incomplete gamma `Q(a, x)`: series representation of
/// `P` below `x < a+1`, Lentz continued fraction for `Q` above.
fn gammq(a: f64, x: f64) -> f64 {
    if a <= 0.0 || x <= 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        // series for P(a, x); Q = 1 - P
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut delta = sum;
        for _ in 0..500 {
            ap += 1.0;
            delta *= x / ap;
            sum += delta;
            if delta.abs() < sum.abs() * 1e-14 {
                break;
            }
        }
        1.0 - sum * (-x + a * x.ln() - gammln(a)).exp()
    } else {
        // modified Lentz continued fraction for Q(a, x)
        const TINY: f64 = 1e-300;
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / TINY;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < TINY {
                d = TINY;
            }
            c = b + an / c;
            if c.abs() < TINY {
                c = TINY;
            }
            d = 1.0 / d;
            let delta = d * c;
            h *= delta;
            if (delta - 1.0).abs() < 1e-14 {
                break;
            }
        }
        (-x + a * x.ln() - gammln(a)).exp() * h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn chi2_tail_matches_reference_values() {
        // classic table values: P(X² ≥ 3.841 | 1 dof) = 0.05,
        // P(X² ≥ 6.635 | 1 dof) = 0.01, P(X² ≥ 5.991 | 2 dof) = 0.05
        assert!((chi2_sf(3.841, 1) - 0.05).abs() < 5e-4);
        assert!((chi2_sf(6.635, 1) - 0.01).abs() < 5e-4);
        assert!((chi2_sf(5.991, 2) - 0.05).abs() < 5e-4);
        // extremes
        assert!((chi2_sf(0.0, 3) - 1.0).abs() < 1e-12);
        assert!(chi2_sf(1000.0, 1) < 1e-12);
        // both gammq branches (series x < a+1, continued fraction x > a+1)
        assert!((chi2_sf(1.0, 10) - 0.9998).abs() < 1e-3);
        assert!(chi2_sf(40.0, 10) < 2e-5);
    }

    #[test]
    fn detects_dependence_and_independence_on_asia_samples() {
        let net = embedded::asia();
        let data = crate::learn::Dataset::from_network(&net, 20_000, 7);
        let v = |n: &str| net.var_id(n).unwrap();
        let mut scratch = CiScratch::default();
        // smoke -> lung: marginally dependent
        let dep = g_squared(&data, v("smoke"), v("lung"), &[], 0.01, &mut scratch);
        assert!(!dep.independent, "smoke/lung p={}", dep.p);
        // asia vs smoke: disconnected components, marginally independent
        let ind = g_squared(&data, v("asia"), v("smoke"), &[], 0.01, &mut scratch);
        assert!(ind.independent, "asia/smoke p={}", ind.p);
        // xray ⟂ dysp | either (d-separation through the collider's child)
        let sep = g_squared(&data, v("xray"), v("dysp"), &[v("either")], 0.01, &mut scratch);
        assert!(sep.independent, "xray/dysp|either p={}", sep.p);
    }

    #[test]
    fn deterministic_conditioning_is_uninformative_not_independent() {
        // either is a deterministic OR of (lung, tub): conditioned on both
        // parents it has zero variance in every stratum, so the classical
        // test would call either ⟂ xray | {lung, tub} and delete a true
        // edge. Adaptive dof flags the test uninformative instead.
        let net = embedded::asia();
        let data = crate::learn::Dataset::from_network(&net, 20_000, 7);
        let v = |n: &str| net.var_id(n).unwrap();
        let mut scratch = CiScratch::default();
        let out = g_squared(&data, v("either"), v("xray"), &[v("lung"), v("tub")], 0.01, &mut scratch);
        assert_eq!(out.dof, 0, "deterministic stratum must yield zero adaptive dof");
        assert!(!out.independent);
    }

    #[test]
    fn scratch_is_reusable_across_table_sizes() {
        let net = embedded::asia();
        let data = crate::learn::Dataset::from_network(&net, 2_000, 3);
        let mut scratch = CiScratch::default();
        let a = g_squared(&data, 0, 1, &[2, 3], 0.05, &mut scratch);
        let b = g_squared(&data, 0, 1, &[], 0.05, &mut scratch);
        let mut fresh = CiScratch::default();
        let b2 = g_squared(&data, 0, 1, &[], 0.05, &mut fresh);
        assert_eq!(b.g2.to_bits(), b2.g2.to_bits(), "stale counts must not leak between tests");
        assert_eq!(b.dof, b2.dof);
        let _ = a;
    }
}
