//! Maximum-likelihood CPT fitting with Laplace smoothing.
//!
//! Given the learned DAG's parent sets, each variable's CPT is fitted in
//! one pass over its family's columns: count `n(v = s, pa = config)`,
//! then
//!
//! ```text
//! P(v = s | pa = config) = (n + λ) / (n_config + λ·card(v))
//! ```
//!
//! with pseudo-count `λ` (default 1.0 — add-one smoothing). Smoothing is
//! not cosmetic here: an unobserved parent configuration with `λ = 0`
//! would produce an all-zero CPT row (an invalid distribution), and a
//! zero-probability entry would make the served junction tree call
//! perfectly valid evidence inconsistent. `λ > 0` keeps every learned
//! network fully supported; `λ = 0` is allowed for pure MLE, with unseen
//! rows falling back to uniform.

use crate::bn::cpt::Cpt;
use crate::bn::network::Network;
use crate::bn::variable::Variable;
use crate::learn::data::Dataset;
use crate::Result;

/// Fit CPTs for `parents` (sorted parent ids per variable, as
/// [`crate::learn::orient::extend_to_dag`] returns) on `data`, producing
/// a validated network called `name`.
pub fn fit(data: &Dataset, parents: &[Vec<usize>], laplace: f64, name: &str) -> Result<Network> {
    let n = data.n_vars();
    assert_eq!(parents.len(), n, "one parent list per variable");
    let cards = data.cards();
    let vars: Vec<Variable> = (0..n)
        .map(|v| Variable {
            name: data.names()[v].clone(),
            states: data.states(v).to_vec(),
        })
        .collect();
    let mut cpts = Vec::with_capacity(n);
    for v in 0..n {
        let ps = &parents[v];
        let rows: usize = ps.iter().map(|&p| cards[p]).product();
        let c = cards[v];
        let mut counts = vec![0u32; rows * c];
        let pcols: Vec<(&[u32], usize)> = ps.iter().map(|&p| (data.col(p), cards[p])).collect();
        let col_v = data.col(v);
        for r in 0..data.n_rows() {
            let mut ri = 0usize;
            for (pc, card) in &pcols {
                ri = ri * card + pc[r] as usize;
            }
            counts[ri * c + col_v[r] as usize] += 1;
        }
        let mut probs = Vec::with_capacity(rows * c);
        for row in counts.chunks_exact(c) {
            let total: f64 = row.iter().map(|&x| x as f64).sum::<f64>() + laplace * c as f64;
            if total == 0.0 {
                // λ = 0 and an unseen configuration: uniform fallback
                probs.extend(std::iter::repeat(1.0 / c as f64).take(c));
            } else {
                probs.extend(row.iter().map(|&x| (x as f64 + laplace) / total));
            }
        }
        cpts.push(Cpt::new(v, ps.clone(), probs, &cards)?);
    }
    Network::new(name, vars, cpts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::learn::Dataset;

    #[test]
    fn fitted_cpts_approach_the_generating_cpts() {
        let net = embedded::asia();
        let data = Dataset::from_network(&net, 100_000, 13);
        // fit with the TRUE structure: CPTs must converge on the source
        let parents: Vec<Vec<usize>> = (0..net.n()).map(|v| net.parents(v).to_vec()).collect();
        let fitted = fit(&data, &parents, 1.0, "asia-mle").unwrap();
        assert_eq!(fitted.name, "asia-mle");
        let smoke = net.var_id("smoke").unwrap();
        assert!((fitted.cpts[smoke].probs[0] - 0.5).abs() < 0.01);
        let lung = net.var_id("lung").unwrap();
        // P(lung=yes | smoke=yes) = 0.1
        assert!((fitted.cpts[lung].probs[0] - 0.1).abs() < 0.01);
    }

    #[test]
    fn unseen_rows_are_smoothed_not_zero() {
        // asia=yes is rare (1%); with few samples some (asia=yes) rows of
        // tub's CPT may be unseen — Laplace keeps them valid and non-zero
        let net = embedded::asia();
        let data = Dataset::from_network(&net, 50, 2);
        let parents: Vec<Vec<usize>> = (0..net.n()).map(|v| net.parents(v).to_vec()).collect();
        let fitted = fit(&data, &parents, 1.0, "asia-small").unwrap();
        assert!(fitted.cpts.iter().all(|c| c.probs.iter().all(|&p| p > 0.0)));
        // and the result passed Network::new's row-sum validation already
    }

    #[test]
    fn zero_laplace_uses_uniform_for_unseen_rows() {
        let net = embedded::asia();
        let data = Dataset::from_network(&net, 10, 4);
        let parents: Vec<Vec<usize>> = (0..net.n()).map(|v| net.parents(v).to_vec()).collect();
        // pure MLE still yields a valid network (unseen rows -> uniform)
        let fitted = fit(&data, &parents, 0.0, "asia-mle0").unwrap();
        fitted.validate().unwrap();
    }
}
