//! PC-stable skeleton discovery with level-wise pool parallelism.
//!
//! The companion paper to the inference poster ("Fast Parallel Bayesian
//! Network Structure Learning") parallelizes PC-stable by observing that
//! all CI tests of one *level* (conditioning-set size) are independent:
//! PC-stable freezes the adjacency sets at the start of each level, so no
//! test's outcome can influence another's inputs within the level. This
//! driver exploits exactly that: each level's edge batch is **one region**
//! of the existing [`Pool`] — tasks (one per surviving edge) are claimed
//! by `fetch_add` dynamic self-scheduling, contingency scratch is
//! per-worker ([`PerWorker`]), and every task writes only its own result
//! slot. Results therefore do not depend on the thread count or the
//! claim order in any way: the learned skeleton, sepsets, and statistics
//! are bit-identical from `threads = 1` to `threads = N`.
//!
//! Per edge `x — y`, candidate separating sets of size `level` are drawn
//! from the frozen `adj(x) \ {y}` first, then `adj(y) \ {x}` (subsets of
//! the first side are skipped as duplicates), each side enumerated in
//! lexicographic order — the first accepting set is recorded as the
//! sepset, making sepsets deterministic too. Removals apply at the end
//! of the level (the "stable" in PC-stable).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use crate::engine::pool::Pool;
use crate::engine::share::PerWorker;
use crate::learn::ci::{g_squared, CiScratch};
use crate::learn::data::Dataset;

/// Per-level accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LevelStats {
    /// Edges alive at the start of the level (= pool tasks dispatched).
    pub edges: usize,
    /// CI tests executed during the level.
    pub tests: usize,
    /// Edges removed at the end of the level.
    pub removed: usize,
}

/// Output of skeleton discovery.
#[derive(Clone, Debug)]
pub struct SkeletonResult {
    /// Sorted adjacency lists of the learned skeleton.
    pub adj: Vec<Vec<usize>>,
    /// Sorted undirected edges `(x, y)`, `x < y`.
    pub edges: Vec<(usize, usize)>,
    /// Separating set recorded for every removed pair (keyed `(x, y)`,
    /// `x < y`) — the v-structure oracle for orientation.
    pub sepsets: BTreeMap<(usize, usize), Vec<usize>>,
    /// Per-level accounting, index = conditioning-set size.
    pub levels: Vec<LevelStats>,
}

impl SkeletonResult {
    /// Total CI tests across all levels.
    pub fn ci_tests(&self) -> usize {
        self.levels.iter().map(|l| l.tests).sum()
    }
}

/// Lexicographic `k`-combinations of `items`; `f` returns `true` to stop
/// early (separating set found). Returns whether enumeration was stopped.
fn for_each_combination(items: &[usize], k: usize, f: &mut dyn FnMut(&[usize]) -> bool) -> bool {
    if k > items.len() {
        return false;
    }
    if k == 0 {
        return f(&[]);
    }
    let n = items.len();
    let mut idx: Vec<usize> = (0..k).collect();
    let mut buf = vec![0usize; k];
    loop {
        for (j, &i) in idx.iter().enumerate() {
            buf[j] = items[i];
        }
        if f(&buf) {
            return true;
        }
        // advance to the next combination: bump the rightmost index that
        // still has room, reset everything after it
        let mut j = k;
        while j > 0 && idx[j - 1] == n - k + (j - 1) {
            j -= 1;
        }
        if j == 0 {
            return false;
        }
        idx[j - 1] += 1;
        for l in j..k {
            idx[l] = idx[l - 1] + 1;
        }
    }
}

/// Discover the skeleton of `data` via PC-stable at significance `alpha`,
/// conditioning sets capped at `max_cond`, CI batches dispatched through
/// `pool`.
pub fn skeleton(data: &Dataset, alpha: f64, max_cond: usize, pool: &Pool) -> SkeletonResult {
    let n = data.n_vars();
    let mut adj: Vec<BTreeSet<usize>> = (0..n).map(|v| (0..n).filter(|&u| u != v).collect()).collect();
    let mut sepsets: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
    let mut levels = Vec::new();

    let scratches = PerWorker::new(pool.threads(), |_| CiScratch::default());
    let mut counters = PerWorker::new(pool.threads(), |_| 0usize);

    let mut level = 0usize;
    loop {
        // PC-stable: adjacency frozen for the whole level
        let frozen: Vec<Vec<usize>> = adj.iter().map(|s| s.iter().copied().collect()).collect();
        let edges: Vec<(usize, usize)> =
            (0..n).flat_map(|x| adj[x].iter().copied().filter(move |&y| y > x).map(move |y| (x, y))).collect();

        // one pool region per level: every edge is an independent task,
        // claimed dynamically; slot t is written by task t alone
        let slots: Vec<Mutex<Option<Vec<usize>>>> = edges.iter().map(|_| Mutex::new(None)).collect();
        {
            let (frozen, edges, slots) = (&frozen, &edges, &slots);
            let (scratches, counters) = (&scratches, &counters);
            pool.parallel_region("pc.level", edges.len(), &|w, t| {
                let (x, y) = edges[t];
                // SAFETY: the pool runs one task per worker id at a time.
                let scratch = unsafe { scratches.get(w) };
                let tests = unsafe { counters.get(w) };
                let nx: Vec<usize> = frozen[x].iter().copied().filter(|&v| v != y).collect();
                let ny: Vec<usize> = frozen[y].iter().copied().filter(|&v| v != x).collect();
                let mut found: Option<Vec<usize>> = None;
                {
                    let mut try_set = |s: &[usize]| -> bool {
                        *tests += 1;
                        if g_squared(data, x, y, s, alpha, scratch).independent {
                            found = Some(s.to_vec());
                            true
                        } else {
                            false
                        }
                    };
                    if !for_each_combination(&nx, level, &mut try_set) {
                        // y's side, skipping subsets already drawn from x's
                        for_each_combination(&ny, level, &mut |s: &[usize]| {
                            if s.iter().all(|v| nx.binary_search(v).is_ok()) {
                                return false;
                            }
                            try_set(s)
                        });
                    }
                }
                if let Some(sep) = found {
                    *slots[t].lock().unwrap() = Some(sep);
                }
            });
        }

        // the "stable" half: removals apply only after the whole level ran
        let mut removed = 0usize;
        for (t, &(x, y)) in edges.iter().enumerate() {
            if let Some(sep) = slots[t].lock().unwrap().take() {
                adj[x].remove(&y);
                adj[y].remove(&x);
                sepsets.insert((x, y), sep);
                removed += 1;
            }
        }
        let tests: usize = counters
            .iter_mut()
            .map(|c| {
                let v = *c;
                *c = 0;
                v
            })
            .sum();
        levels.push(LevelStats { edges: edges.len(), tests, removed });

        // escalate only if some surviving edge can actually be tested at
        // the next conditioning-set size — checked against the
        // post-removal adjacency, so no zero-test phantom level runs
        let next = level + 1;
        let more = (0..n).any(|x| {
            adj[x].iter().any(|&y| {
                y > x && (adj[x].len().saturating_sub(1) >= next || adj[y].len().saturating_sub(1) >= next)
            })
        });
        if !more || next > n.min(max_cond) {
            break;
        }
        level = next;
    }

    let adj_sorted: Vec<Vec<usize>> = adj.iter().map(|s| s.iter().copied().collect()).collect();
    let edges: Vec<(usize, usize)> =
        (0..n).flat_map(|x| adj[x].iter().copied().filter(move |&y| y > x).map(move |y| (x, y))).collect();
    SkeletonResult { adj: adj_sorted, edges, sepsets, levels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::learn::Dataset;

    fn true_edges(net: &crate::bn::network::Network) -> Vec<(usize, usize)> {
        let mut edges: Vec<(usize, usize)> = (0..net.n())
            .flat_map(|v| net.parents(v).iter().map(move |&p| (p.min(v), p.max(v))))
            .collect();
        edges.sort_unstable();
        edges.dedup();
        edges
    }

    #[test]
    fn combinations_enumerate_in_lexicographic_order() {
        let items = [2usize, 5, 7, 9];
        let mut seen = Vec::new();
        for_each_combination(&items, 2, &mut |s: &[usize]| {
            seen.push(s.to_vec());
            false
        });
        assert_eq!(
            seen,
            vec![vec![2, 5], vec![2, 7], vec![2, 9], vec![5, 7], vec![5, 9], vec![7, 9]]
        );
        // k = 0: exactly one empty set; k > len: nothing
        let mut count = 0;
        for_each_combination(&items, 0, &mut |s: &[usize]| {
            assert!(s.is_empty());
            count += 1;
            false
        });
        assert_eq!(count, 1);
        for_each_combination(&items, 5, &mut |_s: &[usize]| panic!("must not run"));
        // early stop propagates
        assert!(for_each_combination(&items, 1, &mut |s: &[usize]| s[0] == 5));
    }

    #[test]
    fn recovers_the_cancer_skeleton() {
        let net = embedded::cancer();
        let data = Dataset::from_network(&net, 50_000, 0xA51A);
        let pool = Pool::new(2);
        let skel = skeleton(&data, 0.01, usize::MAX, &pool);
        assert_eq!(skel.edges, true_edges(&net));
        assert!(skel.ci_tests() > 0);
        assert!(skel.levels.len() >= 2);
        // every removed pair carries a sepset
        for x in 0..net.n() {
            for y in (x + 1)..net.n() {
                let has_edge = skel.edges.contains(&(x, y));
                assert_eq!(skel.sepsets.contains_key(&(x, y)), !has_edge, "pair ({x},{y})");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_the_result() {
        let net = embedded::sprinkler();
        let data = Dataset::from_network(&net, 20_000, 9);
        let base = skeleton(&data, 0.01, usize::MAX, &Pool::new(1));
        for threads in [2usize, 4, 8] {
            let other = skeleton(&data, 0.01, usize::MAX, &Pool::new(threads));
            assert_eq!(other.edges, base.edges, "threads={threads}");
            assert_eq!(other.sepsets, base.sepsets, "threads={threads}");
            assert_eq!(other.levels, base.levels, "threads={threads}");
        }
    }

    #[test]
    fn max_cond_caps_the_level() {
        let net = embedded::asia();
        let data = Dataset::from_network(&net, 5_000, 1);
        let pool = Pool::new(1);
        let capped = skeleton(&data, 0.01, 1, &pool);
        // levels 0 and 1 ran; the cap stopped the escalation
        assert!(capped.levels.len() <= 2, "{:?}", capped.levels);
    }
}
