//! Edge orientation: v-structures + Meek rules → CPDAG → consistent DAG.
//!
//! From the skeleton and sepsets, unshielded colliders are oriented first
//! (`x → z ← y` whenever `x — z — y`, `x`/`y` nonadjacent, and `z` is not
//! in their separating set), then Meek's rules R1–R3 propagate compelled
//! directions to a fixpoint. The result is a **CPDAG**: compelled edges
//! directed, reversible edges undirected — every DAG in the Markov
//! equivalence class agrees on the directed part.
//!
//! Parameter fitting needs one concrete member of the class, so
//! [`extend_to_dag`] runs the Dor–Tarsi consistent-extension algorithm:
//! repeatedly find a node that is a directed sink whose undirected
//! neighbors are adjacent to all its other neighbors, orient its
//! undirected edges inward, and retire it. This never creates a new
//! v-structure, so the extension stays in the learned equivalence class.
//!
//! Everything here iterates over `BTreeSet`s in sorted order — the
//! orientation is a pure function of (skeleton, sepsets), independent of
//! thread count or hash-map iteration luck.

use std::collections::{BTreeMap, BTreeSet};

use crate::{Error, Result};

/// A partially directed acyclic graph: the learned equivalence class.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Cpdag {
    /// Variable count.
    pub n: usize,
    /// Compelled edges `(from, to)`.
    pub directed: BTreeSet<(usize, usize)>,
    /// Reversible edges `(x, y)`, `x < y`.
    pub undirected: BTreeSet<(usize, usize)>,
}

impl Cpdag {
    fn is_adjacent(&self, a: usize, b: usize) -> bool {
        self.undirected.contains(&(a.min(b), a.max(b))) || self.directed.contains(&(a, b)) || self.directed.contains(&(b, a))
    }
}

/// Build the CPDAG from skeleton `edges` (pairs `x < y`) and the sepsets
/// recorded during skeleton discovery.
pub fn cpdag(n: usize, edges: &[(usize, usize)], sepsets: &BTreeMap<(usize, usize), Vec<usize>>) -> Cpdag {
    let mut g = Cpdag { n, directed: BTreeSet::new(), undirected: edges.iter().copied().collect() };
    let mut adj: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    for &(x, y) in edges {
        adj[x].insert(y);
        adj[y].insert(x);
    }

    // v-structures: unshielded triples x - z - y with z outside sepset(x,y)
    for z in 0..n {
        let nbrs: Vec<usize> = adj[z].iter().copied().collect();
        for i in 0..nbrs.len() {
            for j in (i + 1)..nbrs.len() {
                let (x, y) = (nbrs[i], nbrs[j]);
                if adj[x].contains(&y) {
                    continue; // shielded
                }
                let in_sepset =
                    sepsets.get(&(x.min(y), x.max(y))).map(|s| s.contains(&z)).unwrap_or(false);
                if !in_sepset {
                    for (a, b) in [(x, z), (y, z)] {
                        let e = (a.min(b), a.max(b));
                        if g.undirected.contains(&e) && !g.directed.contains(&(b, a)) {
                            g.undirected.remove(&e);
                            g.directed.insert((a, b));
                        }
                    }
                }
            }
        }
    }

    // Meek rules R1-R3 to a fixpoint (restart after every orientation so
    // the scan order stays canonical)
    loop {
        let mut oriented: Option<((usize, usize), (usize, usize))> = None;
        'scan: for &(a, b) in &g.undirected {
            for (u, v) in [(a, b), (b, a)] {
                // R1: z -> u, u - v, z/v nonadjacent  =>  u -> v
                let r1 = (0..n)
                    .any(|z| z != u && z != v && g.directed.contains(&(z, u)) && !g.is_adjacent(z, v));
                // R2: u -> z -> v with u - v  =>  u -> v (avoid the cycle)
                let r2 = (0..n).any(|z| g.directed.contains(&(u, z)) && g.directed.contains(&(z, v)));
                // R3: u - z1 -> v and u - z2 -> v, z1/z2 nonadjacent  =>  u -> v
                let zs: Vec<usize> = (0..n)
                    .filter(|&z| g.undirected.contains(&(u.min(z), u.max(z))) && g.directed.contains(&(z, v)))
                    .collect();
                let r3 = zs
                    .iter()
                    .enumerate()
                    .any(|(i, &z1)| zs[i + 1..].iter().any(|&z2| !g.is_adjacent(z1, z2)));
                if r1 || r2 || r3 {
                    oriented = Some(((a, b), (u, v)));
                    break 'scan;
                }
            }
        }
        match oriented {
            Some((e, (u, v))) => {
                g.undirected.remove(&e);
                g.directed.insert((u, v));
            }
            None => break,
        }
    }
    g
}

/// Extend the CPDAG to a consistent DAG (Dor & Tarsi), returning the
/// sorted parent list per variable. Falls back to a low-id → high-id
/// orientation of whatever undirected edges remain (then verifies
/// acyclicity) if no extension order exists — which a CPDAG produced by
/// [`cpdag`] never hits, but arbitrary hand-built inputs can.
pub fn extend_to_dag(n: usize, g: &Cpdag) -> Result<Vec<Vec<usize>>> {
    let mut directed = g.directed.clone();
    let mut und = g.undirected.clone();
    let mut nodes: BTreeSet<usize> = (0..n).collect();
    let mut result: BTreeSet<(usize, usize)> = g.directed.clone();

    let neighbors = |x: usize, directed: &BTreeSet<(usize, usize)>, und: &BTreeSet<(usize, usize)>| {
        let mut out = BTreeSet::new();
        for &(a, b) in directed.iter().chain(und.iter()) {
            if a == x {
                out.insert(b);
            } else if b == x {
                out.insert(a);
            }
        }
        out
    };

    while !nodes.is_empty() {
        let mut found = None;
        for &x in &nodes {
            if directed.iter().any(|&(a, _)| a == x) {
                continue; // has an outgoing compelled edge: not a sink yet
            }
            let und_nbrs: Vec<usize> =
                und.iter().filter(|&&(a, b)| a == x || b == x).map(|&(a, b)| if a == x { b } else { a }).collect();
            let all_nbrs = neighbors(x, &directed, &und);
            let ok = und_nbrs.iter().all(|&y| {
                all_nbrs.iter().all(|&z| {
                    z == y
                        || neighbors(z, &directed, &und).contains(&y)
                        || neighbors(y, &directed, &und).contains(&z)
                })
            });
            if ok {
                found = Some(x);
                break;
            }
        }
        let Some(x) = found else {
            // no valid sink: orient the leftovers by id and verify
            for &(a, b) in &und {
                result.insert((a, b));
            }
            return parents_if_acyclic(n, &result);
        };
        for &(a, b) in und.clone().iter() {
            if a == x || b == x {
                let other = if a == x { b } else { a };
                und.remove(&(a, b));
                result.insert((other, x));
            }
        }
        directed.retain(|&(a, b)| a != x && b != x);
        nodes.remove(&x);
    }
    parents_if_acyclic(n, &result)
}

/// Turn an edge set into per-variable parent lists, erroring on cycles.
fn parents_if_acyclic(n: usize, edges: &BTreeSet<(usize, usize)>) -> Result<Vec<Vec<usize>>> {
    let mut parents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(p, c) in edges {
        parents[c].push(p);
    }
    // Kahn's algorithm over the candidate DAG
    let mut indeg: Vec<usize> = parents.iter().map(|p| p.len()).collect();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(p, c) in edges {
        children[p].push(c);
    }
    let mut stack: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut seen = 0usize;
    while let Some(v) = stack.pop() {
        seen += 1;
        for &c in &children[v] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                stack.push(c);
            }
        }
    }
    if seen != n {
        return Err(Error::msg("CPDAG extension produced a cycle (inconsistent orientation input)"));
    }
    Ok(parents)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sepsets(entries: &[((usize, usize), &[usize])]) -> BTreeMap<(usize, usize), Vec<usize>> {
        entries.iter().map(|&(k, v)| (k, v.to_vec())).collect()
    }

    #[test]
    fn collider_is_oriented_chain_is_not() {
        // skeleton x - z - y; sepset(x,y) = {} => collider x -> z <- y
        let g = cpdag(3, &[(0, 2), (1, 2)], &sepsets(&[((0, 1), &[])]));
        assert!(g.directed.contains(&(0, 2)) && g.directed.contains(&(1, 2)));
        assert!(g.undirected.is_empty());
        // same skeleton, sepset(x,y) = {z} => no collider, both reversible
        let g = cpdag(3, &[(0, 2), (1, 2)], &sepsets(&[((0, 1), &[2])]));
        assert!(g.directed.is_empty());
        assert_eq!(g.undirected.len(), 2);
    }

    #[test]
    fn meek_r1_propagates_past_a_collider() {
        // 0 -> 2 <- 1 (collider), 2 - 3: R1 forces 2 -> 3 (else a new
        // v-structure 0 -> 2 <- 3 would appear)
        let g = cpdag(4, &[(0, 2), (1, 2), (2, 3)], &sepsets(&[((0, 1), &[])]));
        assert!(g.directed.contains(&(2, 3)), "{g:?}");
        assert!(g.undirected.is_empty());
    }

    #[test]
    fn extension_recovers_a_full_dag() {
        // cancer-shaped CPDAG: Pollution -> Cancer <- Smoker compelled,
        // Cancer -> Xray / Cancer -> Dyspnoea compelled by R1
        let g = cpdag(
            5,
            &[(0, 2), (1, 2), (2, 3), (2, 4)],
            &sepsets(&[((0, 1), &[]), ((0, 3), &[2]), ((0, 4), &[2]), ((1, 3), &[2]), ((1, 4), &[2]), ((3, 4), &[2])]),
        );
        let parents = extend_to_dag(5, &g).unwrap();
        assert_eq!(parents, vec![vec![], vec![], vec![0, 1], vec![2], vec![2]]);
    }

    #[test]
    fn extension_never_creates_a_new_collider() {
        // skeleton 0 - 2 - 1 with sepset {2}: both edges reversible; a
        // valid extension must NOT orient 0 -> 2 <- 1
        let g = cpdag(3, &[(0, 2), (1, 2)], &sepsets(&[((0, 1), &[2])]));
        let parents = extend_to_dag(3, &g).unwrap();
        let collider_at_2 = parents[2].len() == 2;
        assert!(!collider_at_2, "extension created a new v-structure: {parents:?}");
    }

    #[test]
    fn cyclic_compelled_input_is_rejected() {
        let g = Cpdag {
            n: 3,
            directed: [(0, 1), (1, 2), (2, 0)].into_iter().collect(),
            undirected: BTreeSet::new(),
        };
        assert!(extend_to_dag(3, &g).is_err());
    }
}
