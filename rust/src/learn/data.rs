//! Column-major datasets of discrete samples.
//!
//! Structure learning is column access patterns all the way down: every
//! conditional-independence test walks a handful of *columns* (the tested
//! pair plus the conditioning set) across all rows, and CPT fitting walks
//! one family's columns. A [`Dataset`] therefore stores samples
//! **column-major** — `col(v)[r]` is row `r`'s state of variable `v` —
//! so a test touches only the columns it reads, each a contiguous run.
//!
//! Datasets come from two places: CSV files ([`Dataset::from_csv`] /
//! [`Dataset::to_csv`], state names on the wire) and the crate's own
//! forward sampler ([`Dataset::from_network`], which fills the columns
//! directly via [`crate::bn::sample::forward_samples_columns`] — no
//! row-major intermediate).

use crate::bn::network::Network;
use crate::rng::Rng;
use crate::{Error, Result};

/// A column-major table of discrete samples with named state spaces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Dataset {
    names: Vec<String>,
    states: Vec<Vec<String>>,
    cols: Vec<Vec<u32>>,
    n_rows: usize,
}

impl Dataset {
    /// Assemble from parallel columns, validating shapes and ranges.
    pub fn from_columns(names: Vec<String>, states: Vec<Vec<String>>, cols: Vec<Vec<u32>>) -> Result<Dataset> {
        if names.len() != states.len() || names.len() != cols.len() {
            return Err(Error::msg(format!(
                "dataset shape mismatch: {} names, {} state spaces, {} columns",
                names.len(),
                states.len(),
                cols.len()
            )));
        }
        // fail here, not minutes later when Network::new rejects the
        // learned result
        let mut seen = std::collections::BTreeSet::new();
        for name in &names {
            if !seen.insert(name.as_str()) {
                return Err(Error::msg(format!("dataset has duplicate variable name {name:?}")));
            }
        }
        let n_rows = cols.first().map(|c| c.len()).unwrap_or(0);
        for (v, col) in cols.iter().enumerate() {
            if col.len() != n_rows {
                return Err(Error::msg(format!(
                    "dataset column {:?} has {} rows, expected {n_rows}",
                    names[v],
                    col.len()
                )));
            }
            let card = states[v].len() as u32;
            if card == 0 {
                return Err(Error::msg(format!("dataset variable {:?} has no states", names[v])));
            }
            if let Some(&bad) = col.iter().find(|&&s| s >= card) {
                return Err(Error::msg(format!(
                    "dataset column {:?} holds state {bad}, cardinality is {card}",
                    names[v]
                )));
            }
        }
        Ok(Dataset { names, states, cols, n_rows })
    }

    /// Draw `n` forward samples from `net` (seeded), filling the columns
    /// directly — the generation path the closed sample→learn→serve loop
    /// uses.
    pub fn from_network(net: &Network, n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let cols = crate::bn::sample::forward_samples_columns(net, &mut rng, n);
        Dataset {
            names: net.vars.iter().map(|v| v.name.clone()).collect(),
            states: net.vars.iter().map(|v| v.states.clone()).collect(),
            cols,
            n_rows: n,
        }
    }

    /// Number of variables (columns).
    #[inline]
    pub fn n_vars(&self) -> usize {
        self.cols.len()
    }

    /// Number of samples (rows).
    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Cardinality of variable `v`.
    #[inline]
    pub fn card(&self, v: usize) -> usize {
        self.states[v].len()
    }

    /// All cardinalities.
    pub fn cards(&self) -> Vec<usize> {
        self.states.iter().map(|s| s.len()).collect()
    }

    /// Variable names, in column order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// State names of variable `v`.
    pub fn states(&self, v: usize) -> &[String] {
        &self.states[v]
    }

    /// The column of variable `v` (state index per row).
    #[inline]
    pub fn col(&self, v: usize) -> &[u32] {
        &self.cols[v]
    }

    /// Stream the CSV form into `sink` one line at a time (constant
    /// memory — at learning-scale sample counts the full text can run to
    /// hundreds of megabytes).
    fn write_csv(&self, sink: &mut impl std::io::Write) -> Result<()> {
        let mut line = String::new();
        for (v, name) in self.names.iter().enumerate() {
            if v > 0 {
                line.push(',');
            }
            push_csv_field(&mut line, name);
        }
        line.push('\n');
        sink.write_all(line.as_bytes())?;
        for r in 0..self.n_rows {
            line.clear();
            for v in 0..self.n_vars() {
                if v > 0 {
                    line.push(',');
                }
                push_csv_field(&mut line, &self.states[v][self.cols[v][r] as usize]);
            }
            line.push('\n');
            sink.write_all(line.as_bytes())?;
        }
        Ok(())
    }

    /// Render as CSV: a header of variable names, then one row of state
    /// *names* per sample (names, not indices, so files are portable
    /// across state orderings). Names containing commas, quotes,
    /// newlines, or surrounding whitespace are RFC-4180-quoted so
    /// interval-style state names like `(1,5-2,5]` round-trip. For big
    /// datasets prefer [`Dataset::save`], which streams.
    pub fn to_csv(&self) -> String {
        let mut out = Vec::new();
        self.write_csv(&mut out).expect("writing CSV to memory cannot fail");
        String::from_utf8(out).expect("CSV text is UTF-8")
    }

    /// Parse CSV produced by [`Dataset::to_csv`] (or any header + state-name
    /// grid; quoted fields per RFC 4180, unquoted fields trimmed). State
    /// spaces are inferred per column in first-appearance order, so the
    /// *set* of states round-trips while the order may differ from the
    /// generating network's.
    pub fn from_csv(text: &str) -> Result<Dataset> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let Some((lineno, header)) = lines.next() else {
            return Err(Error::msg("empty CSV: no header line"));
        };
        let names = split_csv_line(header, lineno + 1)?;
        if names.iter().any(|n| n.is_empty()) {
            return Err(Error::msg("CSV header has an empty variable name"));
        }
        let n_vars = names.len();
        let mut states: Vec<Vec<String>> = vec![Vec::new(); n_vars];
        let mut cols: Vec<Vec<u32>> = vec![Vec::new(); n_vars];
        for (lineno, line) in lines {
            let fields = split_csv_line(line, lineno + 1)?;
            if fields.len() != n_vars {
                return Err(Error::Parse {
                    line: lineno + 1,
                    msg: format!("row has {} fields, expected {n_vars}", fields.len()),
                });
            }
            for (v, field) in fields.iter().enumerate() {
                let s = match states[v].iter().position(|s| s == field) {
                    Some(s) => s,
                    None => {
                        states[v].push(field.to_string());
                        states[v].len() - 1
                    }
                };
                cols[v].push(s as u32);
            }
        }
        if cols[0].is_empty() {
            return Err(Error::msg("CSV has a header but no data rows"));
        }
        Dataset::from_columns(names, states, cols)
    }

    /// Write as CSV to a file, streaming row by row.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use std::io::Write;
        let mut writer = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_csv(&mut writer)?;
        writer.flush()?;
        Ok(())
    }

    /// Load a CSV file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Dataset> {
        Self::from_csv(&std::fs::read_to_string(path)?)
    }
}

/// Append one CSV field, RFC-4180-quoting it when it contains a comma,
/// quote, newline, or surrounding whitespace (which the reader would
/// otherwise trim away).
fn push_csv_field(out: &mut String, field: &str) {
    let needs_quoting =
        field.contains(',') || field.contains('"') || field.contains('\n') || field != field.trim();
    if needs_quoting {
        out.push('"');
        out.push_str(&field.replace('"', "\"\""));
        out.push('"');
    } else {
        out.push_str(field);
    }
}

/// Split one CSV line into fields: a field wrapped in double quotes may
/// contain commas and doubled quotes and round-trips verbatim; unquoted
/// fields are trimmed (forgiving hand-written input).
fn split_csv_line(line: &str, lineno: usize) -> Result<Vec<String>> {
    let chars: Vec<char> = line.chars().collect();
    let mut fields = Vec::new();
    let mut i = 0usize;
    loop {
        let start = i;
        // peek past leading whitespace to detect a quoted field
        let mut j = i;
        while j < chars.len() && chars[j] != ',' && chars[j].is_whitespace() {
            j += 1;
        }
        if j < chars.len() && chars[j] == '"' {
            i = j + 1;
            let mut field = String::new();
            loop {
                match chars.get(i) {
                    None => {
                        return Err(Error::Parse { line: lineno, msg: "unterminated quoted CSV field".into() })
                    }
                    Some('"') if chars.get(i + 1) == Some(&'"') => {
                        field.push('"');
                        i += 2;
                    }
                    Some('"') => {
                        i += 1;
                        break;
                    }
                    Some(&c) => {
                        field.push(c);
                        i += 1;
                    }
                }
            }
            // only whitespace may follow the closing quote
            while i < chars.len() && chars[i] != ',' {
                if !chars[i].is_whitespace() {
                    return Err(Error::Parse {
                        line: lineno,
                        msg: "unexpected characters after a quoted CSV field".into(),
                    });
                }
                i += 1;
            }
            fields.push(field);
        } else {
            while i < chars.len() && chars[i] != ',' {
                i += 1;
            }
            let raw: String = chars[start..i].iter().collect();
            fields.push(raw.trim().to_string());
        }
        if i >= chars.len() {
            break;
        }
        i += 1; // the comma
        if i >= chars.len() {
            // trailing comma: one final empty field, as plain split gives
            fields.push(String::new());
            break;
        }
    }
    Ok(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;

    #[test]
    fn from_network_matches_row_major_sampler() {
        let net = embedded::asia();
        let data = Dataset::from_network(&net, 50, 11);
        assert_eq!(data.n_vars(), 8);
        assert_eq!(data.n_rows(), 50);
        // same seed, same stream: the row-major sampler must agree cell
        // for cell (the column-major fill is a layout change, not a
        // different experiment)
        let mut rng = Rng::new(11);
        let rows = crate::bn::sample::forward_samples(&net, &mut rng, 50);
        for (r, row) in rows.iter().enumerate() {
            for v in 0..net.n() {
                assert_eq!(data.col(v)[r] as usize, row[v], "row {r} var {v}");
            }
        }
    }

    #[test]
    fn csv_roundtrip_preserves_cells() {
        let net = embedded::asia();
        let data = Dataset::from_network(&net, 40, 3);
        let text = data.to_csv();
        let back = Dataset::from_csv(&text).unwrap();
        assert_eq!(back.n_rows(), 40);
        assert_eq!(back.names(), data.names());
        // state *names* per cell agree even if index order was re-derived
        for v in 0..data.n_vars() {
            for r in 0..data.n_rows() {
                assert_eq!(
                    back.states(v)[back.col(v)[r] as usize],
                    data.states(v)[data.col(v)[r] as usize],
                    "cell ({r},{v})"
                );
            }
        }
    }

    #[test]
    fn csv_error_paths() {
        assert!(Dataset::from_csv("").is_err());
        assert!(Dataset::from_csv("a,b\n").is_err());
        assert!(Dataset::from_csv("a,b\nyes\n").is_err());
        assert!(Dataset::from_csv("a,\nyes,no\n").is_err());
        // duplicate header names die here, not after a full PC run
        assert!(Dataset::from_csv("a,a\nyes,no\n").is_err());
    }

    #[test]
    fn csv_quotes_awkward_state_names() {
        // interval-style names with commas, embedded quotes, and padded
        // whitespace must survive the save-data -> --data round trip
        let names = vec!["v".to_string(), "w".to_string()];
        let states = vec![
            vec!["(1,5-2,5]".to_string(), "x\"y".to_string(), " padded ".to_string()],
            vec!["plain".to_string(), "also plain".to_string()],
        ];
        let cols = vec![vec![0, 1, 2, 0], vec![1, 0, 1, 0]];
        let d = Dataset::from_columns(names, states, cols).unwrap();
        let text = d.to_csv();
        let back = Dataset::from_csv(&text).unwrap();
        assert_eq!(back.names(), d.names());
        for v in 0..d.n_vars() {
            for r in 0..d.n_rows() {
                assert_eq!(
                    back.states(v)[back.col(v)[r] as usize],
                    d.states(v)[d.col(v)[r] as usize],
                    "cell ({r},{v}) in {text:?}"
                );
            }
        }
        // malformed quoting is a parse error, not silent data corruption
        assert!(Dataset::from_csv("a\n\"unterminated\n").is_err());
        assert!(Dataset::from_csv("a\n\"x\" trailing\n").is_err());
    }

    #[test]
    fn from_columns_validates() {
        let names = vec!["a".to_string(), "b".to_string()];
        let states = vec![vec!["t".to_string(), "f".to_string()]; 2];
        assert!(Dataset::from_columns(names.clone(), states.clone(), vec![vec![0, 1], vec![1, 0]]).is_ok());
        assert!(Dataset::from_columns(names.clone(), states.clone(), vec![vec![0, 1]]).is_err());
        assert!(Dataset::from_columns(names.clone(), states.clone(), vec![vec![0], vec![1, 0]]).is_err());
        assert!(Dataset::from_columns(names, states, vec![vec![0, 2], vec![1, 0]]).is_err());
        let dup = vec!["a".to_string(), "a".to_string()];
        let states = vec![vec!["t".to_string(), "f".to_string()]; 2];
        assert!(Dataset::from_columns(dup, states, vec![vec![0], vec![1]]).is_err());
    }

    #[test]
    fn save_and_load_files() {
        let net = embedded::cancer();
        let data = Dataset::from_network(&net, 25, 5);
        let path = std::env::temp_dir().join(format!("fastbn-data-{}.csv", std::process::id()));
        data.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.n_rows(), 25);
        assert_eq!(back.names(), data.names());
        let _ = std::fs::remove_file(path);
    }
}
