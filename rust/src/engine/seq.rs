//! **Fast-BNI-seq** — the optimized sequential engine.
//!
//! All of the paper's "bottleneck simplification" with none of the
//! parallelism: cached per-edge index maps (computed once at tree
//! compilation), preallocated scratch reused across cases, and tight flat
//! loops over the tables. This is both a Table-1 column and the
//! correctness reference the parallel engines are tested against.

use std::sync::Arc;

use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::propagate::{calibrate, MapMode, Scratch};
use crate::jt::schedule::Schedule;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::Result;

/// Sequential Fast-BNI engine (see module docs).
pub struct SeqEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    scratch: Scratch,
    mode: MapMode,
}

impl SeqEngine {
    /// Build for a tree. `cfg.map_mode` selects the index-mapping strategy
    /// (the ablation in `benches/ablation.rs` sweeps it).
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let scratch = Scratch::for_tree(&jt);
        SeqEngine { jt, sched, scratch, mode: cfg.map_mode }
    }
}

impl Engine for SeqEngine {
    fn name(&self) -> &'static str {
        "Fast-BNI-seq"
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        calibrate(&self.jt, &self.sched, state, ev, self.mode, &mut self.scratch)?;
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn matches_brute_force_on_asia() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine = SeqEngine::new(Arc::clone(&jt), &EngineConfig::default());
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("dysp", "yes"), ("xray", "no")]).unwrap();
        let post = engine.infer(&mut state, &ev).unwrap();
        let exact = crate::infer::exact::enumerate(&net, &ev).unwrap();
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                assert!(
                    (post.probs[v][s] - exact.probs[v][s]).abs() < 1e-9,
                    "var {v} state {s}: {} vs {}",
                    post.probs[v][s],
                    exact.probs[v][s]
                );
            }
        }
        assert!((post.log_z - exact.log_z).abs() < 1e-9);
    }

    #[test]
    fn state_reuse_across_cases_is_clean() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine = SeqEngine::new(Arc::clone(&jt), &EngineConfig::default());
        let mut state = TreeState::fresh(&jt);
        let ev1 = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let first = engine.infer(&mut state, &ev1).unwrap();
        // run a different case, then the first again: identical results
        let ev2 = Evidence::from_pairs(&net, &[("asia", "yes"), ("xray", "yes")]).unwrap();
        engine.infer(&mut state, &ev2).unwrap();
        let again = engine.infer(&mut state, &ev1).unwrap();
        assert!(first.max_abs_diff(&again) < 1e-15);
    }
}
