//! **Direct inter-clique parallelism** — the Kozlov & Singh '94 baseline
//! (Table 1 column "Dir.").
//!
//! Message passing of different cliques in the same layer runs
//! concurrently; each *task* is one receiving clique (all messages into it,
//! processed sequentially inside the task), so concurrent tasks never touch
//! the same table. The paper's criticism — which `benches/table1.rs`
//! reproduces — is load imbalance: a layer's wall time is its largest
//! clique, and layers with few cliques leave threads idle.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::engine::pool::Pool;
use crate::engine::share::{PerWorker, SharedTables};
use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::propagate::Scratch;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// Worker-local accumulator for one parallel region.
struct WorkerCtx {
    scratch: Scratch,
    log_z: f64,
}

/// Inter-clique engine (see module docs).
pub struct DirectEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    pool: Pool,
    /// Collect phase: `up_groups[layer][task]` = messages into one parent.
    up_groups: Vec<Vec<Vec<Msg>>>,
    /// Distribute phase: one task per message (receivers are distinct).
    down_tasks: Vec<Vec<Msg>>,
    workers: PerWorker<WorkerCtx>,
}

impl DirectEngine {
    /// Build for a tree.
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let threads = cfg.resolved_threads();
        let pool = Pool::new(threads);

        let up_groups = sched
            .up_layers
            .iter()
            .map(|layer| {
                let mut by_parent: std::collections::BTreeMap<usize, Vec<Msg>> = Default::default();
                for &m in layer {
                    by_parent.entry(m.to).or_default().push(m);
                }
                by_parent.into_values().collect()
            })
            .collect();
        let down_tasks = sched.down_layers.clone();
        let workers = PerWorker::new(threads, |_| WorkerCtx { scratch: Scratch::for_tree(&jt), log_z: 0.0 });

        DirectEngine { jt, sched, pool, up_groups, down_tasks, workers }
    }

    /// Send one message inside a task. Safety contract: the caller's
    /// schedule guarantees exclusive access to `msg.to`'s clique and
    /// `msg.sep`'s separator, and read access to `msg.from`.
    fn send_in_task(jt: &JunctionTree, shared: &SharedTables, ctx: &mut WorkerCtx, msg: Msg, failed: &AtomicBool) {
        let sep_meta = &jt.seps[msg.sep];
        let maps = &jt.edge_maps[msg.sep];
        let new_sep = &mut ctx.scratch.new_sep[..sep_meta.len];
        ops::zero(new_sep);
        // SAFETY: see method contract.
        let src = unsafe { shared.clique(msg.from) };
        ops::marg_with_map(src, maps.from(sep_meta, msg.from), new_sep);
        let mass = ops::sum(new_sep);
        if mass == 0.0 {
            failed.store(true, Ordering::Relaxed);
            return;
        }
        ops::scale(new_sep, 1.0 / mass);
        ctx.log_z += mass.ln();
        let ratio = &mut ctx.scratch.ratio[..sep_meta.len];
        // SAFETY: msg.sep is owned by this task.
        let sep_tab = unsafe { shared.sep_mut(msg.sep) };
        ops::ratio(new_sep, sep_tab, ratio);
        sep_tab.copy_from_slice(new_sep);
        // SAFETY: msg.to is owned by this task.
        let dst = unsafe { shared.clique_mut(msg.to) };
        ops::extend_with_map(dst, maps.from(sep_meta, msg.to), ratio);
    }

    fn collect_logz(&mut self, state: &mut TreeState) {
        for ctx in self.workers.iter_mut() {
            state.log_z += ctx.log_z;
            ctx.log_z = 0.0;
        }
    }
}

impl Engine for DirectEngine {
    fn name(&self) -> &'static str {
        "Dir."
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        state.reset(&self.jt);
        ev.apply(&self.jt, state);
        let failed = AtomicBool::new(false);

        // collect
        for layer in &self.up_groups {
            let shared = SharedTables::new(state);
            let jt = &self.jt;
            let workers = &self.workers;
            self.pool.parallel(layer.len(), &|w, t| {
                // SAFETY: one task per worker id at a time.
                let ctx = unsafe { workers.get(w) };
                for &msg in &layer[t] {
                    Self::send_in_task(jt, &shared, ctx, msg, &failed);
                }
            });
            if failed.load(Ordering::Relaxed) {
                self.collect_logz(state);
                return Err(Error::InconsistentEvidence);
            }
        }
        self.collect_logz(state);
        for &root in &self.sched.roots {
            let data = state.clique_mut(root);
            let mass = ops::sum(data);
            if mass == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            ops::scale(data, 1.0 / mass);
            state.log_z += mass.ln();
        }

        // distribute (scale factors here don't contribute to P(e))
        let z = state.log_z;
        for layer in &self.down_tasks {
            let shared = SharedTables::new(state);
            let jt = &self.jt;
            let workers = &self.workers;
            self.pool.parallel(layer.len(), &|w, t| {
                let ctx = unsafe { workers.get(w) };
                Self::send_in_task(jt, &shared, ctx, layer[t], &failed);
            });
            if failed.load(Ordering::Relaxed) {
                return Err(Error::InconsistentEvidence);
            }
        }
        for ctx in self.workers.iter_mut() {
            ctx.log_z = 0.0;
        }
        state.log_z = z;
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::engine::seq::SeqEngine;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn up_groups_have_distinct_parents_and_sources() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let e = DirectEngine::new(Arc::clone(&jt), &EngineConfig::default().with_threads(2));
        for layer in &e.up_groups {
            let mut parents = std::collections::HashSet::new();
            let mut sources = std::collections::HashSet::new();
            for group in layer {
                assert!(parents.insert(group[0].to), "duplicate parent task");
                for m in group {
                    assert_eq!(m.to, group[0].to);
                    assert!(sources.insert(m.from), "duplicate source in layer");
                }
            }
            // parents never appear as sources in the same layer
            for p in &parents {
                assert!(!sources.contains(p));
            }
        }
    }

    #[test]
    fn agrees_with_seq_on_random_cases() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig::default().with_threads(4);
        let mut dir = DirectEngine::new(Arc::clone(&jt), &cfg);
        let mut seq = SeqEngine::new(Arc::clone(&jt), &cfg);
        let mut s1 = TreeState::fresh(&jt);
        let mut s2 = TreeState::fresh(&jt);
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 10, observed_fraction: 0.25, seed: 11 },
        );
        for (i, ev) in cases.iter().enumerate() {
            let a = dir.infer(&mut s1, ev).unwrap();
            let b = seq.infer(&mut s2, ev).unwrap();
            assert!(a.max_abs_diff(&b) < 1e-9, "case {i}: diff {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn detects_impossible_evidence() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut e = DirectEngine::new(Arc::clone(&jt), &EngineConfig::default().with_threads(2));
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(matches!(e.infer(&mut state, &ev), Err(Error::InconsistentEvidence)));
        // engine remains usable after the error
        let ok = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let post = e.infer(&mut state, &ok).unwrap();
        assert!((post.evidence_probability() - 0.5).abs() < 1e-9);
    }
}
