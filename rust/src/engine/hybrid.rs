//! **Fast-BNI-par** — the paper's contribution: hybrid inter-/intra-clique
//! parallelism with flattened per-layer task pools.
//!
//! §2: *"At the beginning of each layer, all the potential table entries
//! corresponding to this layer are packed to constitute one of the
//! parallel tasks. The tasks are then distributed to the parallel threads
//! to perform concurrently."*
//!
//! Per traversal layer the engine enters at most four parallel regions —
//! three in the common all-fused case (see B2 below) — independent of how
//! many messages the layer contains:
//!
//! * **A — flat marginalization**: every message's source-clique entries
//!   are chunked and pooled together; a chunk scatters into its worker's
//!   per-(message) partial buffer (zeroed lazily via generation stamps).
//!   Large and small cliques coexist in one queue → load balance
//!   (advantage i) with one region entry (advantage ii), regardless of
//!   tree shape (advantage iii).
//! * **B1 — flat partial reduction**: separator entries are chunked and
//!   pooled; each chunk sums the (touched) worker partials, so one huge
//!   separator cannot serialize the layer.
//! * **B2 — separator finish**: per message, mass + scale (accumulating
//!   `ln P(e)`), update ratio, store the new separator. When a message's
//!   whole separator fits in a single B1 chunk (the common case — most
//!   separators are far smaller than `min_chunk`), the finish is **folded
//!   into the tail of that B1 task** and the message skips region B2
//!   entirely; a layer whose every separator is single-chunk enters the
//!   pool only three times. [`HybridEngine::pool_regions`] counts actual
//!   region entries so `benches/ablation.rs` can report entries per sweep
//!   against `min_chunk`.
//! * **C — flat extension**: receiving cliques' entries are chunked and
//!   pooled; a chunk multiplies in the ratios of *all* messages aimed at
//!   its clique in this layer (grouping by receiver keeps writes
//!   disjoint).
//!
//! All plans (chunk lists, buffer offsets, receiver groups) depend only on
//! the tree, so they are precomputed at construction and shared by every
//! test case — and reused verbatim by the case-major
//! [`crate::engine::batched::BatchedHybridEngine`].

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::pool::{chunk_ranges_aligned, Pool};
use crate::engine::share::{PerWorker, SharedTables};
use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::obs::{self, trace};
use crate::{Error, Result};

/// Precomputed flat plan for one traversal layer. Shared with the
/// case-major batched engine (`engine::batched`), which runs the same
/// tasks with lane-expanded kernels.
pub(crate) struct LayerPlan {
    /// Messages of this layer.
    pub(crate) msgs: Vec<Msg>,
    /// Offset of each message's separator in the layer's ratio/partial
    /// buffers.
    pub(crate) sep_off: Vec<usize>,
    /// Total separator entries of the layer.
    pub(crate) sep_total: usize,
    /// Region-A tasks: (message index, source-clique entry range).
    pub(crate) marg_tasks: Vec<(usize, Range<usize>)>,
    /// Region-B1 tasks: (message index, separator entry range) — the
    /// partial reduction is itself flattened, so one huge separator does
    /// not serialize the layer (§Perf item 3 in EXPERIMENTS.md).
    pub(crate) reduce_tasks: Vec<(usize, Range<usize>)>,
    /// Per message: whether its separator is covered by a single B1 chunk,
    /// letting that task also run the B2 finish (mass/scale/ratio/store)
    /// in its tail — one fewer pool entry per layer when all fuse.
    pub(crate) fused: Vec<bool>,
    /// Messages whose separator spans several B1 chunks and therefore
    /// still needs the separate B2 region.
    pub(crate) b2_msgs: Vec<usize>,
    /// Receiver groups: (receiving clique, message indices into it).
    pub(crate) groups: Vec<(usize, Vec<usize>)>,
    /// Region-C tasks: (group index, receiver-clique entry range).
    pub(crate) ext_tasks: Vec<(usize, Range<usize>)>,
}

impl LayerPlan {
    pub(crate) fn build(jt: &JunctionTree, layer: &[Msg], min_chunk: usize, max_chunks: usize) -> Self {
        Self::build_aligned(jt, layer, min_chunk, max_chunks, 1)
    }

    /// [`LayerPlan::build`] with every task's entry range aligned: interior
    /// chunk boundaries are snapped to multiples of `align` entries
    /// ([`chunk_ranges_aligned`]). The batched engine passes
    /// [`crate::jt::simd::LANE_WIDTH`] — in the case-major layout each
    /// entry spans `lanes` contiguous values, so entry boundaries at
    /// lane-width multiples keep every task's flattened window on a
    /// whole-block boundary and a fixed-width SIMD walk is never cut into
    /// a scalar remainder by a task split mid-table. `align = 1` is the
    /// single-case plan unchanged.
    pub(crate) fn build_aligned(
        jt: &JunctionTree,
        layer: &[Msg],
        min_chunk: usize,
        max_chunks: usize,
        align: usize,
    ) -> Self {
        let msgs = layer.to_vec();
        let mut sep_off = Vec::with_capacity(msgs.len());
        let mut sep_total = 0usize;
        for m in &msgs {
            sep_off.push(sep_total);
            sep_total += jt.seps[m.sep].len;
        }
        // region A: flatten all source entries
        let mut marg_tasks = Vec::new();
        for (mi, m) in msgs.iter().enumerate() {
            for r in chunk_ranges_aligned(jt.cliques[m.from].len, min_chunk, max_chunks, align) {
                marg_tasks.push((mi, r));
            }
        }
        // region B1: flatten all separator entries; a single-chunk
        // separator marks its message fused (B2 folded into that task)
        let mut reduce_tasks = Vec::new();
        let mut fused = Vec::with_capacity(msgs.len());
        let mut b2_msgs = Vec::new();
        for (mi, m) in msgs.iter().enumerate() {
            let ranges = chunk_ranges_aligned(jt.seps[m.sep].len, min_chunk.min(1 << 12), max_chunks, align);
            let single = ranges.len() == 1;
            fused.push(single);
            if !single {
                b2_msgs.push(mi);
            }
            for r in ranges {
                reduce_tasks.push((mi, r));
            }
        }
        // receiver groups (a parent may receive several messages per layer)
        let mut by_to: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for (mi, m) in msgs.iter().enumerate() {
            by_to.entry(m.to).or_default().push(mi);
        }
        let groups: Vec<(usize, Vec<usize>)> = by_to.into_iter().collect();
        // region C: flatten all receiver entries
        let mut ext_tasks = Vec::new();
        for (gi, (to, _)) in groups.iter().enumerate() {
            for r in chunk_ranges_aligned(jt.cliques[*to].len, min_chunk, max_chunks, align) {
                ext_tasks.push((gi, r));
            }
        }
        LayerPlan { msgs, sep_off, sep_total, marg_tasks, reduce_tasks, fused, b2_msgs, groups, ext_tasks }
    }
}

/// Per-worker region-A scratch: the partial separator buffer plus one
/// generation stamp per message. A worker zeroes its slice for message
/// `mi` lazily on first touch of the current generation, and region B
/// reduces only stamped (actually touched) workers — so partial-buffer
/// traffic scales with the work done, not with `threads × sep_total`
/// (§Perf item 2 in EXPERIMENTS.md).
pub(crate) struct Partial {
    pub(crate) buf: Vec<f64>,
    pub(crate) stamps: Vec<u64>,
}

/// Finish one message after its separator values have been reduced into
/// `ratio_buf[off .. off+len]`: compute the mass (0 ⇒ inconsistent
/// evidence), scale to unit mass accumulating `ln`-mass into worker `w`'s
/// slot, store the new separator, and turn the buffer slice into the
/// update ratio in place. Shared by the fused B1 tail and region B2.
///
/// # Safety
/// The caller must hold `ratio_buf[off .. off+len]`, the message's
/// separator table, and worker `w`'s log-z slot exclusively.
pub(crate) unsafe fn finish_message(
    jt: &JunctionTree,
    m: Msg,
    off: usize,
    ratio_buf: &[AtomicU64],
    shared: &SharedTables,
    log_z: &PerWorker<f64>,
    w: usize,
    failed: &AtomicBool,
) {
    let len = jt.seps[m.sep].len;
    let ratio_slice = std::slice::from_raw_parts_mut(ratio_buf.as_ptr().add(off) as *mut f64, len);
    let mass = ops::sum(ratio_slice);
    if mass == 0.0 {
        failed.store(true, Ordering::Relaxed);
        return;
    }
    ops::scale(ratio_slice, 1.0 / mass);
    *log_z.get(w) += mass.ln();
    // store new separator, convert slice to ratio in place
    let sep_tab = shared.sep_mut(m.sep);
    for j in 0..len {
        let new = ratio_slice[j];
        let old = sep_tab[j];
        sep_tab[j] = new;
        ratio_slice[j] = if old != 0.0 { new / old } else { 0.0 };
    }
}

/// The hybrid Fast-BNI-par engine (see module docs).
pub struct HybridEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    pool: Pool,
    threads: usize,
    up_plans: Vec<LayerPlan>,
    down_plans: Vec<LayerPlan>,
    /// Per-worker partial buffers with lazy-zero stamps.
    partials: PerWorker<Partial>,
    /// Layer-wide ratio buffer.
    ratio: Vec<f64>,
    /// Per-worker `ln`-mass accumulators for region B.
    log_z: PerWorker<f64>,
    /// Current stamp generation (bumped per layer execution).
    generation: u64,
    /// Pool regions actually entered (monotone; see
    /// [`HybridEngine::pool_regions`]).
    regions: u64,
}

impl HybridEngine {
    /// Build for a tree; all layer plans are precomputed here.
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let threads = cfg.resolved_threads();
        let pool = Pool::new(threads);
        let up_plans: Vec<LayerPlan> =
            sched.up_layers.iter().map(|l| LayerPlan::build(&jt, l, cfg.min_chunk, cfg.max_chunks)).collect();
        let down_plans: Vec<LayerPlan> =
            sched.down_layers.iter().map(|l| LayerPlan::build(&jt, l, cfg.min_chunk, cfg.max_chunks)).collect();
        let max_sep_total =
            up_plans.iter().chain(&down_plans).map(|p| p.sep_total).max().unwrap_or(1).max(1);
        let max_msgs =
            up_plans.iter().chain(&down_plans).map(|p| p.msgs.len()).max().unwrap_or(1).max(1);
        let partials =
            PerWorker::new(threads, |_| Partial { buf: vec![0.0; max_sep_total], stamps: vec![0; max_msgs] });
        let ratio = vec![0.0; max_sep_total];
        let log_z = PerWorker::new(threads, |_| 0.0);
        HybridEngine {
            jt,
            sched,
            pool,
            threads,
            up_plans,
            down_plans,
            partials,
            ratio,
            log_z,
            generation: 0,
            regions: 0,
        }
    }

    /// Total parallel regions entered so far (monotone across cases).
    /// `benches/ablation.rs` reads the per-sweep delta: with the B2 finish
    /// folded into single-chunk B1 tasks, a layer costs 3 entries instead
    /// of 4 whenever every separator fits one chunk.
    pub fn pool_regions(&self) -> u64 {
        self.regions
    }

    /// Run one layer: regions A, B, C.
    fn run_layer(&mut self, state: &mut TreeState, up: bool, li: usize) -> Result<()> {
        let plan = if up { &self.up_plans[li] } else { &self.down_plans[li] };
        let jt = &self.jt;
        let sep_total = plan.sep_total;
        if plan.msgs.is_empty() {
            return Ok(());
        }

        // region A: flat marginalization into per-worker partials.
        // Slices are zeroed lazily on first touch per (worker, message)
        // via generation stamps — no O(threads × sep_total) memset.
        self.generation += 1;
        self.regions += 1;
        let generation = self.generation;
        {
            let shared = SharedTables::new(state);
            let partials = &self.partials;
            self.pool.parallel_region("hybrid.A", plan.marg_tasks.len(), &|w, t| {
                let (mi, ref range) = plan.marg_tasks[t];
                let m = plan.msgs[mi];
                let sep_meta = &jt.seps[m.sep];
                let rm = jt.edge_maps[m.sep].runs_from(sep_meta, m.from);
                // SAFETY: sources are read-only in region A; worker w owns
                // its partial slot.
                let src = unsafe { shared.clique(m.from) };
                let partial = unsafe { partials.get(w) };
                let off = plan.sep_off[mi];
                let slice = &mut partial.buf[off..off + sep_meta.len];
                if partial.stamps[mi] != generation {
                    partial.stamps[mi] = generation;
                    ops::zero(slice);
                }
                ops::marg_runs_range(src, rm, range.clone(), slice);
            });
        }

        // region B1: flat partial reduction — separator entry chunks, so a
        // single huge separator never serializes the layer. A task whose
        // chunk covers its message's whole separator (plan.fused) also runs
        // the B2 finish in its tail, so that message skips region B2.
        let failed = AtomicBool::new(false);
        self.regions += 1;
        {
            let shared = SharedTables::new(state);
            let partials = &self.partials;
            let log_z = &self.log_z;
            let ratio_buf = ops::as_atomic(&mut self.ratio[..sep_total]);
            let n_workers = self.threads;
            self.pool.parallel_region("hybrid.B1", plan.reduce_tasks.len(), &|w, t| {
                let (mi, ref range) = plan.reduce_tasks[t];
                let off = plan.sep_off[mi];
                // SAFETY: tasks of one message cover disjoint sub-ranges of
                // [off, off+len); tasks of different messages are disjoint
                // by construction.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(
                        ratio_buf.as_ptr().add(off + range.start) as *mut f64,
                        range.len(),
                    )
                };
                for x in slice.iter_mut() {
                    *x = 0.0;
                }
                for wk in 0..n_workers {
                    // SAFETY: region A is complete; partial reads race-free.
                    let partial = unsafe { partials.get(wk) };
                    if partial.stamps[mi] != generation {
                        continue;
                    }
                    let p = &partial.buf[off + range.start..off + range.end];
                    for (d, &x) in slice.iter_mut().zip(p) {
                        *d += x;
                    }
                }
                if plan.fused[mi] {
                    // SAFETY: this task owns the message's whole
                    // [off, off+len) range and its separator exclusively.
                    unsafe { finish_message(jt, plan.msgs[mi], off, ratio_buf, &shared, log_z, w, &failed) };
                }
            });
        }

        // region B2: finish for multi-chunk separators only (skipped —
        // no pool entry — when every message of the layer fused into B1)
        if !plan.b2_msgs.is_empty() {
            self.regions += 1;
            let shared = SharedTables::new(state);
            let log_z = &self.log_z;
            let ratio_buf = ops::as_atomic(&mut self.ratio[..sep_total]);
            self.pool.parallel_region("hybrid.B2", plan.b2_msgs.len(), &|w, t| {
                let mi = plan.b2_msgs[t];
                // SAFETY: message mi owns [off, off+len) of the ratio
                // buffer and its separator table exclusively.
                unsafe {
                    finish_message(jt, plan.msgs[mi], plan.sep_off[mi], ratio_buf, &shared, log_z, w, &failed)
                };
            });
        }
        for w in self.log_z.iter_mut() {
            state.log_z += *w;
            *w = 0.0;
        }
        if failed.load(Ordering::Relaxed) {
            return Err(Error::InconsistentEvidence);
        }

        // region C: flat extension grouped by receiver
        self.regions += 1;
        {
            let shared = SharedTables::new(state);
            let ratio = &self.ratio;
            self.pool.parallel_region("hybrid.C", plan.ext_tasks.len(), &|_w, t| {
                let (gi, ref range) = plan.ext_tasks[t];
                let (to, ref mis) = plan.groups[gi];
                // SAFETY: groups have distinct receivers; ranges of one
                // receiver are disjoint.
                let dst = unsafe { shared.clique_mut(to) };
                for &mi in mis {
                    let m = plan.msgs[mi];
                    let sep_meta = &jt.seps[m.sep];
                    let rm = jt.edge_maps[m.sep].runs_from(sep_meta, m.to);
                    let off = plan.sep_off[mi];
                    ops::extend_runs_range(dst, rm, range.clone(), &ratio[off..off + sep_meta.len]);
                }
            });
        }
        Ok(())
    }
}

impl Engine for HybridEngine {
    fn name(&self) -> &'static str {
        "Fast-BNI-par"
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        // Telemetry below reads the clock and bumps counters only — the
        // numeric path is untouched, so posteriors stay byte-identical.
        let root_span = trace::span("hybrid.infer");
        let regions0 = self.regions;
        state.reset(&self.jt);
        ev.apply(&self.jt, state);
        {
            let up_span = trace::span("hybrid.up");
            for li in 0..self.up_plans.len() {
                self.run_layer(state, true, li)?;
            }
            up_span.note(&format!("layers={}", self.up_plans.len()));
        }
        for root in self.sched.roots.clone() {
            let data = state.clique_mut(root);
            let mass = ops::sum(data);
            if mass == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            ops::scale(data, 1.0 / mass);
            state.log_z += mass.ln();
        }
        let z = state.log_z;
        {
            let down_span = trace::span("hybrid.down");
            for li in 0..self.down_plans.len() {
                self.run_layer(state, false, li)?;
            }
            down_span.note(&format!("layers={}", self.down_plans.len()));
        }
        state.log_z = z;
        let sweep_regions = self.regions - regions0;
        root_span.note(&format!("regions={sweep_regions}"));
        obs::global().counter("fastbn_hybrid_sweeps_total").inc();
        obs::global().counter("fastbn_pool_regions_total").add(sweep_regions);
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};
    use crate::engine::seq::SeqEngine;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn plans_cover_all_entries_exactly_once() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 4, min_chunk: 4, ..Default::default() };
        let e = HybridEngine::new(Arc::clone(&jt), &cfg);
        for plan in e.up_plans.iter().chain(&e.down_plans) {
            // per message, region A ranges must tile the source clique
            for (mi, m) in plan.msgs.iter().enumerate() {
                let mut covered = vec![false; jt.cliques[m.from].len];
                for (tmi, r) in &plan.marg_tasks {
                    if *tmi == mi {
                        for i in r.clone() {
                            assert!(!covered[i], "entry {i} covered twice");
                            covered[i] = true;
                        }
                    }
                }
                assert!(covered.iter().all(|&c| c), "message {mi} incompletely covered");
            }
            // groups: receivers distinct, messages partitioned
            let mut seen_to = std::collections::HashSet::new();
            let mut seen_mi = std::collections::HashSet::new();
            for (to, mis) in &plan.groups {
                assert!(seen_to.insert(*to));
                for mi in mis {
                    assert!(seen_mi.insert(*mi));
                }
            }
            assert_eq!(seen_mi.len(), plan.msgs.len());
        }
    }

    #[test]
    fn agrees_with_seq_on_random_cases() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 4, min_chunk: 4, ..Default::default() };
        let mut hyb = HybridEngine::new(Arc::clone(&jt), &cfg);
        let mut seq = SeqEngine::new(Arc::clone(&jt), &cfg);
        let mut s1 = TreeState::fresh(&jt);
        let mut s2 = TreeState::fresh(&jt);
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 20, observed_fraction: 0.25, seed: 41 },
        );
        for (i, ev) in cases.iter().enumerate() {
            let a = hyb.infer(&mut s1, ev).unwrap();
            let b = seq.infer(&mut s2, ev).unwrap();
            assert!(a.max_abs_diff(&b) < 1e-9, "case {i}: diff {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn agrees_with_seq_on_a_larger_generated_network() {
        let net = netgen::NetSpec {
            name: "hyb-test".into(),
            nodes: 80,
            arcs: 110,
            max_parents: 3,
            card_choices: vec![(2, 0.6), (3, 0.25), (4, 0.15)],
            locality: 10,
            max_table: 1 << 12,
            alpha: 1.0,
            seed: 77,
        }
        .generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 8, min_chunk: 16, ..Default::default() };
        let mut hyb = HybridEngine::new(Arc::clone(&jt), &cfg);
        let mut seq = SeqEngine::new(Arc::clone(&jt), &cfg);
        let mut s1 = TreeState::fresh(&jt);
        let mut s2 = TreeState::fresh(&jt);
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 5, observed_fraction: 0.2, seed: 43 },
        );
        for (i, ev) in cases.iter().enumerate() {
            let a = hyb.infer(&mut s1, ev).unwrap();
            let b = seq.infer(&mut s2, ev).unwrap();
            assert!(a.max_abs_diff(&b) < 1e-9, "case {i}: diff {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn b2_fold_covers_every_message_exactly_once() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        for min_chunk in [1usize, 4, 1 << 11] {
            let cfg = EngineConfig { threads: 4, min_chunk, ..Default::default() };
            let e = HybridEngine::new(Arc::clone(&jt), &cfg);
            for plan in e.up_plans.iter().chain(&e.down_plans) {
                assert_eq!(plan.fused.len(), plan.msgs.len());
                for (mi, &fused) in plan.fused.iter().enumerate() {
                    let n_chunks = plan.reduce_tasks.iter().filter(|(tmi, _)| *tmi == mi).count();
                    // fused ⇔ exactly one B1 chunk; unfused messages appear
                    // in b2_msgs exactly once
                    assert_eq!(fused, n_chunks == 1, "mi={mi} min_chunk={min_chunk}");
                    let in_b2 = plan.b2_msgs.iter().filter(|&&x| x == mi).count();
                    assert_eq!(in_b2, usize::from(!fused));
                }
            }
        }
        // with the default (large) min_chunk every mixed12 separator fits
        // one chunk, so the whole layer fuses: 3 regions per layer
        let cfg = EngineConfig { threads: 4, ..Default::default() };
        let e = HybridEngine::new(Arc::clone(&jt), &cfg);
        assert!(e.up_plans.iter().chain(&e.down_plans).all(|p| p.b2_msgs.is_empty()));
    }

    #[test]
    fn pool_region_counter_counts_entered_regions() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 2, ..Default::default() };
        let mut e = HybridEngine::new(Arc::clone(&jt), &cfg);
        let mut state = TreeState::fresh(&jt);
        assert_eq!(e.pool_regions(), 0);
        e.infer(&mut state, &Evidence::none()).unwrap();
        let per_sweep = e.pool_regions();
        // all-fused layers: exactly 3 regions per non-empty layer
        let layers: u64 =
            (e.up_plans.iter().chain(&e.down_plans)).filter(|p| !p.msgs.is_empty()).count() as u64;
        assert_eq!(per_sweep, 3 * layers);
        // the counter is monotone per sweep
        e.infer(&mut state, &Evidence::none()).unwrap();
        assert_eq!(e.pool_regions(), 2 * per_sweep);
    }

    #[test]
    fn detects_impossible_evidence_and_recovers() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut e = HybridEngine::new(Arc::clone(&jt), &EngineConfig::default().with_threads(2));
        let mut state = TreeState::fresh(&jt);
        let bad = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(matches!(e.infer(&mut state, &bad), Err(Error::InconsistentEvidence)));
        let ok = Evidence::from_pairs(&net, &[("smoke", "no")]).unwrap();
        let post = e.infer(&mut state, &ok).unwrap();
        assert!((post.evidence_probability() - 0.5).abs() < 1e-9);
    }
}
