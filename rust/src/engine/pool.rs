//! Persistent worker pool with a flat, dynamically-stolen task queue.
//!
//! The paper's hybrid parallelism claims *"smaller parallelization
//! overhead"* because it enters one parallel region per layer instead of
//! one per table operation. That only matters if entering a region is
//! cheap: spawning OS threads per region (≈10–20 µs each) would drown the
//! small layers. This pool keeps `threads − 1` workers parked on a
//! condvar; publishing a job is one mutex lock + notify, and tasks are
//! claimed with a single `fetch_add` (dynamic self-scheduling, the OpenMP
//! `schedule(dynamic)` analog the paper's implementations use).
//!
//! The leader participates in the work, so `Pool::new(1)` degrades to a
//! plain inline loop with zero synchronization.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs::profile::{self, RegionTally};

/// Type-erased job: `(worker_id, task_index)` callback plus the shared
/// task counter. The raw pointer erases the borrow lifetime; safety comes
/// from `parallel()` not returning until every worker is done with it.
struct Job {
    /// Borrowed closure, valid for the duration of the `parallel()` call.
    f: *const (dyn Fn(usize, usize) + Sync),
    /// Next task index to claim.
    next: Arc<AtomicUsize>,
    /// Total tasks.
    n_tasks: usize,
    /// Per-worker busy/task tally, present only while the parallelism
    /// profiler is armed — the disarmed claim loop is untouched.
    prof: Option<Arc<RegionTally>>,
}

unsafe impl Send for Job {}

struct Slot {
    /// Monotone generation counter; bumped per published job.
    generation: u64,
    /// Current job, if a generation is active.
    job: Option<Job>,
    /// Workers still running the current generation.
    active: usize,
    /// Pool is shutting down.
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers wait here for a new generation.
    work_cv: Condvar,
    /// The leader waits here for `active == 0`.
    done_cv: Condvar,
}

/// A persistent thread pool running flat task queues.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Create a pool that runs jobs on `threads` threads total (the
    /// calling thread counts as one; `threads - 1` workers are spawned).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { generation: 0, job: None, active: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("fastbn-worker-{wid}"))
                    .spawn(move || worker_loop(shared, wid))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Pool { shared, workers, threads }
    }

    /// Number of threads participating in `parallel` (including the
    /// leader).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_id, task)` for every `task in 0..n_tasks`, dynamically
    /// load-balanced across all threads. Returns when all tasks finished.
    /// `worker_id` is in `0..threads()` (leader = 0) and is stable within a
    /// call — tasks may use it to index per-worker scratch without locking.
    pub fn parallel(&self, n_tasks: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        self.parallel_region("pool.region", n_tasks, f);
    }

    /// [`Pool::parallel`] under a named profiler region. Engines name
    /// their regions after the hybrid phases (`hybrid.A`, `batched.B1`,
    /// `approx.round`, `pc.level`, …); while the profiler is armed every
    /// entry records per-worker busy time, task counts, region wall time
    /// and the leader's barrier wait under that name. Disarmed, the name
    /// costs nothing — one relaxed load decides.
    pub fn parallel_region(&self, region: &'static str, n_tasks: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        let prof = if profile::armed() { Some((Instant::now(), Arc::new(RegionTally::new(self.threads)))) } else { None };
        if self.threads == 1 || n_tasks == 1 {
            match &prof {
                None => {
                    for t in 0..n_tasks {
                        f(0, t);
                    }
                }
                Some((_, tally)) => {
                    for t in 0..n_tasks {
                        let t0 = Instant::now();
                        f(0, t);
                        tally.busy_ns[0].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        tally.tasks[0].fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            if let Some((start, tally)) = prof {
                profile::record_region(region, start.elapsed(), Duration::ZERO, &tally);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        {
            let mut slot = self.shared.slot.lock().unwrap();
            debug_assert!(slot.job.is_none(), "parallel() is not reentrant");
            slot.generation += 1;
            slot.active = self.workers.len();
            slot.job = Some(Job {
                // SAFETY: we block below until `active == 0`, so the borrow
                // outlives every worker's use of the pointer. The transmute
                // only erases the lifetime, not the type.
                f: unsafe {
                    std::mem::transmute::<&(dyn Fn(usize, usize) + Sync), &'static (dyn Fn(usize, usize) + Sync)>(f)
                        as *const _
                },
                next: Arc::clone(&next),
                n_tasks,
                prof: prof.as_ref().map(|(_, tally)| Arc::clone(tally)),
            });
            self.shared.work_cv.notify_all();
        }
        // Leader works too (worker id 0).
        claim_loop(f, &next, n_tasks, 0, prof.as_ref().map(|(_, tally)| tally.as_ref()));
        // Wait for the workers to drain the queue; while armed, the time
        // spent here is the region's barrier wait (the leader ran dry
        // before the slowest worker).
        let barrier_start = prof.as_ref().map(|_| Instant::now());
        let mut slot = self.shared.slot.lock().unwrap();
        while slot.active > 0 {
            slot = self.shared.done_cv.wait(slot).unwrap();
        }
        slot.job = None;
        drop(slot);
        if let Some((start, tally)) = prof {
            let barrier = barrier_start.map(|b| b.elapsed()).unwrap_or(Duration::ZERO);
            profile::record_region(region, start.elapsed(), barrier, &tally);
        }
    }
}

/// The dynamic self-scheduling claim loop, shared by leader and workers.
/// With a tally the per-task cost is two monotonic clock reads and two
/// relaxed atomic adds; without one it is the bare `fetch_add` claim.
fn claim_loop(
    f: &(dyn Fn(usize, usize) + Sync),
    next: &AtomicUsize,
    n_tasks: usize,
    wid: usize,
    tally: Option<&RegionTally>,
) {
    match tally {
        None => loop {
            let t = next.fetch_add(1, Ordering::Relaxed);
            if t >= n_tasks {
                break;
            }
            f(wid, t);
        },
        Some(tally) => loop {
            let t = next.fetch_add(1, Ordering::Relaxed);
            if t >= n_tasks {
                break;
            }
            let t0 = Instant::now();
            f(wid, t);
            tally.busy_ns[wid].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            tally.tasks[wid].fetch_add(1, Ordering::Relaxed);
        },
    }
}

fn worker_loop(shared: Arc<Shared>, wid: usize) {
    let mut last_gen = 0u64;
    loop {
        // wait for a new generation (or shutdown)
        let (f, next, n_tasks, prof) = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.generation != last_gen {
                    if let Some(job) = &slot.job {
                        last_gen = slot.generation;
                        break (job.f, Arc::clone(&job.next), job.n_tasks, job.prof.clone());
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        // SAFETY: the leader blocks in `parallel()` until we decrement
        // `active`, so `f` is alive for the whole claim loop.
        let f = unsafe { &*f };
        claim_loop(f, &next, n_tasks, wid, prof.as_deref());
        let mut slot = shared.slot.lock().unwrap();
        slot.active -= 1;
        if slot.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `0..len` into at most `max_chunks` contiguous ranges of at least
/// `min_chunk` elements — the flattening helper engines use to turn table
/// entries into tasks.
pub fn chunk_ranges(len: usize, min_chunk: usize, max_chunks: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let min_chunk = min_chunk.max(1);
    let n_chunks = (len / min_chunk).clamp(1, max_chunks.max(1));
    let base = len / n_chunks;
    let rem = len % n_chunks;
    let mut out = Vec::with_capacity(n_chunks);
    let mut start = 0usize;
    for i in 0..n_chunks {
        let sz = base + usize::from(i < rem);
        out.push(start..start + sz);
        start += sz;
    }
    debug_assert_eq!(start, len);
    out
}

/// [`chunk_ranges`] with every **interior** boundary snapped to the
/// nearest multiple of `align` (the first starts at 0 and the last ends
/// at `len` regardless). The batched engine passes
/// [`crate::jt::simd::LANE_WIDTH`] so a fixed-width SIMD walk over a
/// chunk's lane-expanded window never gets cut into a scalar remainder by
/// a task split mid-table; the final ragged tail — if any — lands once,
/// at the table's true end.
///
/// Chunk-count selection is `chunk_ranges`'s (same `min_chunk` /
/// `max_chunks` semantics); snapping moves each boundary by less than
/// `align`, and boundaries that collide after snapping merge their chunks
/// (so chunks stay non-empty and coverage stays exact). `align ≤ 1`
/// degrades to plain `chunk_ranges`.
pub fn chunk_ranges_aligned(
    len: usize,
    min_chunk: usize,
    max_chunks: usize,
    align: usize,
) -> Vec<std::ops::Range<usize>> {
    let plain = chunk_ranges(len, min_chunk, max_chunks);
    if align <= 1 || plain.len() <= 1 {
        return plain;
    }
    let mut bounds: Vec<usize> = Vec::with_capacity(plain.len() + 1);
    bounds.push(0);
    for r in &plain[..plain.len() - 1] {
        let b = (r.end + align / 2) / align * align;
        if b > *bounds.last().expect("bounds starts non-empty") && b < len {
            bounds.push(b);
        }
    }
    bounds.push(len);
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn all_tasks_run_exactly_once() {
        let pool = Pool::new(4);
        let n = 1000;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel(n, &|_w, t| {
            counts[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let counter = AtomicUsize::new(0);
        pool.parallel(17, &|w, _t| {
            assert_eq!(w, 0);
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn worker_ids_stay_in_range() {
        let pool = Pool::new(3);
        let seen = Mutex::new(std::collections::HashSet::new());
        pool.parallel(200, &|w, _t| {
            assert!(w < 3);
            seen.lock().unwrap().insert(w);
        });
        // at least the leader participated
        assert!(seen.lock().unwrap().contains(&0));
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = Pool::new(4);
        for round in 0..50 {
            let total = AtomicUsize::new(0);
            pool.parallel(100, &|_w, t| {
                total.fetch_add(t, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 4950, "round {round}");
        }
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        let pool = Pool::new(2);
        pool.parallel(0, &|_w, _t| panic!("must not run"));
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let pool = Pool::new(8);
        let data: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let partials: Vec<Mutex<f64>> = (0..8).map(|_| Mutex::new(0.0)).collect();
        let chunks = chunk_ranges(data.len(), 64, 100);
        let chunks_ref = &chunks;
        let data_ref = &data;
        pool.parallel(chunks.len(), &|w, t| {
            let s: f64 = data_ref[chunks_ref[t].clone()].iter().sum();
            *partials[w].lock().unwrap() += s;
        });
        let total: f64 = partials.iter().map(|p| *p.lock().unwrap()).sum();
        assert_eq!(total, 49_995_000.0);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (len, min, maxc) in [(0usize, 1usize, 4usize), (10, 3, 4), (100, 7, 3), (5, 100, 8), (64, 1, 64)] {
            let ranges = chunk_ranges(len, min, maxc);
            let mut covered = 0usize;
            let mut expect_start = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect_start);
                covered += r.len();
                expect_start = r.end;
            }
            assert_eq!(covered, len, "len={len} min={min} maxc={maxc}");
            if len > 0 {
                assert!(ranges.len() <= maxc);
            }
        }
    }

    #[test]
    fn aligned_chunk_ranges_snap_interior_boundaries_only() {
        for (len, min, maxc, align) in [
            (100usize, 7usize, 3usize, 8usize), // boundaries 34/67 snap to 32/64
            (64, 1, 64, 8),                     // min_chunk 1: many 1-wide chunks merge into 8-wide
            (10, 3, 4, 8),                      // len barely above align: some boundaries collide
            (4, 1, 8, 8),                       // len < align: collapses to one chunk
            (0, 1, 4, 8),                       // empty
            (100, 7, 3, 1),                     // align 1 degrades to chunk_ranges
            (1 << 16, 1 << 11, 256, 4),         // production-shaped split at 4-wide
        ] {
            let ranges = chunk_ranges_aligned(len, min, maxc, align);
            // exact, ordered, gap-free coverage of 0..len
            let mut expect_start = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect_start, "len={len} min={min} maxc={maxc} align={align}");
                assert!(!r.is_empty());
                expect_start = r.end;
            }
            assert_eq!(expect_start, len, "len={len} min={min} maxc={maxc} align={align}");
            if len > 0 {
                assert!(ranges.len() <= maxc);
            }
            // every interior boundary is an align multiple
            for r in ranges.iter().skip(1) {
                assert_eq!(r.start % align, 0, "len={len} min={min} maxc={maxc} align={align}: {r:?}");
            }
            if align == 1 {
                assert_eq!(ranges, chunk_ranges(len, min, maxc));
            }
        }
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = Pool::new(4);
        pool.parallel(10, &|_w, _t| {});
        drop(pool); // must not hang
    }

    #[test]
    fn armed_profiler_tallies_every_task_once() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::profile::set_armed(true);
        let pool = Pool::new(2);
        let ran = AtomicUsize::new(0);
        pool.parallel_region("pool-test-armed", 64, &|_w, _t| {
            ran.fetch_add(1, Ordering::Relaxed);
            std::hint::black_box((0..500u64).sum::<u64>());
        });
        crate::obs::profile::set_armed(false);
        assert_eq!(ran.load(Ordering::Relaxed), 64, "profiling must not change scheduling");
        let snap = crate::obs::profile::snapshot();
        let p = snap.iter().find(|p| p.region == "pool-test-armed").expect("region was profiled");
        assert_eq!(p.entries, 1);
        assert_eq!(p.workers(), 2);
        assert_eq!(p.tasks.iter().sum::<u64>(), 64);
        assert!(p.imbalance() >= 1.0 - 1e-9, "{}", p.imbalance());
        assert!(p.imbalance() <= p.workers() as f64 + 1e-9, "{}", p.imbalance());
        // every lane's busy time fits inside the region wall (µs slop for
        // clock truncation on near-instant tasks)
        for b in &p.busy_us {
            assert!(*b <= p.wall_us + 1_000, "busy {b} vs wall {}", p.wall_us);
        }
    }

    #[test]
    fn armed_inline_path_profiles_as_the_leader_lane() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::profile::set_armed(true);
        let pool = Pool::new(1);
        pool.parallel_region("pool-test-inline", 5, &|w, _t| assert_eq!(w, 0));
        crate::obs::profile::set_armed(false);
        let snap = crate::obs::profile::snapshot();
        let p = snap.iter().find(|p| p.region == "pool-test-inline").expect("region was profiled");
        assert_eq!(p.tasks, vec![5]);
        assert_eq!(p.barrier_us, 0, "inline regions have no barrier");
    }

    #[test]
    fn disarmed_regions_record_nothing() {
        let _serialized = crate::obs::trace::TEST_TOGGLE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::obs::profile::set_armed(false);
        crate::obs::profile::reset();
        let pool = Pool::new(2);
        pool.parallel_region("pool-test-disarmed", 32, &|_w, _t| {});
        assert!(crate::obs::profile::snapshot().iter().all(|p| p.region != "pool-test-disarmed"));
    }
}
