//! **Fast-BNI-batch** — case-major batched hybrid propagation.
//!
//! Fast-BNI's winning move is amortizing overhead across work items: one
//! parallel region per layer instead of one per table op, index maps
//! computed once instead of per entry. This engine applies the same move
//! one level up, across **evidence cases**: `B` cases propagate through
//! the tree in one sweep, stored lane-interleaved (entry `i` of case `b`
//! at `i*B + b` — see [`crate::jt::state::BatchState`]), so every cached
//! `map[i]` lookup, every run bound, and every pool-region entry is paid
//! once per *entry* and amortized `B`× across cases, with the per-lane
//! inner loop unit-stride and auto-vectorizable (`ops::marg_runs_cases_range`
//! & co.). This is the throughput direction Fast-PGM pushes the FastBN
//! line toward (PAPERS.md), and it is exactly the shape of the
//! `coordinator::batch` and fleet-serving workloads.
//!
//! The engine reuses the hybrid engine's precomputed
//! [`crate::engine::hybrid::LayerPlan`]s (same flattening, same B2 fold
//! into single-chunk B1 tasks) and the same [`Pool`]; only the kernels are
//! lane-expanded, separator scaling and `log_z` are tracked **per case**,
//! and an inconsistent-evidence case kills its lane, never the batch.
//!
//! `infer_batch` slices arbitrary case lists into chunks of `B` lanes.
//! Every kernel call is bounded by the chunk's **occupancy**: the inner
//! per-lane loops stop at the number of cases actually present while the
//! stride stays `B`, so a partial final chunk (or a lone `infer` through
//! this engine, occupancy 1) pays per-entry work proportional to its
//! cases, not the configured lane count. Idle trailing lanes are simply
//! never touched after the arena reset.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::hybrid::LayerPlan;
use crate::engine::pool::Pool;
use crate::engine::share::{PerWorker, SharedTables};
use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::{BatchState, TreeState};
use crate::jt::tree::JunctionTree;
use crate::obs::{self, trace};
use crate::{Error, Result};

/// Per-worker region-A scratch: lane-expanded partial separator buffer
/// with lazy zero stamps (the lane analog of the hybrid engine's
/// `Partial`). Kept separate from [`LaneFinish`] so a fused B1 tail
/// holding worker `w`'s finish scratch exclusively never overlaps the
/// reduce loops reading `w`'s partial buffer from other tasks.
struct LanePartial {
    buf: Vec<f64>,
    stamps: Vec<u64>,
}

/// Per-worker separator-finish scratch: per-lane `ln`-mass accumulators
/// plus mass/factor buffers. Touched only inside [`finish_lanes`] (one
/// task per message owns it via its worker id) and the post-region fold.
struct LaneFinish {
    log_z: Vec<f64>,
    masses: Vec<f64>,
    factors: Vec<f64>,
}

/// Finish one message after its separator lanes have been reduced into
/// `ratio_buf[off*lanes .. (off+len)*lanes]`: per-lane mass (0 ⇒ that
/// lane's evidence is inconsistent — flag it, keep the sweep going),
/// per-lane scale with `ln`-mass accumulation, store the new separator,
/// and turn the buffer window into the update ratio in place (elementwise
/// over lanes, so the single-case `0/0 → 0` rule applies per lane). All
/// loops stop at the sweep's occupancy `occ`; lanes `occ..lanes` of the
/// buffer and the separator stay untouched.
///
/// # Safety
/// The caller must hold the message's lane window of `ratio_buf`, its
/// separator table, and `scratch` exclusively.
unsafe fn finish_lanes(
    jt: &JunctionTree,
    m: Msg,
    off: usize,
    lanes: usize,
    occ: usize,
    ratio_buf: &[AtomicU64],
    shared: &SharedTables,
    scratch: &mut LaneFinish,
    failed: &[AtomicBool],
) {
    let len = jt.seps[m.sep].len;
    let slice = std::slice::from_raw_parts_mut(ratio_buf.as_ptr().add(off * lanes) as *mut f64, len * lanes);
    let masses = &mut scratch.masses[..occ];
    for x in masses.iter_mut() {
        *x = 0.0;
    }
    ops::sum_cases(slice, lanes, masses);
    let factors = &mut scratch.factors[..occ];
    for (b, factor) in factors.iter_mut().enumerate() {
        if masses[b] == 0.0 {
            // dead lane: flag it and propagate zeros (0/0 → 0 keeps every
            // downstream table of this lane at zero, other lanes untouched)
            failed[b].store(true, Ordering::Relaxed);
            *factor = 1.0;
        } else {
            *factor = 1.0 / masses[b];
            scratch.log_z[b] += masses[b].ln();
        }
    }
    ops::scale_cases(slice, lanes, factors);
    let sep_tab = shared.sep_mut(m.sep);
    if occ == lanes {
        // full occupancy (the steady-state hot path): one contiguous pass
        for j in 0..len * lanes {
            let new = slice[j];
            let old = sep_tab[j];
            sep_tab[j] = new;
            slice[j] = if old != 0.0 { new / old } else { 0.0 };
        }
    } else {
        for j in 0..len {
            for b in 0..occ {
                let idx = j * lanes + b;
                let new = slice[idx];
                let old = sep_tab[idx];
                sep_tab[idx] = new;
                slice[idx] = if old != 0.0 { new / old } else { 0.0 };
            }
        }
    }
}

/// The case-major batched hybrid engine (see module docs).
pub struct BatchedHybridEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    pool: Pool,
    threads: usize,
    lanes: usize,
    up_plans: Vec<LayerPlan>,
    down_plans: Vec<LayerPlan>,
    partials: PerWorker<LanePartial>,
    finish: PerWorker<LaneFinish>,
    /// Layer-wide lane-expanded ratio buffer.
    ratio: Vec<f64>,
    /// Owned lane state — reset (one memcpy) per sweep.
    state: BatchState,
    /// Per-lane inconsistent-evidence flags for the current sweep.
    failed: Vec<AtomicBool>,
    /// Current stamp generation (bumped per layer execution).
    generation: u64,
}

impl BatchedHybridEngine {
    /// Build for a tree with `cfg.batch` lanes per sweep.
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let threads = cfg.resolved_threads();
        let lanes = cfg.batch.max(1);
        let pool = Pool::new(threads);
        // lane-width-aligned task boundaries: a blocked SIMD walk over a
        // task's lane-expanded window never straddles a chunk split
        let align = crate::jt::simd::LANE_WIDTH;
        let up_plans: Vec<LayerPlan> = sched
            .up_layers
            .iter()
            .map(|l| LayerPlan::build_aligned(&jt, l, cfg.min_chunk, cfg.max_chunks, align))
            .collect();
        let down_plans: Vec<LayerPlan> = sched
            .down_layers
            .iter()
            .map(|l| LayerPlan::build_aligned(&jt, l, cfg.min_chunk, cfg.max_chunks, align))
            .collect();
        let max_sep_total = up_plans.iter().chain(&down_plans).map(|p| p.sep_total).max().unwrap_or(0);
        let max_msgs = up_plans.iter().chain(&down_plans).map(|p| p.msgs.len()).max().unwrap_or(0);
        let partials = PerWorker::new(threads, |_| LanePartial {
            buf: vec![0.0; max_sep_total * lanes],
            stamps: vec![0; max_msgs],
        });
        let finish = PerWorker::new(threads, |_| LaneFinish {
            log_z: vec![0.0; lanes],
            masses: vec![0.0; lanes],
            factors: vec![0.0; lanes],
        });
        let ratio = vec![0.0; max_sep_total * lanes];
        let state = BatchState::fresh(&jt, lanes);
        let failed = (0..lanes).map(|_| AtomicBool::new(false)).collect();
        BatchedHybridEngine {
            jt,
            sched,
            pool,
            threads,
            lanes,
            up_plans,
            down_plans,
            partials,
            finish,
            ratio,
            state,
            failed,
            generation: 0,
        }
    }

    /// Lanes per sweep.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run every case, `lanes` per sweep, returning per-case results in
    /// order. An inconsistent case yields `Err` for its slot only.
    pub fn infer_cases(&mut self, cases: &[Evidence]) -> Vec<Result<Posteriors>> {
        let mut out = Vec::with_capacity(cases.len());
        for chunk in cases.chunks(self.lanes) {
            self.sweep(chunk, &mut out);
        }
        out
    }

    /// One full sweep over ≤ `lanes` cases. For a partial chunk every
    /// kernel is bounded by the occupancy `chunk.len()` — trailing lanes
    /// stay at the freshly-reset prior and are never touched or read.
    fn sweep(&mut self, chunk: &[Evidence], out: &mut Vec<Result<Posteriors>>) {
        debug_assert!(chunk.len() <= self.lanes && !chunk.is_empty());
        let lanes = self.lanes;
        let occ = chunk.len();
        // Telemetry only (clock reads + counter bumps): posteriors are
        // byte-identical with observability on or off.
        let sweep_span = trace::span("batched.sweep");
        sweep_span.note(&format!("occ={occ}/{lanes}"));
        obs::global().histogram("fastbn_batched_lane_occupancy").record_value(occ as u64);
        self.state.reset();
        for f in &self.failed {
            f.store(false, Ordering::Relaxed);
        }
        for (b, ev) in chunk.iter().enumerate() {
            ev.apply_lane(&self.jt, self.state.data_mut(), lanes, b);
        }

        // collect
        for li in 0..self.up_plans.len() {
            self.run_layer(true, li, occ);
        }
        // per-lane root normalization (occupied lanes only)
        let mut masses = vec![0.0; occ];
        let mut factors = vec![1.0; occ];
        for root in self.sched.roots.clone() {
            for m in masses.iter_mut() {
                *m = 0.0;
            }
            ops::sum_cases(self.state.clique(root), lanes, &mut masses);
            for b in 0..occ {
                if masses[b] == 0.0 {
                    self.failed[b].store(true, Ordering::Relaxed);
                    factors[b] = 1.0;
                } else {
                    factors[b] = 1.0 / masses[b];
                    self.state.log_z[b] += masses[b].ln();
                }
            }
            ops::scale_cases(self.state.clique_mut(root), lanes, &factors);
        }

        // distribute (downward scale factors must not change ln P(e))
        let z_snapshot = self.state.log_z.clone();
        for li in 0..self.down_plans.len() {
            self.run_layer(false, li, occ);
        }
        self.state.log_z.copy_from_slice(&z_snapshot);

        for b in 0..chunk.len() {
            if self.failed[b].load(Ordering::Relaxed) {
                out.push(Err(Error::InconsistentEvidence));
            } else {
                out.push(Posteriors::compute_lane(&self.jt, self.state.data(), lanes, b, self.state.log_z[b]));
            }
        }
    }

    /// Run one layer: regions A, B (B2 folded where separators fit one
    /// chunk), C — identical task structure to the hybrid engine, with
    /// lane-expanded kernels bounded to the sweep's occupancy `occ`.
    fn run_layer(&mut self, up: bool, li: usize, occ: usize) {
        let plan = if up { &self.up_plans[li] } else { &self.down_plans[li] };
        if plan.msgs.is_empty() {
            return;
        }
        let jt = &self.jt;
        let lanes = self.lanes;
        let sep_total = plan.sep_total;

        // region A: flat lane-expanded marginalization into per-worker
        // partials (lazy-zeroed via generation stamps)
        self.generation += 1;
        let generation = self.generation;
        {
            let shared = SharedTables::for_batch(&mut self.state);
            let partials = &self.partials;
            self.pool.parallel_region("batched.A", plan.marg_tasks.len(), &|w, t| {
                let (mi, ref range) = plan.marg_tasks[t];
                let m = plan.msgs[mi];
                let sep_meta = &jt.seps[m.sep];
                let rm = jt.edge_maps[m.sep].runs_from(sep_meta, m.from);
                // SAFETY: sources are read-only in region A; worker w owns
                // its partial slot.
                let src = unsafe { shared.clique(m.from) };
                let partial = unsafe { partials.get(w) };
                let off = plan.sep_off[mi];
                let slice = &mut partial.buf[off * lanes..(off + sep_meta.len) * lanes];
                if partial.stamps[mi] != generation {
                    partial.stamps[mi] = generation;
                    // full-width zero: one contiguous pass; the reduce
                    // below reads only the occupied lanes anyway
                    ops::zero(slice);
                }
                ops::marg_runs_cases_range(src, rm, lanes, occ, range.clone(), slice);
            });
        }

        // region B1 (+ folded finish): reduce partials per separator-entry
        // chunk; a single-chunk separator finishes in the task tail
        let failed = &self.failed;
        {
            let shared = SharedTables::for_batch(&mut self.state);
            let partials = &self.partials;
            let finish = &self.finish;
            let ratio_buf = ops::as_atomic(&mut self.ratio[..sep_total * lanes]);
            let n_workers = self.threads;
            self.pool.parallel_region("batched.B1", plan.reduce_tasks.len(), &|w, t| {
                let (mi, ref range) = plan.reduce_tasks[t];
                let off = plan.sep_off[mi];
                let lo = (off + range.start) * lanes;
                let len = range.len() * lanes;
                // SAFETY: tasks of one message cover disjoint entry
                // sub-ranges; tasks of different messages are disjoint.
                let slice =
                    unsafe { std::slice::from_raw_parts_mut(ratio_buf.as_ptr().add(lo) as *mut f64, len) };
                // occupied lanes only: zero, then accumulate each worker's
                // partial (stride stays `lanes`, inner loops stop at occ;
                // full occupancy keeps the single contiguous pass)
                if occ == lanes {
                    for x in slice.iter_mut() {
                        *x = 0.0;
                    }
                } else {
                    for e in 0..range.len() {
                        for x in &mut slice[e * lanes..e * lanes + occ] {
                            *x = 0.0;
                        }
                    }
                }
                for wk in 0..n_workers {
                    // SAFETY: region A is complete; partial reads race-free.
                    let partial = unsafe { partials.get(wk) };
                    if partial.stamps[mi] != generation {
                        continue;
                    }
                    let p = &partial.buf[lo..lo + len];
                    if occ == lanes {
                        for (d, &x) in slice.iter_mut().zip(p) {
                            *d += x;
                        }
                    } else {
                        for e in 0..range.len() {
                            let d = &mut slice[e * lanes..e * lanes + occ];
                            let s = &p[e * lanes..e * lanes + occ];
                            for (dv, &sv) in d.iter_mut().zip(s) {
                                *dv += sv;
                            }
                        }
                    }
                }
                if plan.fused[mi] {
                    // SAFETY: this task owns the message's whole lane
                    // window and separator; worker w owns its finish slot
                    // (no other task touches the finish scratch).
                    let scratch = unsafe { finish.get(w) };
                    unsafe {
                        finish_lanes(jt, plan.msgs[mi], off, lanes, occ, ratio_buf, &shared, scratch, failed)
                    };
                }
            });
        }

        // region B2: finish for multi-chunk separators only
        if !plan.b2_msgs.is_empty() {
            let shared = SharedTables::for_batch(&mut self.state);
            let finish = &self.finish;
            let ratio_buf = ops::as_atomic(&mut self.ratio[..sep_total * lanes]);
            self.pool.parallel_region("batched.B2", plan.b2_msgs.len(), &|w, t| {
                let mi = plan.b2_msgs[t];
                // SAFETY: message mi owns its lane window and separator;
                // worker w owns its finish slot.
                let scratch = unsafe { finish.get(w) };
                unsafe {
                    finish_lanes(jt, plan.msgs[mi], plan.sep_off[mi], lanes, occ, ratio_buf, &shared, scratch, failed)
                };
            });
        }
        // fold per-worker per-lane ln-masses into the state
        for fin in self.finish.iter_mut() {
            for b in 0..occ {
                self.state.log_z[b] += fin.log_z[b];
                fin.log_z[b] = 0.0;
            }
        }

        // region C: flat lane-expanded extension grouped by receiver
        {
            let shared = SharedTables::for_batch(&mut self.state);
            let ratio = &self.ratio;
            self.pool.parallel_region("batched.C", plan.ext_tasks.len(), &|_w, t| {
                let (gi, ref range) = plan.ext_tasks[t];
                let (to, ref mis) = plan.groups[gi];
                // SAFETY: groups have distinct receivers; entry ranges of
                // one receiver are disjoint.
                let dst = unsafe { shared.clique_mut(to) };
                for &mi in mis {
                    let m = plan.msgs[mi];
                    let sep_meta = &jt.seps[m.sep];
                    let rm = jt.edge_maps[m.sep].runs_from(sep_meta, m.to);
                    let off = plan.sep_off[mi];
                    let r = &ratio[off * lanes..(off + sep_meta.len) * lanes];
                    ops::extend_runs_cases_range(dst, rm, lanes, occ, range.clone(), r);
                }
            });
        }
    }
}

impl Engine for BatchedHybridEngine {
    fn name(&self) -> &'static str {
        "Fast-BNI-batch"
    }

    /// Single-case inference runs a full sweep with one occupied lane.
    /// `state` is unused — the engine owns its lane arena — but accepted
    /// so the engine is a drop-in `Engine` anywhere (shards, coordinator,
    /// CLI).
    fn infer(&mut self, _state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        self.infer_cases(std::slice::from_ref(ev)).pop().expect("one case in, one result out")
    }

    fn infer_batch(&mut self, _state: &mut TreeState, cases: &[Evidence]) -> Vec<Result<Posteriors>> {
        self.infer_cases(cases)
    }

    /// Batched exact MPE through the engine's own lane arena: `lanes`
    /// cases per upward max sweep via the case-major max kernels
    /// ([`crate::jt::mpe::most_probable_explanation_batch`]). `state` is
    /// unused, as in `infer`/`infer_batch`.
    fn mpe_batch(&mut self, _state: &mut TreeState, cases: &[Evidence]) -> Vec<Result<crate::jt::mpe::MpeResult>> {
        crate::jt::mpe::most_probable_explanation_batch(&self.jt, &self.sched, &mut self.state, cases)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};
    use crate::engine::seq::SeqEngine;
    use crate::jt::triangulate::TriangulationHeuristic;

    fn seq_results(jt: &Arc<JunctionTree>, cases: &[Evidence]) -> Vec<Result<Posteriors>> {
        let mut seq = SeqEngine::new(Arc::clone(jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(jt);
        cases.iter().map(|ev| seq.infer(&mut state, ev)).collect()
    }

    fn assert_agree(jt: &Arc<JunctionTree>, cases: &[Evidence], lanes: usize, threads: usize) {
        let cfg = EngineConfig { threads, min_chunk: 4, batch: lanes, ..Default::default() };
        let mut batched = BatchedHybridEngine::new(Arc::clone(jt), &cfg);
        assert_eq!(batched.lanes(), lanes);
        let got = batched.infer_cases(cases);
        let want = seq_results(jt, cases);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            match (g, w) {
                (Ok(a), Ok(b)) => {
                    assert!(a.max_abs_diff(b) < 1e-9, "case {i}: diff {}", a.max_abs_diff(b));
                }
                (Err(Error::InconsistentEvidence), Err(Error::InconsistentEvidence)) => {}
                other => panic!("case {i}: batched/seq outcome mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn agrees_with_seq_across_lane_counts_including_partial_chunks() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 11, observed_fraction: 0.25, seed: 51 },
        );
        // 11 cases: exercises full sweeps, partial tails, and B=1
        for lanes in [1usize, 3, 4, 16] {
            assert_agree(&jt, &cases, lanes, 4);
        }
    }

    #[test]
    fn agrees_with_seq_on_a_larger_generated_network() {
        let net = netgen::NetSpec {
            name: "batch-test".into(),
            nodes: 60,
            arcs: 85,
            max_parents: 3,
            card_choices: vec![(2, 0.6), (3, 0.25), (4, 0.15)],
            locality: 10,
            max_table: 1 << 10,
            alpha: 1.0,
            seed: 99,
        }
        .generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 9, observed_fraction: 0.2, seed: 53 },
        );
        assert_agree(&jt, &cases, 4, 8);
    }

    #[test]
    fn inconsistent_case_kills_its_lane_only() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let good = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let bad = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let cases = vec![good.clone(), bad, good.clone()];
        let cfg = EngineConfig { threads: 2, batch: 3, ..Default::default() };
        let mut batched = BatchedHybridEngine::new(Arc::clone(&jt), &cfg);
        let out = batched.infer_cases(&cases);
        assert!(out[0].is_ok() && out[2].is_ok());
        assert!(matches!(out[1], Err(Error::InconsistentEvidence)));
        let p = out[0].as_ref().unwrap();
        assert!((p.marginal(&net, "lung").unwrap()[0] - 0.1).abs() < 1e-9);
        assert!((p.evidence_probability() - 0.5).abs() < 1e-9);
        // the engine stays clean for the next batch
        let again = batched.infer_cases(&[good]);
        assert!((again[0].as_ref().unwrap().evidence_probability() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn matches_exact_enumeration_through_the_engine_trait() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let ev = Evidence::from_pairs(&net, &[("dysp", "yes")]).unwrap();
        let exact = crate::infer::exact::enumerate(&net, &ev).unwrap();
        let cfg = EngineConfig { threads: 2, batch: 4, ..Default::default() };
        let mut engine: Box<dyn Engine> = Box::new(BatchedHybridEngine::new(Arc::clone(&jt), &cfg));
        let mut state = TreeState::fresh(&jt);
        let post = engine.infer(&mut state, &ev).unwrap();
        assert!(post.max_abs_diff(&exact) < 1e-9);
        // and via the trait's batch entry point
        let outs = engine.infer_batch(&mut state, &[ev.clone(), Evidence::none()]);
        assert!(outs[0].as_ref().unwrap().max_abs_diff(&exact) < 1e-9);
        assert!(outs[1].as_ref().unwrap().log_z.abs() < 1e-9);
    }

    #[test]
    fn occupancy_grows_cleanly_across_sweeps() {
        // a partial sweep leaves lanes occ..B untouched (stale); the next
        // sweep at higher occupancy must re-zero exactly what it uses —
        // partial → full → lone-infer ordering exercises every transition
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 4, observed_fraction: 0.3, seed: 57 },
        );
        let cfg = EngineConfig { threads: 2, min_chunk: 4, batch: 4, ..Default::default() };
        let mut batched = BatchedHybridEngine::new(Arc::clone(&jt), &cfg);
        let partial = batched.infer_cases(&cases[..2]); // occ = 2
        let full = batched.infer_cases(&cases); // occ = 4
        let mut state = TreeState::fresh(&jt);
        let lone = batched.infer(&mut state, &cases[3]).unwrap(); // occ = 1
        let want = seq_results(&jt, &cases);
        for (i, (g, w)) in partial.iter().zip(&want[..2]).enumerate() {
            assert!(g.as_ref().unwrap().max_abs_diff(w.as_ref().unwrap()) < 1e-9, "partial case {i}");
        }
        for (i, (g, w)) in full.iter().zip(&want).enumerate() {
            assert!(g.as_ref().unwrap().max_abs_diff(w.as_ref().unwrap()) < 1e-9, "full case {i}");
        }
        assert!(lone.max_abs_diff(want[3].as_ref().unwrap()) < 1e-9, "lone infer");
    }

    #[test]
    fn mpe_batch_matches_single_case_mpe_through_the_trait() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = vec![
            Evidence::none(),
            Evidence::from_pairs(&net, &[("xray", "yes")]).unwrap(),
            Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap(), // infeasible
            Evidence::from_pairs(&net, &[("dysp", "yes"), ("smoke", "no")]).unwrap(),
            Evidence::from_pairs(&net, &[("bronc", "no")]).unwrap(),
        ];
        let cfg = EngineConfig { threads: 2, batch: 3, ..Default::default() };
        let mut engine: Box<dyn Engine> = Box::new(BatchedHybridEngine::new(Arc::clone(&jt), &cfg));
        let mut state = TreeState::fresh(&jt);
        let got = engine.mpe_batch(&mut state, &cases); // chunks of 3: full + partial
        let want: Vec<_> = cases.iter().map(|ev| engine.mpe(&mut state, ev)).collect();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            match (g, w) {
                (Ok(g), Ok(w)) => {
                    assert_eq!(g.assignment, w.assignment, "case {i}");
                    assert_eq!(g.log_prob.to_bits(), w.log_prob.to_bits(), "case {i}");
                }
                (Err(_), Err(_)) => {}
                other => panic!("case {i}: batched/single MPE outcome mismatch: {other:?}"),
            }
        }
        // sum-product sweeps stay clean after a max sweep reused the arena
        let post = engine.infer(&mut state, &cases[1]).unwrap();
        let mut seq = SeqEngine::new(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let want_post = seq.infer(&mut state, &cases[1]).unwrap();
        assert!(post.max_abs_diff(&want_post) < 1e-9);
    }

    #[test]
    fn soft_evidence_propagates_per_lane() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let smoke = net.var_id("smoke").unwrap();
        let soft = Evidence::none().with_soft(smoke, vec![4.0, 1.0]).unwrap();
        let cases = vec![soft, Evidence::none()];
        let cfg = EngineConfig { threads: 2, batch: 2, ..Default::default() };
        let mut batched = BatchedHybridEngine::new(Arc::clone(&jt), &cfg);
        let out = batched.infer_cases(&cases);
        let a = out[0].as_ref().unwrap();
        assert!((a.probs[smoke][0] - 0.8).abs() < 1e-9);
        let b = out[1].as_ref().unwrap();
        assert!((b.probs[smoke][0] - 0.5).abs() < 1e-9);
    }
}
