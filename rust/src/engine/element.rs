//! **Element-wise parallelism** — the Zheng '13 (GPU JT) baseline adapted
//! to CPU threads (Table 1 column "Elem.").
//!
//! Like [`crate::engine::primitive::PrimitiveEngine`] this parallelizes
//! inside each message, but in the GPU idiom: one flat element range per
//! message with **atomic scatter-adds** into the separator (the CPU analog
//! of `atomicAdd`), instead of per-worker partials + reduction. Contended
//! atomics on small separators are its characteristic cost.

use std::sync::Arc;

use crate::engine::pool::{chunk_ranges, Pool};
use crate::engine::share::SharedTables;
use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// Element-wise engine (see module docs).
pub struct ElementEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    pool: Pool,
    threads: usize,
    min_chunk: usize,
    max_chunks: usize,
    new_sep: Vec<f64>,
    ratio: Vec<f64>,
}

impl ElementEngine {
    /// Build for a tree.
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let threads = cfg.resolved_threads();
        let pool = Pool::new(threads);
        let max_sep = jt.seps.iter().map(|s| s.len).max().unwrap_or(1);
        ElementEngine {
            jt,
            sched,
            pool,
            threads,
            min_chunk: cfg.min_chunk,
            max_chunks: cfg.max_chunks,
            new_sep: vec![0.0; max_sep],
            ratio: vec![0.0; max_sep],
        }
    }

    fn send(&mut self, state: &mut TreeState, msg: Msg) -> f64 {
        let jt = &self.jt;
        let sep_meta = &jt.seps[msg.sep];
        let sep_len = sep_meta.len;
        let maps = &jt.edge_maps[msg.sep];
        let from_map = maps.from(sep_meta, msg.from);
        let to_map = maps.from(sep_meta, msg.to);

        // element-wise marginalization: atomic scatter into new_sep
        ops::zero(&mut self.new_sep[..sep_len]);
        let src_len = jt.cliques[msg.from].len;
        let chunks = chunk_ranges(src_len, self.min_chunk, self.max_chunks.max(self.threads));
        {
            let slots = ops::as_atomic(&mut self.new_sep[..sep_len]);
            let src = state.clique(msg.from);
            let chunks_ref = &chunks;
            self.pool.parallel(chunks_ref.len(), &|_w, t| {
                ops::atomic_marg_range(src, from_map, chunks_ref[t].clone(), slots);
            });
        }

        // leader: scale + ratio + store
        {
            let new_sep = &mut self.new_sep[..sep_len];
            let mass = ops::sum(new_sep);
            if mass == 0.0 {
                return 0.0;
            }
            ops::scale(new_sep, 1.0 / mass);
            state.log_z += mass.ln();
            let old = state.sep_mut(msg.sep);
            ops::ratio(new_sep, old, &mut self.ratio[..sep_len]);
            old.copy_from_slice(new_sep);
        }

        // element-wise extension
        let dst_len = jt.cliques[msg.to].len;
        let chunks = chunk_ranges(dst_len, self.min_chunk, self.max_chunks.max(self.threads));
        {
            let shared = SharedTables::new(state);
            let ratio = &self.ratio[..sep_len];
            let chunks_ref = &chunks;
            self.pool.parallel(chunks_ref.len(), &|_w, t| {
                // SAFETY: chunks of msg.to are disjoint.
                let dst = unsafe { shared.clique_mut(msg.to) };
                ops::extend_range(dst, to_map, chunks_ref[t].clone(), ratio);
            });
        }
        1.0
    }
}

impl Engine for ElementEngine {
    fn name(&self) -> &'static str {
        "Elem."
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        state.reset(&self.jt);
        ev.apply(&self.jt, state);
        let layers: Vec<Vec<Msg>> = self.sched.up_layers.clone();
        for layer in &layers {
            for &msg in layer {
                if self.send(state, msg) == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        for root in self.sched.roots.clone() {
            let data = state.clique_mut(root);
            let mass = ops::sum(data);
            if mass == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            ops::scale(data, 1.0 / mass);
            state.log_z += mass.ln();
        }
        let z = state.log_z;
        let layers: Vec<Vec<Msg>> = self.sched.down_layers.clone();
        for layer in &layers {
            for &msg in layer {
                if self.send(state, msg) == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        state.log_z = z;
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::engine::seq::SeqEngine;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn agrees_with_seq_on_random_cases() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 4, min_chunk: 4, ..Default::default() };
        let mut elem = ElementEngine::new(Arc::clone(&jt), &cfg);
        let mut seq = SeqEngine::new(Arc::clone(&jt), &cfg);
        let mut s1 = TreeState::fresh(&jt);
        let mut s2 = TreeState::fresh(&jt);
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 10, observed_fraction: 0.25, seed: 31 },
        );
        for (i, ev) in cases.iter().enumerate() {
            let a = elem.infer(&mut s1, ev).unwrap();
            let b = seq.infer(&mut s2, ev).unwrap();
            assert!(a.max_abs_diff(&b) < 1e-9, "case {i}: diff {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn detects_impossible_evidence() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut e = ElementEngine::new(Arc::clone(&jt), &EngineConfig::default().with_threads(2));
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(matches!(e.infer(&mut state, &ev), Err(Error::InconsistentEvidence)));
    }
}
