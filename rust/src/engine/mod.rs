//! The six propagation engines of the paper's Table 1.
//!
//! | Engine | Paper column | Strategy |
//! |---|---|---|
//! | [`unb::UnbEngine`] | UnBBayes | sequential, naive: per-entry div/mod index mapping recomputed per message, per-message allocation |
//! | [`seq::SeqEngine`] | Fast-BNI-seq | sequential, cached index maps, zero per-case allocation |
//! | [`direct::DirectEngine`] | Dir. (Kozlov & Singh '94) | coarse inter-clique: one task per receiving clique per layer |
//! | [`primitive::PrimitiveEngine`] | Prim. (Xia & Prasanna '07) | fine intra-clique: each table operation is its own parallel region |
//! | [`element::ElementEngine`] | Elem. (Zheng '13) | fine element-wise: GPU-style atomic scatter per message |
//! | [`hybrid::HybridEngine`] | Fast-BNI-par | **the contribution**: per layer, all table entries of all messages flattened into one task pool |
//!
//! All engines share the substrate (tree, maps, kernels) so measured
//! differences isolate the parallelization strategy, mirroring the
//! paper's comparison.

pub mod approx;
pub mod batched;
pub mod direct;
pub mod element;
pub mod hybrid;
pub mod pool;
pub mod primitive;
pub mod seq;
pub mod share;
pub mod simulate;
pub mod unb;

use std::sync::Arc;

use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::mpe::MpeResult;
use crate::jt::propagate::MapMode;
use crate::jt::schedule::{RootStrategy, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::Result;

/// A calibrated-inference engine: given evidence, produce all posteriors.
///
/// Not `Send`: the XLA-backed engine holds PJRT handles that are
/// thread-affine. Multi-threaded consumers (the batch coordinator, the
/// server) construct one engine *inside* each worker thread instead of
/// moving engines across threads.
pub trait Engine {
    /// Engine name as used in reports (matches Table 1 labels).
    fn name(&self) -> &'static str;

    /// Run one case: reset `state`, absorb `ev`, calibrate, extract
    /// posteriors. `state` must come from the same tree the engine was
    /// built for.
    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors>;

    /// Run many cases, returning one result per case in order. A failing
    /// case (inconsistent evidence) yields `Err` for its slot only.
    ///
    /// Default: a plain loop over [`Engine::infer`] reusing `state`. The
    /// batched engine overrides this with fused multi-case sweeps
    /// ([`batched::BatchedHybridEngine`]); callers that batch (the fleet's
    /// `BATCH` verb, the coordinator's fused mode) always go through this
    /// entry point so any engine slots in.
    fn infer_batch(&mut self, state: &mut TreeState, cases: &[Evidence]) -> Vec<Result<Posteriors>> {
        cases.iter().map(|ev| self.infer(state, ev)).collect()
    }

    /// Exact MPE (max-product) for one case: reset `state`, absorb `ev`,
    /// run the upward max-pass, decode the jointly most probable
    /// assignment.
    ///
    /// Default: [`crate::jt::mpe::most_probable_explanation`] over the
    /// engine's compiled tree and schedule. Engines without one (the
    /// sampling tier reports `schedule() == None`) return `Err` — MPE is
    /// an exact-tier query with no approximate fallback.
    fn mpe(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<MpeResult> {
        match (self.tree(), self.schedule()) {
            (Some(jt), Some(sched)) => crate::jt::mpe::most_probable_explanation(jt, sched, state, ev),
            _ => Err(crate::Error::msg("MPE requires a compiled junction tree (exact tier)")),
        }
    }

    /// Exact MPE for many cases, one result per case in order; a failing
    /// case yields `Err` for its slot only.
    ///
    /// Default: a plain loop over [`Engine::mpe`] reusing `state`. The
    /// batched engine overrides this with lane-parallel max sweeps
    /// ([`batched::BatchedHybridEngine`]).
    fn mpe_batch(&mut self, state: &mut TreeState, cases: &[Evidence]) -> Vec<Result<MpeResult>> {
        cases.iter().map(|ev| self.mpe(state, ev)).collect()
    }

    /// The traversal schedule in use (for layer-count reporting). `None`
    /// for the sampling tier, which has no message-passing schedule.
    fn schedule(&self) -> Option<&Schedule>;

    /// The compiled tree this engine runs on. `None` for the sampling
    /// tier when it was built straight from a network (the cost-based
    /// fallback path never compiles a tree).
    fn tree(&self) -> Option<&Arc<JunctionTree>>;
}

/// Engine-construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads (including the calling thread). 0 = all cores.
    pub threads: usize,
    /// Root selection (paper default: tree center).
    pub root_strategy: RootStrategy,
    /// Index-mapping strategy for the sequential engine (ablation knob).
    pub map_mode: MapMode,
    /// Minimum table entries per flattened task (hybrid/primitive);
    /// balances stealing overhead against load balance.
    pub min_chunk: usize,
    /// Maximum chunks a single table is split into.
    pub max_chunks: usize,
    /// Cases per sweep (lanes) for the batched engine; other engines
    /// ignore it. 1 = unbatched.
    pub batch: usize,
    /// Likelihood-weighting samples per case for the approximate engine
    /// ([`approx::ApproxEngine`]); exact engines ignore it.
    pub samples: usize,
    /// Target 95% CI half-width for the approximate engine: when > 0,
    /// sampling continues past `samples` (in deterministic chunk rounds,
    /// up to a fixed budget multiple) until the worst-case reported
    /// half-width drops below this. 0 = fixed sample count.
    pub target_half_width: f64,
    /// Base seed for the approximate engine's per-chunk sub-streams.
    /// The same seed yields bit-identical posteriors at any thread count.
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: 0,
            root_strategy: RootStrategy::Center,
            map_mode: MapMode::Cached,
            min_chunk: 1 << 11,
            max_chunks: 256,
            batch: 1,
            samples: 100_000,
            target_half_width: 0.0,
            seed: 0x5EED_CAFE,
        }
    }
}

impl EngineConfig {
    /// Resolved thread count (0 → available parallelism).
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// Copy with a specific thread count.
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    /// Copy with a specific lane count (cases per batched sweep).
    pub fn with_batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    /// Copy with a specific likelihood-weighting sample count.
    pub fn with_samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Copy with a specific approximate-engine base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// The engine selector (Table 1 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// UnBBayes-style naive sequential baseline.
    Unb,
    /// Fast-BNI-seq.
    Seq,
    /// Direct inter-clique parallelism (Kozlov & Singh).
    Direct,
    /// Node-level primitives (Xia & Prasanna).
    Primitive,
    /// Element-wise parallelism (Zheng).
    Element,
    /// Fast-BNI-par hybrid parallelism (the paper's contribution).
    Hybrid,
    /// Case-major batched hybrid: `EngineConfig::batch` cases per sweep
    /// (an extension beyond the poster — the Fast-PGM throughput
    /// direction; not a Table-1 column, so not in [`EngineKind::ALL`]).
    Batched,
    /// Pool-parallel likelihood weighting ([`approx::ApproxEngine`]) —
    /// the approximate tier for networks whose junction-tree cost makes
    /// exact compilation infeasible. Not a Table-1 column, so not in
    /// [`EngineKind::ALL`].
    Approx,
}

impl EngineKind {
    /// All kinds in Table-1 column order.
    pub const ALL: [EngineKind; 6] = [
        EngineKind::Unb,
        EngineKind::Seq,
        EngineKind::Direct,
        EngineKind::Primitive,
        EngineKind::Element,
        EngineKind::Hybrid,
    ];

    /// The parallel kinds compared in the "Parallel implementation" half
    /// of Table 1.
    pub const PARALLEL: [EngineKind; 4] =
        [EngineKind::Direct, EngineKind::Primitive, EngineKind::Element, EngineKind::Hybrid];

    /// Construct the engine.
    pub fn build(&self, jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Box<dyn Engine> {
        match self {
            EngineKind::Unb => Box::new(unb::UnbEngine::new(jt, cfg)),
            EngineKind::Seq => Box::new(seq::SeqEngine::new(jt, cfg)),
            EngineKind::Direct => Box::new(direct::DirectEngine::new(jt, cfg)),
            EngineKind::Primitive => Box::new(primitive::PrimitiveEngine::new(jt, cfg)),
            EngineKind::Element => Box::new(element::ElementEngine::new(jt, cfg)),
            EngineKind::Hybrid => Box::new(hybrid::HybridEngine::new(jt, cfg)),
            EngineKind::Batched => Box::new(batched::BatchedHybridEngine::new(jt, cfg)),
            EngineKind::Approx => Box::new(approx::ApproxEngine::from_tree(jt, cfg)),
        }
    }

    /// Paper label.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Unb => "UnBBayes",
            EngineKind::Seq => "Fast-BNI-seq",
            EngineKind::Direct => "Dir.",
            EngineKind::Primitive => "Prim.",
            EngineKind::Element => "Elem.",
            EngineKind::Hybrid => "Fast-BNI-par",
            EngineKind::Batched => "Fast-BNI-batch",
            EngineKind::Approx => "Approx-LW",
        }
    }
}

impl std::str::FromStr for EngineKind {
    type Err = crate::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "unb" | "unbbayes" => Ok(EngineKind::Unb),
            "seq" | "fast-bni-seq" => Ok(EngineKind::Seq),
            "direct" | "dir" => Ok(EngineKind::Direct),
            "primitive" | "prim" => Ok(EngineKind::Primitive),
            "element" | "elem" => Ok(EngineKind::Element),
            "hybrid" | "par" | "fast-bni-par" => Ok(EngineKind::Hybrid),
            "batched" | "batch" | "fast-bni-batch" => Ok(EngineKind::Batched),
            "approx" | "lw" | "sampling" | "approx-lw" => Ok(EngineKind::Approx),
            other => Err(crate::Error::msg(format!("unknown engine {other:?}"))),
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn kind_parsing_and_labels() {
        assert_eq!("hybrid".parse::<EngineKind>().unwrap(), EngineKind::Hybrid);
        assert_eq!("Prim".parse::<EngineKind>().unwrap(), EngineKind::Primitive);
        assert_eq!("batched".parse::<EngineKind>().unwrap(), EngineKind::Batched);
        assert_eq!("approx".parse::<EngineKind>().unwrap(), EngineKind::Approx);
        assert_eq!("lw".parse::<EngineKind>().unwrap(), EngineKind::Approx);
        assert!("warp".parse::<EngineKind>().is_err());
        assert_eq!(EngineKind::Hybrid.label(), "Fast-BNI-par");
        assert_eq!(EngineKind::Batched.label(), "Fast-BNI-batch");
        assert_eq!(EngineKind::Approx.label(), "Approx-LW");
        assert_eq!(format!("{}", EngineKind::Unb), "UnBBayes");
        // Batched and Approx are extensions, not Table-1 columns
        assert!(!EngineKind::ALL.contains(&EngineKind::Batched));
        assert!(!EngineKind::ALL.contains(&EngineKind::Approx));
    }

    #[test]
    fn default_infer_batch_loops_infer_and_isolates_failures() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut engine = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(&jt);
        let good = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let bad = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let outs = engine.infer_batch(&mut state, &[good.clone(), bad, good]);
        assert!(outs[0].is_ok() && outs[2].is_ok());
        assert!(outs[1].is_err());
        assert!((outs[0].as_ref().unwrap().evidence_probability() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn batched_kind_builds_through_the_selector() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 2, ..Default::default() }.with_batch(4);
        let mut engine = EngineKind::Batched.build(Arc::clone(&jt), &cfg);
        assert_eq!(engine.name(), "Fast-BNI-batch");
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let post = engine.infer(&mut state, &ev).unwrap();
        assert!((post.marginal(&net, "lung").unwrap()[0] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn all_kinds_build_and_infer() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { threads: 2, ..Default::default() };
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        for kind in EngineKind::ALL {
            let mut engine = kind.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            let post = engine.infer(&mut state, &ev).unwrap();
            let lung = post.marginal(&net, "lung").unwrap();
            assert!((lung[0] - 0.1).abs() < 1e-9, "{kind}: P(lung|smoke)={}", lung[0]);
            assert!((post.evidence_probability() - 0.5).abs() < 1e-9, "{kind}");
        }
    }

    #[test]
    fn default_mpe_runs_on_exact_engines_and_rejects_the_sampling_tier() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let ev = Evidence::from_pairs(&net, &[("xray", "yes")]).unwrap();
        // exact engine: the trait default delegates to jt::mpe
        let mut seq = EngineKind::Seq.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        let mut state = TreeState::fresh(&jt);
        let got = seq.mpe(&mut state, &ev).unwrap();
        let sched = Schedule::build(&jt, RootStrategy::Center);
        let want = crate::jt::mpe::most_probable_explanation(&jt, &sched, &mut state, &ev).unwrap();
        assert_eq!(got.assignment, want.assignment);
        assert_eq!(got.log_prob.to_bits(), want.log_prob.to_bits());
        // batch default loops mpe and isolates the failing slot
        let bad = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let outs = seq.mpe_batch(&mut state, &[ev.clone(), bad, ev.clone()]);
        assert!(outs[0].is_ok() && outs[2].is_ok());
        assert!(outs[1].is_err());
        // the sampling tier has no schedule: MPE is refused, not approximated
        let mut approx = EngineKind::Approx.build(Arc::clone(&jt), &EngineConfig::default().with_threads(1));
        assert!(approx.mpe(&mut state, &ev).is_err());
    }

    #[test]
    fn config_thread_resolution() {
        let c = EngineConfig::default();
        assert!(c.resolved_threads() >= 1);
        assert_eq!(c.with_threads(3).resolved_threads(), 3);
    }
}
