//! **Node-level primitives** — the Xia & Prasanna '07 baseline (Table 1
//! column "Prim.").
//!
//! Messages are processed one at a time (sequentially), but each potential
//! table *operation* is parallelized as its own primitive: a parallel
//! marginalization (entry chunks scattering into per-worker partial
//! buffers, then a reduction), followed by a parallel extension. Every
//! message therefore pays two parallel-region entries plus a partial-buffer
//! zeroing — the "large parallelization overhead since the table
//! operations are invoked frequently" the paper criticizes, and the effect
//! `benches/table1.rs` shows on trees with many small cliques.

use std::sync::Arc;

use crate::engine::pool::{chunk_ranges, Pool};
use crate::engine::share::{PerWorker, SharedTables};
use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// Node-level-primitive engine (see module docs).
pub struct PrimitiveEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
    pool: Pool,
    threads: usize,
    min_chunk: usize,
    max_chunks: usize,
    /// Per-worker partial separator buffers (max sep len each).
    partials: PerWorker<Vec<f64>>,
    /// Leader buffers for the reduced message and the update ratio.
    new_sep: Vec<f64>,
    ratio: Vec<f64>,
}

impl PrimitiveEngine {
    /// Build for a tree.
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        let threads = cfg.resolved_threads();
        let pool = Pool::new(threads);
        let max_sep = jt.seps.iter().map(|s| s.len).max().unwrap_or(1);
        let partials = PerWorker::new(threads, |_| vec![0.0; max_sep]);
        PrimitiveEngine {
            jt,
            sched,
            pool,
            threads,
            min_chunk: cfg.min_chunk,
            max_chunks: cfg.max_chunks,
            partials,
            new_sep: vec![0.0; max_sep],
            ratio: vec![0.0; max_sep],
        }
    }

    /// One message with per-operation parallel primitives.
    fn send(&mut self, state: &mut TreeState, msg: Msg) -> f64 {
        let jt = &self.jt;
        let sep_meta = &jt.seps[msg.sep];
        let sep_len = sep_meta.len;
        let maps = &jt.edge_maps[msg.sep];
        let from_map = maps.from(sep_meta, msg.from);
        let to_map = maps.from(sep_meta, msg.to);

        // primitive 1: parallel marginalization into per-worker partials
        for p in self.partials.iter_mut() {
            ops::zero(&mut p[..sep_len]);
        }
        let src_len = jt.cliques[msg.from].len;
        let chunks = chunk_ranges(src_len, self.min_chunk, self.max_chunks.max(self.threads));
        {
            let src = state.clique(msg.from);
            let partials = &self.partials;
            let chunks_ref = &chunks;
            self.pool.parallel(chunks_ref.len(), &|w, t| {
                // SAFETY: worker w owns its partial slot.
                let partial = unsafe { partials.get(w) };
                ops::marg_range(src, from_map, chunks_ref[t].clone(), &mut partial[..sep_len]);
            });
        }

        // primitive 2 (leader): reduce partials, scale, ratio
        {
            let new_sep = &mut self.new_sep[..sep_len];
            ops::zero(new_sep);
            for p in self.partials.iter_mut() {
                for (d, &x) in new_sep.iter_mut().zip(&p[..sep_len]) {
                    *d += x;
                }
            }
            let mass = ops::sum(new_sep);
            if mass == 0.0 {
                return 0.0;
            }
            ops::scale(new_sep, 1.0 / mass);
            state.log_z += mass.ln();
            let old = state.sep_mut(msg.sep);
            ops::ratio(new_sep, old, &mut self.ratio[..sep_len]);
            old.copy_from_slice(new_sep);
        }

        // primitive 3: parallel extension of the receiving clique
        let dst_len = jt.cliques[msg.to].len;
        let chunks = chunk_ranges(dst_len, self.min_chunk, self.max_chunks.max(self.threads));
        {
            let shared = SharedTables::new(state);
            let ratio = &self.ratio[..sep_len];
            let chunks_ref = &chunks;
            self.pool.parallel(chunks_ref.len(), &|_w, t| {
                // SAFETY: chunks of msg.to are disjoint.
                let dst = unsafe { shared.clique_mut(msg.to) };
                ops::extend_range(dst, to_map, chunks_ref[t].clone(), ratio);
            });
        }
        1.0
    }
}

impl Engine for PrimitiveEngine {
    fn name(&self) -> &'static str {
        "Prim."
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        state.reset(&self.jt);
        ev.apply(&self.jt, state);
        let layers: Vec<Vec<Msg>> = self.sched.up_layers.clone();
        for layer in &layers {
            for &msg in layer {
                if self.send(state, msg) == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        for root in self.sched.roots.clone() {
            let data = state.clique_mut(root);
            let mass = ops::sum(data);
            if mass == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            ops::scale(data, 1.0 / mass);
            state.log_z += mass.ln();
        }
        let z = state.log_z;
        let layers: Vec<Vec<Msg>> = self.sched.down_layers.clone();
        for layer in &layers {
            for &msg in layer {
                if self.send(state, msg) == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        state.log_z = z;
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::engine::seq::SeqEngine;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn agrees_with_seq_on_random_cases() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        // tiny chunks force real multi-chunk parallelism on small tables
        let cfg = EngineConfig { threads: 4, min_chunk: 4, ..Default::default() };
        let mut prim = PrimitiveEngine::new(Arc::clone(&jt), &cfg);
        let mut seq = SeqEngine::new(Arc::clone(&jt), &cfg);
        let mut s1 = TreeState::fresh(&jt);
        let mut s2 = TreeState::fresh(&jt);
        let cases = crate::infer::cases::generate(
            &net,
            &crate::infer::cases::CaseSpec { n_cases: 10, observed_fraction: 0.25, seed: 21 },
        );
        for (i, ev) in cases.iter().enumerate() {
            let a = prim.infer(&mut s1, ev).unwrap();
            let b = seq.infer(&mut s2, ev).unwrap();
            assert!(a.max_abs_diff(&b) < 1e-9, "case {i}: diff {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn detects_impossible_evidence() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut e = PrimitiveEngine::new(Arc::clone(&jt), &EngineConfig::default().with_threads(2));
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        assert!(matches!(e.infer(&mut state, &ev), Err(Error::InconsistentEvidence)));
    }
}
