//! Calibrated critical-path cost model for the parallel engines.
//!
//! **Why this exists**: this container exposes a single CPU core, so the
//! paper's multi-core speedups cannot be *measured* here (DESIGN.md §3).
//! What CAN be reproduced faithfully is the quantity Table 1 actually
//! compares — how each scheduling strategy turns the same table-operation
//! work into parallel wall time:
//!
//! * **Direct** — one task per receiving clique per layer: a layer costs
//!   its *makespan* over whole-clique tasks → load imbalance.
//! * **Primitive / Element** — parallel regions per *message* (plus
//!   per-worker-buffer zeroing / atomic scatter) → invocation overhead on
//!   trees with many small cliques.
//! * **Hybrid** — three regions per *layer* over flattened entry chunks →
//!   balanced makespans and far fewer region entries.
//!
//! The model replays each engine's **real schedule** (the same layers,
//! groups and chunk lists the live engines execute) through a greedy
//! dynamic-queue worker assignment, using per-entry and per-region costs
//! **measured on this machine** ([`CostModel::calibrate`]). At `t = 1`
//! the model must agree with measured sequential execution (validated in
//! `benches/table1.rs` and reported in EXPERIMENTS.md).

use std::sync::Arc;
use std::time::Instant;

use crate::engine::pool::{chunk_ranges, Pool};
use crate::engine::{EngineConfig, EngineKind};
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::tree::JunctionTree;
use crate::rng::Rng;

/// Machine cost constants (nanoseconds), measured by [`CostModel::calibrate`].
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Map-based marginalization, per source entry.
    pub marg_ns: f64,
    /// Map-based extension, per destination entry.
    pub extend_ns: f64,
    /// Run-kernel marginalization: per-entry cost `b + c / run_len`
    /// (the Fast-BNI hot path; fitted from two measured run lengths).
    pub marg_run_b: f64,
    /// Per-run overhead numerator of the run marginalization cost.
    pub marg_run_c: f64,
    /// Run-kernel extension per-entry base cost.
    pub extend_run_b: f64,
    /// Per-run overhead numerator of the run extension cost.
    pub extend_run_c: f64,
    /// Multiplier for per-entry div/mod index projection (naive baseline).
    pub divmod_factor: f64,
    /// Multiplier for atomic CAS scatter vs plain marginalization.
    pub atomic_factor: f64,
    /// Separator bookkeeping (reduce/ratio/copy), per separator entry.
    pub sep_ns: f64,
    /// Zeroing, per entry (partial buffers).
    pub zero_ns: f64,
    /// One heap allocation (naive baseline's per-message buffers).
    pub alloc_ns: f64,
    /// Entering + leaving one parallel region (publish, wake, join).
    pub region_ns: f64,
    /// Claiming one task from the shared queue (fetch_add + dispatch).
    pub task_ns: f64,
}

impl CostModel {
    /// Measure the constants on the current machine. Takes ~1 s.
    ///
    /// Streaming kernels (marg/extend/run variants, zeroing) are measured
    /// on a 32 MiB buffer so the constants reflect memory-bound reality
    /// (real clique tables exceed cache); compute-bound *ratios*
    /// (div/mod, atomic CAS) are measured cache-hot, which is where those
    /// overheads actually differ.
    pub fn calibrate() -> CostModel {
        let mut rng = Rng::new(0xCAFE);
        let n = 1 << 22; // 32 MiB of f64 — beyond LLC
        let src: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let sep_len = 64usize;
        let map: Vec<u32> = (0..n).map(|i| ((i >> 6) % sep_len) as u32).collect();
        let mut dst = vec![0.0f64; sep_len];

        // one warmup, then timed (a fn item so each call site gets its own
        // borrow lifetime for the boxed closure)
        fn time_per(iters: usize, mut f: Box<dyn FnMut() + '_>) -> f64 {
            f();
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_nanos() as f64 / iters as f64
        }

        let marg_total = {
            let src = &src;
            let map = &map;
            let dst = &mut dst;
            time_per(8, Box::new(move || {
                ops::zero(dst);
                ops::marg_with_map(src, map, dst);
            }))
        };
        let marg_ns = marg_total / n as f64;

        let mut table: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let ratio: Vec<f64> = (0..sep_len).map(|_| 0.5 + rng.f64()).collect();
        let extend_total = {
            let map = &map;
            let ratio = &ratio;
            let table = &mut table;
            time_per(8, Box::new(move || ops::extend_with_map(table, map, ratio)))
        };
        let extend_ns = extend_total / n as f64;

        // run-kernel costs at two run lengths -> fit per-entry = b + c/L
        let fit = |t_lo: f64, l_lo: f64, t_hi: f64, l_hi: f64| -> (f64, f64) {
            // t = b + c / L  at the two measured points
            let c = (t_lo - t_hi) / (1.0 / l_lo - 1.0 / l_hi);
            let b = (t_hi - c / l_hi).max(0.01);
            (b, c.max(0.0))
        };
        let run_measure = |l: usize, rng: &mut Rng| -> (f64, f64) {
            let n_runs = n / l;
            let rm = crate::jt::mapping::RunMap {
                map: (0..n_runs).map(|r| (r % sep_len) as u32).collect(),
                run_len: l,
            };
            let src: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let mut dst = vec![0.0f64; sep_len];
            let t_marg = {
                let src = &src;
                let rm = &rm;
                let dst = &mut dst;
                // local timing loop (same protocol as time_per)
                let mut f = move || {
                    ops::zero(dst);
                    ops::marg_runs(src, rm, dst);
                };
                f();
                let t0 = Instant::now();
                for _ in 0..5 {
                    f();
                }
                t0.elapsed().as_nanos() as f64 / 5.0
            };
            let mut tbl: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let ratio: Vec<f64> = (0..sep_len).map(|_| 0.5 + rng.f64()).collect();
            let t_ext = {
                let rm = &rm;
                let ratio = &ratio;
                let tbl = &mut tbl;
                let mut f = move || ops::extend_runs(tbl, rm, ratio);
                f();
                let t0 = Instant::now();
                for _ in 0..5 {
                    f();
                }
                t0.elapsed().as_nanos() as f64 / 5.0
            };
            (t_marg / n as f64, t_ext / n as f64)
        };
        let (marg_lo, ext_lo) = run_measure(4, &mut rng);
        let (marg_hi, ext_hi) = run_measure(256, &mut rng);
        let (marg_run_b, marg_run_c) = fit(marg_lo, 4.0, marg_hi, 256.0);
        let (extend_run_b, extend_run_c) = fit(ext_lo, 4.0, ext_hi, 256.0);

        // div/mod factor: same op via divmod projection onto the first two
        // axes (dst size 16*16 = 256)
        let cards = vec![16usize, 16, 16, 16]; // 65536 entries
        let strides = crate::jt::mapping::strides(&cards);
        let proj = vec![16usize, 1, 0, 0];
        let mut dst256 = vec![0.0f64; 256];
        let divmod_total = {
            let src = &src;
            let dst = &mut dst256;
            let cards = &cards;
            let strides = &strides;
            let proj = &proj;
            time_per(3, Box::new(move || {
                ops::zero(dst);
                ops::marg_divmod(src, cards, strides, proj, dst);
            }))
        };
        let divmod_factor = (divmod_total / n as f64 / marg_ns).max(1.0);

        // atomic factor
        let mut adst = vec![0.0f64; sep_len];
        let atomic_total = {
            let src = &src;
            let map = &map;
            let adst = &mut adst;
            time_per(3, Box::new(move || {
                ops::zero(adst);
                let slots = ops::as_atomic(adst);
                ops::atomic_marg_range(src, map, 0..src.len(), slots);
            }))
        };
        let atomic_factor = (atomic_total / n as f64 / marg_ns).max(1.0);

        // sep bookkeeping: ratio + copy on a sep-sized buffer
        let new_sep: Vec<f64> = (0..4096).map(|_| rng.f64()).collect();
        let mut old_sep: Vec<f64> = (0..4096).map(|_| rng.f64() + 0.1).collect();
        let mut ratio_buf = vec![0.0f64; 4096];
        let sep_total = {
            let new_sep = &new_sep;
            let old_sep = &mut old_sep;
            let ratio_buf = &mut ratio_buf;
            time_per(50, Box::new(move || {
                ops::ratio(new_sep, old_sep, ratio_buf);
                old_sep.copy_from_slice(new_sep);
            }))
        };
        let sep_ns = sep_total / 4096.0;

        let mut zbuf = vec![1.0f64; 1 << 22];
        let zero_total = {
            let zbuf = &mut zbuf;
            time_per(8, Box::new(move || ops::zero(zbuf)))
        };
        let zero_ns = zero_total / (1 << 22) as f64;

        let alloc_ns = time_per(200, Box::new(|| {
            let v: Vec<f64> = vec![0.0; 512];
            std::hint::black_box(&v);
        }));

        // parallel region + task costs with a 4-thread pool (thread count
        // does not change publish/join cost materially on one core)
        // n_tasks = 2 so the single-task inline fast path is not taken
        let pool = Pool::new(4);
        let region_ns = time_per(50, Box::new(|| pool.parallel(2, &|_w, _t| {}))).max(200.0);
        let region_64 = time_per(50, Box::new(|| pool.parallel(64, &|_w, _t| {})));
        let task_ns = ((region_64 - region_ns) / 62.0).max(5.0);

        CostModel {
            marg_ns,
            extend_ns,
            marg_run_b,
            marg_run_c,
            extend_run_b,
            extend_run_c,
            divmod_factor,
            atomic_factor,
            sep_ns,
            zero_ns,
            alloc_ns,
            region_ns,
            task_ns,
        }
    }

    /// Per-entry marginalization cost of the run kernel at run length `l`.
    #[inline]
    pub fn marg_run_ns(&self, l: f64) -> f64 {
        self.marg_run_b + self.marg_run_c / l.max(1.0)
    }

    /// Per-entry extension cost of the run kernel at run length `l`.
    #[inline]
    pub fn extend_run_ns(&self, l: f64) -> f64 {
        self.extend_run_b + self.extend_run_c / l.max(1.0)
    }
}

/// Greedy list scheduling: assign tasks in order to the least-loaded of
/// `t` workers (the steady-state behaviour of a dynamic task queue);
/// returns the makespan.
pub fn makespan(tasks: &[f64], t: usize) -> f64 {
    let t = t.max(1);
    if tasks.is_empty() {
        return 0.0;
    }
    let mut load = vec![0.0f64; t];
    for &c in tasks {
        let (i, _) = load.iter().enumerate().min_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap();
        load[i] += c;
    }
    load.iter().cloned().fold(0.0, f64::max)
}

/// Modeled nanoseconds for one inference case (collect + distribute).
pub fn simulate_case(
    kind: EngineKind,
    jt: &JunctionTree,
    sched: &Schedule,
    threads: usize,
    cfg: &EngineConfig,
    model: &CostModel,
) -> f64 {
    let msg_cost = |m: &Msg, factor: f64| -> f64 {
        let from = jt.cliques[m.from].len as f64;
        let to = jt.cliques[m.to].len as f64;
        let sep = jt.seps[m.sep].len as f64;
        from * model.marg_ns * factor + sep * model.sep_ns + to * model.extend_ns * factor
    };
    // Fast-BNI engines use the run-compressed kernels: per-entry cost
    // depends on the edge's run length.
    let run_len = |clique: usize, sep: usize| -> f64 {
        jt.edge_maps[sep].runs_from(&jt.seps[sep], clique).run_len as f64
    };
    let msg_cost_runs = |m: &Msg| -> f64 {
        let from = jt.cliques[m.from].len as f64;
        let to = jt.cliques[m.to].len as f64;
        let sep = jt.seps[m.sep].len as f64;
        from * model.marg_run_ns(run_len(m.from, m.sep))
            + sep * model.sep_ns
            + to * model.extend_run_ns(run_len(m.to, m.sep))
    };
    let layers: Vec<&Vec<Msg>> = sched.up_layers.iter().chain(sched.down_layers.iter()).collect();

    match kind {
        EngineKind::Seq => layers.iter().flat_map(|l| l.iter()).map(msg_cost_runs).sum(),
        EngineKind::Unb => layers
            .iter()
            .flat_map(|l| l.iter())
            .map(|m| msg_cost(m, model.divmod_factor) + 2.0 * model.alloc_ns)
            .sum(),
        EngineKind::Direct => {
            let mut total = 0.0;
            for (li, layer) in layers.iter().enumerate() {
                let up = li < sched.up_layers.len();
                let tasks: Vec<f64> = if up {
                    // group by receiving parent
                    let mut by_to: std::collections::BTreeMap<usize, f64> = Default::default();
                    for m in layer.iter() {
                        *by_to.entry(m.to).or_default() += msg_cost(m, 1.0);
                    }
                    by_to.into_values().map(|c| c + model.task_ns).collect()
                } else {
                    layer.iter().map(|m| msg_cost(m, 1.0) + model.task_ns).collect()
                };
                total += makespan(&tasks, threads) + model.region_ns;
            }
            total
        }
        EngineKind::Primitive => {
            let mut total = 0.0;
            for layer in &layers {
                for m in layer.iter() {
                    let sep = jt.seps[m.sep].len as f64;
                    // zero per-worker partials + parallel marg region
                    total += threads as f64 * sep * model.zero_ns;
                    let chunks: Vec<f64> = chunk_ranges(jt.cliques[m.from].len, cfg.min_chunk, cfg.max_chunks)
                        .into_iter()
                        .map(|r| r.len() as f64 * model.marg_ns + model.task_ns)
                        .collect();
                    total += makespan(&chunks, threads) + model.region_ns;
                    // leader reduce + ratio
                    total += threads as f64 * sep * model.sep_ns;
                    // parallel extend region
                    let chunks: Vec<f64> = chunk_ranges(jt.cliques[m.to].len, cfg.min_chunk, cfg.max_chunks)
                        .into_iter()
                        .map(|r| r.len() as f64 * model.extend_ns + model.task_ns)
                        .collect();
                    total += makespan(&chunks, threads) + model.region_ns;
                }
            }
            total
        }
        EngineKind::Element => {
            let mut total = 0.0;
            for layer in &layers {
                for m in layer.iter() {
                    let sep = jt.seps[m.sep].len as f64;
                    // atomic scatter region (zero once, no partials)
                    total += sep * model.zero_ns;
                    let chunks: Vec<f64> = chunk_ranges(jt.cliques[m.from].len, cfg.min_chunk, cfg.max_chunks)
                        .into_iter()
                        .map(|r| r.len() as f64 * model.marg_ns * model.atomic_factor + model.task_ns)
                        .collect();
                    total += makespan(&chunks, threads) + model.region_ns;
                    total += sep * model.sep_ns; // leader finish
                    let chunks: Vec<f64> = chunk_ranges(jt.cliques[m.to].len, cfg.min_chunk, cfg.max_chunks)
                        .into_iter()
                        .map(|r| r.len() as f64 * model.extend_ns + model.task_ns)
                        .collect();
                    total += makespan(&chunks, threads) + model.region_ns;
                }
            }
            total
        }
        // The batched engine runs the same plans with lane-expanded
        // kernels; per-case modeled time is the hybrid cost (the model
        // does not capture the cross-case map-lookup amortization —
        // benches/batch.rs measures that for real).
        EngineKind::Hybrid | EngineKind::Batched => {
            let mut total = 0.0;
            for layer in layers.iter() {
                if layer.is_empty() {
                    continue;
                }
                // region A: flat run-kernel marg chunks over every source;
                // lazy zeroing (generation stamps) charges one sep-slice
                // zero per worker that touches a message, inside the task
                let mut a_tasks = Vec::new();
                let mut touched: Vec<usize> = Vec::with_capacity(layer.len());
                for m in layer.iter() {
                    let chunks = chunk_ranges(jt.cliques[m.from].len, cfg.min_chunk, cfg.max_chunks);
                    let n_chunks = chunks.len();
                    touched.push(n_chunks.min(threads));
                    let l = run_len(m.from, m.sep);
                    let sep = jt.seps[m.sep].len as f64;
                    for (i, r) in chunks.into_iter().enumerate() {
                        let zero = if i < n_chunks.min(threads) { sep * model.zero_ns } else { 0.0 };
                        a_tasks.push(r.len() as f64 * model.marg_run_ns(l) + model.task_ns + zero);
                    }
                }
                total += makespan(&a_tasks, threads) + model.region_ns;
                // region B1: flat partial reduction (sep-entry chunks × the
                // workers that actually touched the message); a message
                // whose separator fits one chunk runs the B2 finish in
                // that task's tail (the fold — see engine/hybrid.rs)
                let mut b1_tasks = Vec::new();
                let mut b2_tasks: Vec<f64> = Vec::new();
                for (m, &tw) in layer.iter().zip(&touched) {
                    let sep = jt.seps[m.sep].len as f64;
                    let finish = sep * 2.0 * model.sep_ns;
                    let ranges = chunk_ranges(jt.seps[m.sep].len, cfg.min_chunk.min(1 << 12), cfg.max_chunks);
                    let fused = ranges.len() == 1;
                    for r in ranges {
                        let tail = if fused { finish } else { 0.0 };
                        b1_tasks.push(r.len() as f64 * tw as f64 * model.sep_ns + model.task_ns + tail);
                    }
                    if !fused {
                        b2_tasks.push(finish + model.task_ns);
                    }
                }
                total += makespan(&b1_tasks, threads) + model.region_ns;
                // region B2 only for multi-chunk separators — with default
                // chunking it is usually skipped, and so is its region cost
                if !b2_tasks.is_empty() {
                    total += makespan(&b2_tasks, threads) + model.region_ns;
                }
                // region C: flat run-kernel extend chunks grouped by receiver
                let mut by_to: std::collections::BTreeMap<usize, Vec<&Msg>> = Default::default();
                for m in layer.iter() {
                    by_to.entry(m.to).or_default().push(m);
                }
                let mut c_tasks = Vec::new();
                for (&to, msgs) in &by_to {
                    let per_entry: f64 =
                        msgs.iter().map(|m| model.extend_run_ns(run_len(to, m.sep))).sum();
                    for r in chunk_ranges(jt.cliques[to].len, cfg.min_chunk, cfg.max_chunks) {
                        c_tasks.push(r.len() as f64 * per_entry + model.task_ns);
                    }
                }
                total += makespan(&c_tasks, threads) + model.region_ns;
            }
            total
        }
    }
}

/// Convenience: modeled per-case time for an engine on a tree at `t`
/// threads, in seconds.
pub fn simulate_seconds(
    kind: EngineKind,
    jt: &Arc<JunctionTree>,
    threads: usize,
    cfg: &EngineConfig,
    model: &CostModel,
) -> f64 {
    let sched = Schedule::build(jt, cfg.root_strategy);
    simulate_case(kind, jt, &sched, threads, cfg, model) * 1e-9
}

/// The best (minimum) modeled time over a thread sweep — Table 1's
/// "varied t from 1 to 32 and chose the shortest" protocol.
pub fn best_over_threads(
    kind: EngineKind,
    jt: &Arc<JunctionTree>,
    sweep: &[usize],
    cfg: &EngineConfig,
    model: &CostModel,
) -> (usize, f64) {
    let sched = Schedule::build(jt, cfg.root_strategy);
    sweep
        .iter()
        .map(|&t| (t, simulate_case(kind, jt, &sched, t, cfg, model) * 1e-9))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("non-empty sweep")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::netgen;
    use crate::jt::triangulate::TriangulationHeuristic;

    fn test_model() -> CostModel {
        // fixed constants for deterministic tests
        CostModel {
            marg_ns: 1.0,
            extend_ns: 1.0,
            marg_run_b: 0.4,
            marg_run_c: 1.0,
            extend_run_b: 0.4,
            extend_run_c: 1.0,
            divmod_factor: 4.0,
            atomic_factor: 2.0,
            sep_ns: 2.0,
            zero_ns: 0.3,
            alloc_ns: 50.0,
            region_ns: 4000.0,
            task_ns: 30.0,
        }
    }

    fn tree() -> Arc<JunctionTree> {
        let net = netgen::paper_net("hailfinder-sim").unwrap();
        Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap())
    }

    #[test]
    fn makespan_properties() {
        assert_eq!(makespan(&[], 4), 0.0);
        assert_eq!(makespan(&[5.0], 4), 5.0);
        // perfect split
        assert_eq!(makespan(&[1.0; 8], 4), 2.0);
        // imbalance: one huge task bounds the makespan
        assert_eq!(makespan(&[100.0, 1.0, 1.0, 1.0], 4), 100.0);
        // more threads never hurt
        let tasks: Vec<f64> = (0..37).map(|i| (i % 7 + 1) as f64).collect();
        let mut last = f64::INFINITY;
        for t in 1..=8 {
            let m = makespan(&tasks, t);
            assert!(m <= last + 1e-12);
            last = m;
        }
    }

    #[test]
    fn seq_equals_hybrid_minus_overheads_at_t1_scaling() {
        let jt = tree();
        let cfg = EngineConfig::default();
        let model = test_model();
        let seq = simulate_seconds(EngineKind::Seq, &jt, 1, &cfg, &model);
        let hybrid1 = simulate_seconds(EngineKind::Hybrid, &jt, 1, &cfg, &model);
        // hybrid at t=1 = seq + region/zero overheads: strictly more
        assert!(hybrid1 > seq);
        // ... but within a reasonable factor on a small net
        assert!(hybrid1 < seq * 200.0, "overheads exploded: {hybrid1} vs {seq}");
    }

    #[test]
    fn unb_is_slower_than_seq() {
        let jt = tree();
        let cfg = EngineConfig::default();
        let model = test_model();
        let seq = simulate_seconds(EngineKind::Seq, &jt, 1, &cfg, &model);
        let unb = simulate_seconds(EngineKind::Unb, &jt, 1, &cfg, &model);
        assert!(unb > 2.0 * seq, "divmod baseline must be substantially slower");
    }

    #[test]
    fn hybrid_scales_with_threads_on_a_heavy_tree() {
        let net = netgen::NetSpec {
            name: "heavy".into(),
            nodes: 60,
            arcs: 90,
            max_parents: 3,
            card_choices: vec![(4, 1.0)],
            locality: 10,
            max_table: 1 << 14,
            alpha: 1.0,
            seed: 9,
        }
        .generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig { min_chunk: 256, ..Default::default() };
        let model = test_model();
        let t1 = simulate_seconds(EngineKind::Hybrid, &jt, 1, &cfg, &model);
        let t8 = simulate_seconds(EngineKind::Hybrid, &jt, 8, &cfg, &model);
        assert!(t8 < t1, "8 modeled threads must beat 1: {t8} vs {t1}");
    }

    #[test]
    fn hybrid_beats_primitive_on_many_small_cliques() {
        // chain-like tree: many messages, tiny tables -> primitive pays
        // 2 regions per message, hybrid 3 per layer
        let net = netgen::NetSpec {
            name: "chainy".into(),
            nodes: 200,
            arcs: 210,
            max_parents: 2,
            card_choices: vec![(2, 1.0)],
            locality: 3,
            max_table: 64,
            alpha: 1.0,
            seed: 10,
        }
        .generate();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig::default();
        let model = test_model();
        let hybrid = simulate_seconds(EngineKind::Hybrid, &jt, 8, &cfg, &model);
        let prim = simulate_seconds(EngineKind::Primitive, &jt, 8, &cfg, &model);
        assert!(hybrid < prim, "hybrid {hybrid} must beat primitive {prim} here");
    }

    #[test]
    fn best_over_threads_returns_minimum() {
        let jt = tree();
        let cfg = EngineConfig::default();
        let model = test_model();
        let sweep = [1usize, 2, 4, 8, 16, 32];
        let (best_t, best) = best_over_threads(EngineKind::Hybrid, &jt, &sweep, &cfg, &model);
        assert!(sweep.contains(&best_t));
        for &t in &sweep {
            assert!(best <= simulate_seconds(EngineKind::Hybrid, &jt, t, &cfg, &model) + 1e-15);
        }
    }

    #[test]
    fn calibration_produces_sane_constants() {
        let m = CostModel::calibrate();
        assert!(m.marg_ns > 0.05 && m.marg_ns < 1000.0, "marg {:?}", m);
        assert!(m.extend_ns > 0.05 && m.extend_ns < 1000.0);
        assert!(m.divmod_factor >= 1.0 && m.divmod_factor < 100.0);
        assert!(m.atomic_factor >= 1.0 && m.atomic_factor < 100.0);
        assert!(m.region_ns > 100.0, "region {:?}", m.region_ns);
    }
}
