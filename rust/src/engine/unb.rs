//! **UnBBayes-style baseline** — a deliberately naive sequential engine.
//!
//! Reproduces the *algorithmic* overheads of the Java reference
//! implementation the paper compares against (Carvalho et al. 2010), so
//! the Fast-BNI-seq vs UnBBayes row of Table 1 isolates the same effects:
//!
//! * index mappings recomputed **per entry, per message** with div/mod
//!   chains (no caching, no odometer);
//! * fresh allocations for every message's separator/ratio buffers;
//! * per-message recomputation of stride metadata.
//!
//! This is a substitution, not a port: we cannot run the JVM here, and a
//! Rust re-implementation removes the JIT/GC confound while keeping the
//! asymptotic overheads. DESIGN.md §3 discusses how this affects the
//! expected magnitude (but not direction) of the Table-1 seq speedups.

use std::sync::Arc;

use crate::engine::{Engine, EngineConfig};
use crate::infer::query::Posteriors;
use crate::jt::evidence::Evidence;
use crate::jt::mapping::{projection_strides, strides};
use crate::jt::ops;
use crate::jt::schedule::{Msg, Schedule};
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::{Error, Result};

/// Naive sequential baseline (see module docs).
pub struct UnbEngine {
    jt: Arc<JunctionTree>,
    sched: Schedule,
}

impl UnbEngine {
    /// Build for a tree. Thread/chunk settings are ignored (sequential).
    pub fn new(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let sched = Schedule::build(&jt, cfg.root_strategy);
        UnbEngine { jt, sched }
    }

    fn send(&self, state: &mut TreeState, msg: Msg) -> f64 {
        let jt = &self.jt;
        let sep_meta = &jt.seps[msg.sep];

        // per-message metadata recomputation + fresh allocations (the
        // baseline's characteristic overhead)
        let from = &jt.cliques[msg.from];
        let from_strides = strides(&from.cards);
        let from_proj = projection_strides(&from.vars, &sep_meta.vars, &sep_meta.cards);
        let mut new_sep = vec![0.0f64; sep_meta.len];
        ops::marg_divmod(state.clique(msg.from), &from.cards, &from_strides, &from_proj, &mut new_sep);

        let mass = ops::sum(&new_sep);
        if mass == 0.0 {
            return 0.0;
        }
        ops::scale(&mut new_sep, 1.0 / mass);
        state.log_z += mass.ln();

        let mut ratio = vec![0.0f64; sep_meta.len];
        ops::ratio(&new_sep, state.sep(msg.sep), &mut ratio);
        state.sep_mut(msg.sep).copy_from_slice(&new_sep);

        let to = &jt.cliques[msg.to];
        let to_strides = strides(&to.cards);
        let to_proj = projection_strides(&to.vars, &sep_meta.vars, &sep_meta.cards);
        ops::extend_divmod(state.clique_mut(msg.to), &to.cards, &to_strides, &to_proj, &ratio);
        mass
    }
}

impl Engine for UnbEngine {
    fn name(&self) -> &'static str {
        "UnBBayes"
    }

    fn infer(&mut self, state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        state.reset(&self.jt);
        ev.apply(&self.jt, state);
        for layer in &self.sched.up_layers {
            for &msg in layer {
                if self.send(state, msg) == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        for &root in &self.sched.roots {
            let data = state.clique_mut(root);
            let mass = ops::sum(data);
            if mass == 0.0 {
                return Err(Error::InconsistentEvidence);
            }
            ops::scale(data, 1.0 / mass);
            state.log_z += mass.ln();
        }
        let z = state.log_z;
        for layer in &self.sched.down_layers {
            for &msg in layer {
                if self.send(state, msg) == 0.0 {
                    return Err(Error::InconsistentEvidence);
                }
            }
        }
        state.log_z = z;
        Posteriors::compute(&self.jt, state)
    }

    fn schedule(&self) -> Option<&Schedule> {
        Some(&self.sched)
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        Some(&self.jt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::engine::seq::SeqEngine;
    use crate::jt::triangulate::TriangulationHeuristic;

    #[test]
    fn agrees_with_seq_engine() {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig::default();
        let mut unb = UnbEngine::new(Arc::clone(&jt), &cfg);
        let mut seq = SeqEngine::new(Arc::clone(&jt), &cfg);
        let mut s1 = TreeState::fresh(&jt);
        let mut s2 = TreeState::fresh(&jt);
        for seed in 0..5 {
            let cases = crate::infer::cases::generate(
                &net,
                &crate::infer::cases::CaseSpec { n_cases: 1, observed_fraction: 0.3, seed },
            );
            let a = unb.infer(&mut s1, &cases[0]).unwrap();
            let b = seq.infer(&mut s2, &cases[0]).unwrap();
            assert!(a.max_abs_diff(&b) < 1e-9, "seed {seed}: diff {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn detects_impossible_evidence() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let mut unb = UnbEngine::new(Arc::clone(&jt), &EngineConfig::default());
        let mut state = TreeState::fresh(&jt);
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("tub", "yes")]).unwrap();
        assert!(matches!(unb.infer(&mut state, &ev), Err(Error::InconsistentEvidence)));
    }
}
