//! Unsafe-but-contained sharing utilities for the parallel engines.
//!
//! The engines' schedules guarantee structural disjointness (each task
//! writes a distinct clique / separator / chunk range), but the borrow
//! checker cannot see through a `Vec<Vec<f64>>` indexed from multiple
//! worker threads. These two small wrappers concentrate the `unsafe` in
//! one audited place:
//!
//! * [`SharedTables`] — hands out raw clique/separator slices of a
//!   [`TreeState`] across threads; callers must touch disjoint regions.
//! * [`PerWorker`] — one scratch slot per pool worker; the pool guarantees
//!   a worker id runs one task at a time, so access is race-free.

use std::cell::UnsafeCell;

use crate::jt::state::TreeState;

/// Raw shared view of a `TreeState` for one parallel region.
pub struct SharedTables {
    cliques: *mut Vec<f64>,
    n_cliques: usize,
    seps: *mut Vec<f64>,
    n_seps: usize,
}

// SAFETY: access contracts are delegated to the unsafe methods below.
unsafe impl Send for SharedTables {}
unsafe impl Sync for SharedTables {}

impl SharedTables {
    /// Wrap a state for the duration of one parallel region. The `&mut`
    /// receipt guarantees exclusivity at the region boundary.
    pub fn new(state: &mut TreeState) -> Self {
        SharedTables {
            cliques: state.cliques.as_mut_ptr(),
            n_cliques: state.cliques.len(),
            seps: state.seps.as_mut_ptr(),
            n_seps: state.seps.len(),
        }
    }

    /// Read-only view of clique `c`.
    ///
    /// # Safety
    /// No concurrent task may hold a mutable view of the same clique.
    #[inline]
    pub unsafe fn clique(&self, c: usize) -> &[f64] {
        debug_assert!(c < self.n_cliques);
        &*self.cliques.add(c)
    }

    /// Mutable view of clique `c`.
    ///
    /// # Safety
    /// Concurrent tasks must write disjoint cliques, or disjoint entry
    /// ranges of the same clique.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn clique_mut(&self, c: usize) -> &mut [f64] {
        debug_assert!(c < self.n_cliques);
        &mut *self.cliques.add(c)
    }

    /// Mutable view of separator `s`.
    ///
    /// # Safety
    /// Concurrent tasks must write disjoint separators.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn sep_mut(&self, s: usize) -> &mut [f64] {
        debug_assert!(s < self.n_seps);
        &mut *self.seps.add(s)
    }
}

/// One value per pool worker, accessed without locks.
pub struct PerWorker<T> {
    slots: Vec<UnsafeCell<T>>,
}

// SAFETY: each worker id accesses only its own slot, and the pool runs one
// task per worker id at a time.
unsafe impl<T: Send> Sync for PerWorker<T> {}

impl<T> PerWorker<T> {
    /// Build `threads` slots from a constructor.
    pub fn new(threads: usize, mut init: impl FnMut(usize) -> T) -> Self {
        PerWorker { slots: (0..threads).map(|w| UnsafeCell::new(init(w))).collect() }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when there are no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Mutable access to worker `w`'s slot.
    ///
    /// # Safety
    /// Must only be called from the task currently running as worker `w`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self, w: usize) -> &mut T {
        &mut *self.slots[w].get()
    }

    /// Exclusive iteration over all slots (for post-region reduction).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.slots.iter_mut().map(|c| c.get_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::pool::Pool;

    #[test]
    fn per_worker_accumulates_independently() {
        let pool = Pool::new(4);
        let mut pw = PerWorker::new(4, |_| 0u64);
        {
            let pw_ref = &pw;
            pool.parallel(1000, &|w, t| unsafe {
                *pw_ref.get(w) += t as u64;
            });
        }
        let total: u64 = pw.iter_mut().map(|x| *x).sum();
        assert_eq!(total, 499_500);
    }

    #[test]
    fn shared_tables_disjoint_writes() {
        use crate::bn::embedded;
        use crate::jt::tree::JunctionTree;
        use crate::jt::triangulate::TriangulationHeuristic;

        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut state = TreeState::fresh(&jt);
        let n = state.cliques.len();
        let pool = Pool::new(4);
        {
            let shared = SharedTables::new(&mut state);
            let shared_ref = &shared;
            pool.parallel(n, &|_w, c| unsafe {
                // each task owns clique c exclusively
                for x in shared_ref.clique_mut(c) {
                    *x = c as f64;
                }
            });
        }
        for (c, data) in state.cliques.iter().enumerate() {
            assert!(data.iter().all(|&x| x == c as f64));
        }
    }
}
