//! Unsafe-but-contained sharing utilities for the parallel engines.
//!
//! The engines' schedules guarantee structural disjointness (each task
//! writes a distinct clique / separator / chunk range), but the borrow
//! checker cannot see through a shared flat arena indexed from multiple
//! worker threads. These two small wrappers concentrate the `unsafe` in
//! one audited place:
//!
//! * [`SharedTables`] — hands out raw clique/separator slices of a
//!   [`TreeState`] (or, lane-expanded, of a [`BatchState`]) across
//!   threads; callers must touch disjoint regions. Since the arena
//!   refactor all tables live in **one allocation**, so "disjoint" means
//!   disjoint index ranges of that allocation — which the layout
//!   guarantees for distinct tables, and chunk plans guarantee within a
//!   table.
//! * [`PerWorker`] — one scratch slot per pool worker; the pool guarantees
//!   a worker id runs one task at a time, so access is race-free.

use std::cell::UnsafeCell;
use std::sync::Arc;

use crate::jt::state::{ArenaLayout, BatchState, TreeState};

/// Raw shared view of a state arena for one parallel region.
///
/// `lanes == 1` for a [`TreeState`]; a [`BatchState`] view returns
/// lane-expanded slices (`len * lanes` values per table, entry `i` of lane
/// `b` at `i * lanes + b`).
pub struct SharedTables {
    data: *mut f64,
    lanes: usize,
    layout: Arc<ArenaLayout>,
}

// SAFETY: access contracts are delegated to the unsafe methods below.
unsafe impl Send for SharedTables {}
unsafe impl Sync for SharedTables {}

impl SharedTables {
    /// Wrap a single-case state for the duration of one parallel region.
    /// The `&mut` receipt guarantees exclusivity at the region boundary.
    pub fn new(state: &mut TreeState) -> Self {
        let layout = Arc::clone(state.layout());
        SharedTables { data: state.data_mut().as_mut_ptr(), lanes: 1, layout }
    }

    /// Wrap a batch state (lane-expanded slices) for one parallel region.
    pub fn for_batch(state: &mut BatchState) -> Self {
        let layout = Arc::clone(state.layout());
        let lanes = state.lanes();
        SharedTables { data: state.data_mut().as_mut_ptr(), lanes, layout }
    }

    /// Lanes per entry in the slices this view hands out.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// # Safety
    /// Caller must uphold the per-method aliasing contracts.
    #[inline]
    unsafe fn range_mut(&self, r: std::ops::Range<usize>) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.data.add(r.start * self.lanes), (r.end - r.start) * self.lanes)
    }

    /// Read-only view of clique `c`.
    ///
    /// # Safety
    /// No concurrent task may hold a mutable view of the same clique.
    #[inline]
    pub unsafe fn clique(&self, c: usize) -> &[f64] {
        &*self.range_mut(self.layout.clique_range(c))
    }

    /// Mutable view of clique `c`.
    ///
    /// # Safety
    /// Concurrent tasks must write disjoint cliques, or disjoint entry
    /// ranges of the same clique.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn clique_mut(&self, c: usize) -> &mut [f64] {
        self.range_mut(self.layout.clique_range(c))
    }

    /// Mutable view of separator `s`.
    ///
    /// # Safety
    /// Concurrent tasks must write disjoint separators.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn sep_mut(&self, s: usize) -> &mut [f64] {
        self.range_mut(self.layout.sep_range(s))
    }
}

/// One value per pool worker, accessed without locks.
pub struct PerWorker<T> {
    slots: Vec<UnsafeCell<T>>,
}

// SAFETY: each worker id accesses only its own slot, and the pool runs one
// task per worker id at a time.
unsafe impl<T: Send> Sync for PerWorker<T> {}

impl<T> PerWorker<T> {
    /// Build `threads` slots from a constructor.
    pub fn new(threads: usize, mut init: impl FnMut(usize) -> T) -> Self {
        PerWorker { slots: (0..threads).map(|w| UnsafeCell::new(init(w))).collect() }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when there are no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Mutable access to worker `w`'s slot.
    ///
    /// # Safety
    /// Must only be called from the task currently running as worker `w`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self, w: usize) -> &mut T {
        &mut *self.slots[w].get()
    }

    /// Exclusive iteration over all slots (for post-region reduction).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.slots.iter_mut().map(|c| c.get_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::pool::Pool;

    #[test]
    fn per_worker_accumulates_independently() {
        let pool = Pool::new(4);
        let mut pw = PerWorker::new(4, |_| 0u64);
        {
            let pw_ref = &pw;
            pool.parallel(1000, &|w, t| unsafe {
                *pw_ref.get(w) += t as u64;
            });
        }
        let total: u64 = pw.iter_mut().map(|x| *x).sum();
        assert_eq!(total, 499_500);
    }

    #[test]
    fn shared_tables_disjoint_writes() {
        use crate::bn::embedded;
        use crate::jt::tree::JunctionTree;
        use crate::jt::triangulate::TriangulationHeuristic;

        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut state = TreeState::fresh(&jt);
        let n = jt.n_cliques();
        let pool = Pool::new(4);
        {
            let shared = SharedTables::new(&mut state);
            let shared_ref = &shared;
            pool.parallel(n, &|_w, c| unsafe {
                // each task owns clique c exclusively
                for x in shared_ref.clique_mut(c) {
                    *x = c as f64;
                }
            });
        }
        for c in 0..n {
            assert!(state.clique(c).iter().all(|&x| x == c as f64));
        }
    }

    #[test]
    fn batch_view_hands_out_lane_expanded_slices() {
        use crate::bn::embedded;
        use crate::jt::tree::JunctionTree;
        use crate::jt::triangulate::TriangulationHeuristic;

        let net = embedded::asia();
        let jt = JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap();
        let mut bs = BatchState::fresh(&jt, 4);
        {
            let shared = SharedTables::for_batch(&mut bs);
            assert_eq!(shared.lanes(), 4);
            // single-threaded exclusive use satisfies the contracts
            unsafe {
                assert_eq!(shared.clique(0).len(), jt.cliques[0].len * 4);
                shared.clique_mut(0)[1] = 9.0; // entry 0, lane 1
                shared.sep_mut(0)[0] = 3.0; // entry 0, lane 0
            }
        }
        assert_eq!(bs.clique(0)[1], 9.0);
        assert_eq!(bs.sep(0)[0], 3.0);
    }
}
