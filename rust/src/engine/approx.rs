//! The approximate tier: pool-parallel likelihood weighting.
//!
//! Every other engine in this module is exact junction-tree propagation,
//! so treewidth is a hard ceiling — one dense network can mint a clique
//! table that exhausts memory before the first query runs.
//! [`ApproxEngine`] removes that ceiling: it samples the network forward
//! ([`crate::bn::sample::draw_weighted_row`]) with observed variables
//! clamped and importance-weighted, needing only the CPTs — the
//! junction tree is never compiled.
//!
//! ## Determinism contract
//!
//! Samples are drawn in fixed-size chunks ([`CHUNK`] samples each, a
//! constant independent of the thread count). Chunk `i` runs on its own
//! RNG sub-stream derived by mixing the configured seed with `i` through
//! SplitMix64, and each chunk's accumulators land in a dedicated slot.
//! After the parallel region the slots are merged **sequentially in
//! chunk-index order**, so the floating-point addition order — and
//! therefore every output bit — is identical at any thread count. This is
//! the same per-worker-sub-stream discipline the PC-stable learner uses.
//!
//! ## Accuracy contract
//!
//! Returned [`Posteriors`] carry [`ApproxInfo`]: the sample count and the
//! effective sample size `(Σw)²/Σw²`, from which a 95% CI half-width is
//! reported for every probability. `EngineConfig::samples` sets the base
//! sample count; `EngineConfig::target_half_width`, when positive, keeps
//! adding deterministic chunk rounds (up to [`BUDGET_ROUNDS`] × the base
//! count) until the worst-case half-width drops below the target.

use std::sync::{Arc, Mutex};

use crate::bn::network::Network;
use crate::bn::sample::draw_weighted_row;
use crate::engine::pool::Pool;
use crate::engine::{Engine, EngineConfig};
use crate::infer::query::{ApproxInfo, Posteriors};
use crate::jt::evidence::Evidence;
use crate::jt::schedule::Schedule;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::rng::{splitmix64, Rng};
use crate::{Error, Result};

/// Samples per chunk — fixed so the chunk decomposition (and with it the
/// summation order) never depends on the thread count.
pub const CHUNK: usize = 1 << 12;

/// Hard budget when chasing `target_half_width`: at most this many times
/// the configured base sample count is ever drawn for one case.
pub const BUDGET_ROUNDS: usize = 32;

/// Per-chunk accumulator: flat per-state weighted counts plus the weight
/// moments the ESS needs.
struct ChunkAcc {
    acc: Vec<f64>,
    w_sum: f64,
    w_sq: f64,
}

/// Likelihood-weighting engine over [`Pool`]. See the module docs for the
/// determinism and accuracy contracts.
pub struct ApproxEngine {
    net: Arc<Network>,
    /// Kept only when the engine was built from an already-compiled tree
    /// (`EngineKind::Approx.build`); the fallback path has none.
    jt: Option<Arc<JunctionTree>>,
    pool: Pool,
    samples: usize,
    target_half_width: f64,
    seed: u64,
    order: Vec<usize>,
    cards: Vec<usize>,
    /// Flat offset of variable `v`'s states in a chunk accumulator.
    offsets: Vec<usize>,
    /// Total states = Σ cards.
    total_states: usize,
}

impl ApproxEngine {
    /// Build from a network alone — the cost-based fallback path: no
    /// junction tree is ever compiled.
    pub fn from_net(net: Arc<Network>, cfg: &EngineConfig) -> Self {
        let order = net.topo_order().expect("validated networks are acyclic");
        let cards = net.cards();
        let mut offsets = Vec::with_capacity(cards.len());
        let mut total_states = 0usize;
        for &c in &cards {
            offsets.push(total_states);
            total_states += c;
        }
        ApproxEngine {
            jt: None,
            pool: Pool::new(cfg.resolved_threads()),
            samples: cfg.samples.max(1),
            target_half_width: cfg.target_half_width,
            seed: cfg.seed,
            order,
            cards,
            offsets,
            total_states,
            net,
        }
    }

    /// Build from a compiled tree (`EngineKind::Approx` through the
    /// selector) — sampling still only reads the CPTs, but the tree is
    /// retained so [`Engine::tree`] can report it.
    pub fn from_tree(jt: Arc<JunctionTree>, cfg: &EngineConfig) -> Self {
        let mut engine = Self::from_net(Arc::new(jt.net.clone()), cfg);
        engine.jt = Some(jt);
        engine
    }

    /// The network being sampled.
    pub fn net(&self) -> &Arc<Network> {
        &self.net
    }

    /// One deterministic chunk: `n` weighted samples on chunk `index`'s
    /// private sub-stream.
    fn run_chunk(&self, index: u64, n: usize, obs: &[Option<usize>], ev: &Evidence) -> ChunkAcc {
        let mut mix = self.seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(splitmix64(&mut mix));
        let mut acc = ChunkAcc { acc: vec![0.0; self.total_states], w_sum: 0.0, w_sq: 0.0 };
        let mut assignment = vec![0usize; self.net.n()];
        let mut config = Vec::new();
        for _ in 0..n {
            let mut weight =
                draw_weighted_row(&self.net, &self.order, &self.cards, obs, &mut rng, &mut assignment, &mut config);
            if weight == 0.0 {
                continue;
            }
            for (v, lik) in &ev.soft {
                weight *= lik[assignment[*v]];
            }
            if weight > 0.0 {
                acc.w_sum += weight;
                acc.w_sq += weight * weight;
                for (v, &s) in assignment.iter().enumerate() {
                    acc.acc[self.offsets[v] + s] += weight;
                }
            }
        }
        acc
    }

    /// Run one round of `n_chunks` chunks starting at `first_chunk` in
    /// parallel and fold them into `total` in chunk-index order.
    fn run_round(&self, first_chunk: u64, n_chunks: usize, obs: &[Option<usize>], ev: &Evidence, total: &mut ChunkAcc) {
        let slots: Vec<Mutex<Option<ChunkAcc>>> = (0..n_chunks).map(|_| Mutex::new(None)).collect();
        self.pool.parallel_region("approx.round", n_chunks, &|_w, t| {
            let acc = self.run_chunk(first_chunk + t as u64, CHUNK, obs, ev);
            *slots[t].lock().unwrap() = Some(acc);
        });
        // sequential merge in chunk order: the addition order is fixed, so
        // the result is bit-identical at any thread count
        for slot in slots {
            let acc = slot.into_inner().unwrap().expect("every chunk ran");
            total.w_sum += acc.w_sum;
            total.w_sq += acc.w_sq;
            for (t, x) in total.acc.iter_mut().zip(&acc.acc) {
                *t += x;
            }
        }
    }
}

impl Engine for ApproxEngine {
    fn name(&self) -> &'static str {
        "Approx-LW"
    }

    fn infer(&mut self, _state: &mut TreeState, ev: &Evidence) -> Result<Posteriors> {
        // dense observation vector: draw_weighted_row clamps these
        let mut obs: Vec<Option<usize>> = vec![None; self.net.n()];
        for &(v, s) in &ev.obs {
            if v >= self.net.n() || s >= self.cards[v] {
                return Err(Error::UnknownVariable(format!("evidence variable {v} out of range")));
            }
            obs[v] = Some(s);
        }
        for (v, lik) in &ev.soft {
            if *v >= self.net.n() || lik.len() != self.cards[*v] {
                return Err(Error::UnknownVariable(format!("soft evidence variable {v} out of range")));
            }
        }

        let n_chunks = self.samples.div_ceil(CHUNK);
        let mut total = ChunkAcc { acc: vec![0.0; self.total_states], w_sum: 0.0, w_sq: 0.0 };
        let mut drawn = 0usize;
        let mut next_chunk = 0u64;
        let mut rounds = 0u64;
        let budget = self.samples.saturating_mul(BUDGET_ROUNDS);
        // Telemetry below only reads the clock and bumps counters; the
        // sampling path (RNG streams, merge order) is untouched, so
        // posteriors stay bit-identical with observability on or off.
        let root_span = crate::obs::trace::span("approx.infer");
        loop {
            let round_span = crate::obs::trace::span("approx.round");
            self.run_round(next_chunk, n_chunks, &obs, ev, &mut total);
            next_chunk += n_chunks as u64;
            drawn += n_chunks * CHUNK;
            rounds += 1;
            let ess = if total.w_sq > 0.0 { total.w_sum * total.w_sum / total.w_sq } else { 0.0 };
            round_span.note(&format!("drawn={drawn} ess={ess:.0}"));
            drop(round_span);
            if self.target_half_width <= 0.0 || drawn >= budget {
                break;
            }
            let info = ApproxInfo { n_samples: drawn, effective_samples: ess };
            if ess > 0.0 && info.max_half_width() <= self.target_half_width {
                break;
            }
        }
        crate::obs::global().counter("fastbn_approx_rounds_total").add(rounds);
        {
            let ess = if total.w_sq > 0.0 { total.w_sum * total.w_sum / total.w_sq } else { 0.0 };
            root_span.note(&format!("rounds={rounds} drawn={drawn} ess={ess:.0}"));
        }

        if total.w_sum <= 0.0 {
            return Err(Error::InconsistentEvidence);
        }
        let mut probs = Vec::with_capacity(self.net.n());
        for (v, &card) in self.cards.iter().enumerate() {
            let off = self.offsets[v];
            probs.push(total.acc[off..off + card].iter().map(|&x| x / total.w_sum).collect());
        }
        Ok(Posteriors {
            probs,
            log_z: (total.w_sum / drawn as f64).ln(),
            approx: Some(ApproxInfo {
                n_samples: drawn,
                effective_samples: total.w_sum * total.w_sum / total.w_sq,
            }),
        })
    }

    fn schedule(&self) -> Option<&Schedule> {
        None
    }

    fn tree(&self) -> Option<&Arc<JunctionTree>> {
        self.jt.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{embedded, netgen};
    use crate::engine::EngineKind;
    use crate::jt::triangulate::TriangulationHeuristic;

    fn approx(net: &Network, threads: usize, samples: usize) -> ApproxEngine {
        let cfg = EngineConfig::default().with_threads(threads).with_samples(samples);
        ApproxEngine::from_net(Arc::new(net.clone()), &cfg)
    }

    #[test]
    fn posteriors_are_bit_identical_across_thread_counts() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("dysp", "yes")]).unwrap();
        let mut state = TreeState::detached();
        let mut reference: Option<Posteriors> = None;
        for threads in [1usize, 2, 4, 7] {
            let mut engine = approx(&net, threads, 20_000);
            let post = engine.infer(&mut state, &ev).unwrap();
            match &reference {
                None => reference = Some(post),
                Some(r) => {
                    assert_eq!(r.probs, post.probs, "threads={threads}");
                    assert_eq!(r.log_z.to_bits(), post.log_z.to_bits(), "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn agrees_with_exact_within_reported_half_width() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("dysp", "yes")]).unwrap();
        let exact = crate::infer::exact::enumerate(&net, &ev).unwrap();
        let mut engine = approx(&net, 4, 100_000);
        let post = engine.infer(&mut TreeState::detached(), &ev).unwrap();
        let info = post.approx.as_ref().expect("approximate posteriors carry ApproxInfo");
        assert!(info.effective_samples > 1_000.0);
        for v in 0..net.n() {
            for s in 0..net.card(v) {
                let (got, want) = (post.probs[v][s], exact.probs[v][s]);
                // 3× the 95% half-width: a deterministic bound a correct
                // sampler effectively never exceeds
                assert!(
                    (got - want).abs() <= 3.0 * info.half_width(want).max(1e-3),
                    "v{v}s{s}: {got} vs {want} (hw {})",
                    info.half_width(want)
                );
            }
        }
    }

    #[test]
    fn soft_evidence_shifts_the_posterior() {
        let net = embedded::asia();
        let smoke = net.var_id("smoke").unwrap();
        let ev = Evidence::none().with_soft(smoke, vec![4.0, 1.0]).unwrap();
        let mut engine = approx(&net, 2, 100_000);
        let post = engine.infer(&mut TreeState::detached(), &ev).unwrap();
        assert!((post.probs[smoke][0] - 0.8).abs() < 0.02, "got {}", post.probs[smoke][0]);
    }

    #[test]
    fn inconsistent_evidence_is_a_clean_error() {
        let net = embedded::asia();
        let ev = Evidence::from_pairs(&net, &[("either", "no"), ("lung", "yes")]).unwrap();
        let mut engine = approx(&net, 2, 8_192);
        let got = engine.infer(&mut TreeState::detached(), &ev);
        assert!(matches!(got, Err(Error::InconsistentEvidence)), "{got:?}");
    }

    #[test]
    fn target_half_width_draws_more_samples() {
        let net = embedded::asia();
        let ev = Evidence::none();
        let mut fixed = approx(&net, 2, CHUNK);
        let base = fixed.infer(&mut TreeState::detached(), &ev).unwrap();
        let cfg = EngineConfig::default().with_threads(2).with_samples(CHUNK);
        let mut adaptive = ApproxEngine::from_net(Arc::new(net.clone()), &EngineConfig {
            target_half_width: 0.002,
            ..cfg
        });
        let post = adaptive.infer(&mut TreeState::detached(), &ev).unwrap();
        let info = post.approx.as_ref().unwrap();
        let base_info = base.approx.as_ref().unwrap();
        assert!(info.n_samples > base_info.n_samples, "{} vs {}", info.n_samples, base_info.n_samples);
        assert!(info.max_half_width() <= 0.002, "{}", info.max_half_width());
    }

    #[test]
    fn builds_through_the_selector_with_a_tree() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cfg = EngineConfig::default().with_threads(2).with_samples(50_000);
        let mut engine = EngineKind::Approx.build(Arc::clone(&jt), &cfg);
        assert_eq!(engine.name(), "Approx-LW");
        assert!(engine.schedule().is_none());
        assert!(engine.tree().is_some());
        let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
        let post = engine.infer(&mut TreeState::detached(), &ev).unwrap();
        assert!((post.marginal(&net, "lung").unwrap()[0] - 0.1).abs() < 0.02);
        assert!((post.evidence_probability() - 0.5).abs() < 0.02);
    }

    #[test]
    fn serves_an_intractable_network() {
        // the whole point of the tier: a network no exact engine could
        // compile answers queries with a finite, reported accuracy
        let net = netgen::intractable_spec().generate();
        let mut engine = approx(&net, 4, 20_000);
        let post = engine.infer(&mut TreeState::detached(), &Evidence::none()).unwrap();
        assert_eq!(post.probs.len(), net.n());
        for marg in &post.probs {
            let total: f64 = marg.iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
        }
        let info = post.approx.as_ref().unwrap();
        assert!(info.effective_samples > 10_000.0, "prior sampling has weight 1: ESS ≈ n");
    }
}
