//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so this module provides a
//! small, high-quality, seedable PRNG: **xoshiro256\*\*** seeded through
//! SplitMix64 (the reference seeding procedure from Blackman & Vigna).
//! Everything in the repo that needs randomness (network generation, test
//! cases, property tests) goes through [`Rng`], so every experiment is
//! reproducible from a single `u64` seed.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — 256-bit state, period 2^256 − 1, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-case / per-thread
    /// streams that must not correlate with the parent).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift rejection method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// A random probability distribution over `n` outcomes, Dirichlet(alpha)
    /// approximated by normalized Gamma draws via the Marsaglia–Tsang method
    /// for alpha >= 1 and Johnk boost for alpha < 1.
    pub fn dirichlet(&mut self, n: usize, alpha: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            // Degenerate draw — fall back to uniform.
            return vec![1.0 / n as f64; n];
        }
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Gamma(shape, 1) sample.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        // Marsaglia–Tsang
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Standard normal via Box–Muller (one value; the pair is discarded for
    /// simplicity — this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Categorical sample from (unnormalized, non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = r.below(10);
            assert!(x < 10);
            counts[x] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 800.0, "count {c} too skewed");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(11);
        for n in [1usize, 2, 5, 17] {
            for alpha in [0.5, 1.0, 4.0] {
                let d = r.dirichlet(n, alpha);
                assert_eq!(d.len(), n);
                let s: f64 = d.iter().sum();
                assert!((s - 1.0).abs() < 1e-9);
                assert!(d.iter().all(|&p| p >= 0.0));
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut w = v.clone();
        w.sort_unstable();
        assert_eq!(w, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let w = [0.0, 3.0, 1.0];
        let mut c = [0usize; 3];
        for _ in 0..40_000 {
            c[r.categorical(&w)] += 1;
        }
        assert_eq!(c[0], 0);
        let ratio = c[1] as f64 / c[2] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn fork_streams_decorrelate() {
        let mut base = Rng::new(23);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
