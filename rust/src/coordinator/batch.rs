//! Batch router: runs a case list through engine replicas.
//!
//! Work distribution is dynamic (a shared atomic cursor over the case
//! list), so stragglers — cases whose evidence makes propagation cheaper
//! or costlier — don't serialize the batch. Each replica owns a full
//! engine instance (with its own thread pool of `engine_cfg.threads`) and
//! a reusable [`TreeState`]. The serving-side analog of a replica is a
//! [`crate::fleet`] shard: same engine-per-worker layout, but fed by a
//! request stream instead of a case list.
//!
//! **Fused-batch mode** (`BatchConfig::fused_batch > 1`): the cursor
//! claims *chunks* of cases and each replica runs them through
//! [`crate::engine::Engine::infer_batch`] — with the batched engine
//! (`--engine batched`), one sweep propagates the whole chunk and every
//! index-map lookup is amortized across it. Fused chunks and replicas
//! compose: replicas spread chunks across cores, fusion amortizes within
//! a chunk.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencySummary;
use crate::engine::{EngineConfig, EngineKind};
use crate::jt::evidence::Evidence;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::Result;

/// Batch-run configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Which engine to run.
    pub engine: EngineKind,
    /// Engine construction parameters (threads = intra-case parallelism).
    pub engine_cfg: EngineConfig,
    /// Engine replicas processing cases concurrently (1 = the paper's
    /// protocol: cases sequential, parallelism inside each case).
    pub replicas: usize,
    /// Cases per fused chunk run through `Engine::infer_batch` (≤ 1 =
    /// per-case dispatch, the previous behavior). Pair with
    /// `EngineKind::Batched` + `engine_cfg.batch` for single-sweep chunks.
    pub fused_batch: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            engine: EngineKind::Hybrid,
            engine_cfg: EngineConfig::default(),
            replicas: 1,
            fused_batch: 0,
        }
    }
}

/// Outcome of a batch run.
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Engine label.
    pub engine: String,
    /// Wall-clock duration of the whole batch.
    pub wall: Duration,
    /// Per-case latency summary (successful cases).
    pub latency: LatencySummary,
    /// Cases that failed (index, error text) — e.g. inconsistent evidence.
    pub failures: Vec<(usize, String)>,
    /// Mean `ln P(e)` across successful cases (a checksum-like quantity
    /// used to verify different engines computed the same thing).
    pub mean_log_z: f64,
}

impl BatchReport {
    /// Cases per second.
    pub fn throughput(&self) -> f64 {
        self.latency.throughput(self.wall)
    }
}

/// Runs case batches against one junction tree.
pub struct BatchRunner {
    jt: Arc<JunctionTree>,
}

impl BatchRunner {
    /// Create a runner for a tree.
    pub fn new(jt: Arc<JunctionTree>) -> Self {
        BatchRunner { jt }
    }

    /// The tree in use.
    pub fn tree(&self) -> &Arc<JunctionTree> {
        &self.jt
    }

    /// Run all `cases`, returning the report.
    pub fn run(&self, cases: &[Evidence], cfg: &BatchConfig) -> Result<BatchReport> {
        let replicas = cfg.replicas.max(1);
        let fused = cfg.fused_batch.max(1);
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<(usize, Duration, std::result::Result<f64, String>)>> =
            Mutex::new(Vec::with_capacity(cases.len()));

        let started = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..replicas {
                scope.spawn(|| {
                    let mut engine = cfg.engine.build(Arc::clone(&self.jt), &cfg.engine_cfg);
                    let mut state = TreeState::fresh(&self.jt);
                    let mut local = Vec::new();
                    loop {
                        // the cursor claims `fused` cases at a time; each
                        // chunk runs through infer_batch (one sweep with
                        // the batched engine, a plain loop otherwise)
                        let start = cursor.fetch_add(fused, Ordering::Relaxed);
                        if start >= cases.len() {
                            break;
                        }
                        let end = (start + fused).min(cases.len());
                        let t0 = Instant::now();
                        let outs = engine.infer_batch(&mut state, &cases[start..end]);
                        let per_case = t0.elapsed() / (end - start) as u32;
                        for (k, outcome) in outs.into_iter().enumerate() {
                            let outcome = outcome.map(|post| post.log_z).map_err(|e| e.to_string());
                            local.push((start + k, per_case, outcome));
                        }
                    }
                    results.lock().unwrap().extend(local);
                });
            }
        });
        let wall = started.elapsed();

        let mut results = results.into_inner().unwrap();
        results.sort_by_key(|&(i, _, _)| i);
        let mut latencies = Vec::with_capacity(results.len());
        let mut failures = Vec::new();
        let mut log_z_sum = 0.0f64;
        let mut ok = 0usize;
        for (i, lat, outcome) in results {
            match outcome {
                Ok(log_z) => {
                    latencies.push(lat);
                    log_z_sum += log_z;
                    ok += 1;
                }
                Err(e) => failures.push((i, e)),
            }
        }
        Ok(BatchReport {
            engine: cfg.engine.label().to_string(),
            wall,
            latency: LatencySummary::from_samples(&latencies),
            failures,
            mean_log_z: if ok > 0 { log_z_sum / ok as f64 } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::infer::cases::{generate, CaseSpec};
    use crate::jt::triangulate::TriangulationHeuristic;

    fn setup() -> (Arc<JunctionTree>, Vec<Evidence>) {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = generate(&net, &CaseSpec { n_cases: 24, observed_fraction: 0.25, seed: 77 });
        (jt, cases)
    }

    #[test]
    fn single_replica_processes_all_cases() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let cfg = BatchConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            replicas: 1,
            fused_batch: 0,
        };
        let report = runner.run(&cases, &cfg).unwrap();
        assert_eq!(report.latency.count + report.failures.len(), cases.len());
        assert!(report.failures.is_empty());
        assert!(report.throughput() > 0.0);
    }

    #[test]
    fn replicas_produce_same_aggregate_as_single() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let single = runner
            .run(
                &cases,
                &BatchConfig {
                    engine: EngineKind::Seq,
                    engine_cfg: EngineConfig::default().with_threads(1),
                    replicas: 1,
                    fused_batch: 0,
                },
            )
            .unwrap();
        let multi = runner
            .run(
                &cases,
                &BatchConfig {
                    engine: EngineKind::Seq,
                    engine_cfg: EngineConfig::default().with_threads(1),
                    replicas: 4,
                    fused_batch: 0,
                },
            )
            .unwrap();
        assert_eq!(single.latency.count, multi.latency.count);
        assert!((single.mean_log_z - multi.mean_log_z).abs() < 1e-9);
    }

    #[test]
    fn fused_batch_mode_matches_per_case_dispatch() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let per_case = runner
            .run(
                &cases,
                &BatchConfig {
                    engine: EngineKind::Seq,
                    engine_cfg: EngineConfig::default().with_threads(1),
                    replicas: 1,
                    fused_batch: 0,
                },
            )
            .unwrap();
        // fused chunks through the batched engine, with replicas on top —
        // including a chunk size that does not divide the case count
        for (fused, replicas) in [(4usize, 1usize), (7, 2), (64, 2)] {
            let fusedrep = runner
                .run(
                    &cases,
                    &BatchConfig {
                        engine: EngineKind::Batched,
                        engine_cfg: EngineConfig::default().with_threads(2).with_batch(fused),
                        replicas,
                        fused_batch: fused,
                    },
                )
                .unwrap();
            assert_eq!(fusedrep.latency.count, per_case.latency.count, "fused={fused}");
            assert!(
                (fusedrep.mean_log_z - per_case.mean_log_z).abs() < 1e-9,
                "fused={fused} replicas={replicas}: {} vs {}",
                fusedrep.mean_log_z,
                per_case.mean_log_z
            );
        }
    }

    #[test]
    fn engines_agree_on_mean_log_z() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let mut means = Vec::new();
        for kind in EngineKind::ALL {
            let report = runner
                .run(
                    &cases,
                    &BatchConfig {
                        engine: kind,
                        engine_cfg: EngineConfig { threads: 2, min_chunk: 8, ..Default::default() },
                        replicas: 2,
                        fused_batch: 0,
                    },
                )
                .unwrap();
            means.push((kind, report.mean_log_z));
        }
        for (kind, m) in &means[1..] {
            assert!((means[0].1 - m).abs() < 1e-9, "{kind} mean_log_z {m} vs {}", means[0].1);
        }
    }
}
