//! Batch router: runs a case list through engine replicas.
//!
//! Work distribution is dynamic (a shared atomic cursor over the case
//! list), so stragglers — cases whose evidence makes propagation cheaper
//! or costlier — don't serialize the batch. Each replica owns a full
//! engine instance (with its own thread pool of `engine_cfg.threads`) and
//! a reusable [`TreeState`]. The serving-side analog of a replica is a
//! [`crate::fleet`] shard: same engine-per-worker layout, but fed by a
//! request stream instead of a case list.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencySummary;
use crate::engine::{EngineConfig, EngineKind};
use crate::jt::evidence::Evidence;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::Result;

/// Batch-run configuration.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Which engine to run.
    pub engine: EngineKind,
    /// Engine construction parameters (threads = intra-case parallelism).
    pub engine_cfg: EngineConfig,
    /// Engine replicas processing cases concurrently (1 = the paper's
    /// protocol: cases sequential, parallelism inside each case).
    pub replicas: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { engine: EngineKind::Hybrid, engine_cfg: EngineConfig::default(), replicas: 1 }
    }
}

/// Outcome of a batch run.
#[derive(Clone, Debug)]
pub struct BatchReport {
    /// Engine label.
    pub engine: String,
    /// Wall-clock duration of the whole batch.
    pub wall: Duration,
    /// Per-case latency summary (successful cases).
    pub latency: LatencySummary,
    /// Cases that failed (index, error text) — e.g. inconsistent evidence.
    pub failures: Vec<(usize, String)>,
    /// Mean `ln P(e)` across successful cases (a checksum-like quantity
    /// used to verify different engines computed the same thing).
    pub mean_log_z: f64,
}

impl BatchReport {
    /// Cases per second.
    pub fn throughput(&self) -> f64 {
        self.latency.throughput(self.wall)
    }
}

/// Runs case batches against one junction tree.
pub struct BatchRunner {
    jt: Arc<JunctionTree>,
}

impl BatchRunner {
    /// Create a runner for a tree.
    pub fn new(jt: Arc<JunctionTree>) -> Self {
        BatchRunner { jt }
    }

    /// The tree in use.
    pub fn tree(&self) -> &Arc<JunctionTree> {
        &self.jt
    }

    /// Run all `cases`, returning the report.
    pub fn run(&self, cases: &[Evidence], cfg: &BatchConfig) -> Result<BatchReport> {
        let replicas = cfg.replicas.max(1);
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<(usize, Duration, std::result::Result<f64, String>)>> =
            Mutex::new(Vec::with_capacity(cases.len()));

        let started = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..replicas {
                scope.spawn(|| {
                    let mut engine = cfg.engine.build(Arc::clone(&self.jt), &cfg.engine_cfg);
                    let mut state = TreeState::fresh(&self.jt);
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= cases.len() {
                            break;
                        }
                        let t0 = Instant::now();
                        let outcome = engine
                            .infer(&mut state, &cases[i])
                            .map(|post| post.log_z)
                            .map_err(|e| e.to_string());
                        local.push((i, t0.elapsed(), outcome));
                    }
                    results.lock().unwrap().extend(local);
                });
            }
        });
        let wall = started.elapsed();

        let mut results = results.into_inner().unwrap();
        results.sort_by_key(|&(i, _, _)| i);
        let mut latencies = Vec::with_capacity(results.len());
        let mut failures = Vec::new();
        let mut log_z_sum = 0.0f64;
        let mut ok = 0usize;
        for (i, lat, outcome) in results {
            match outcome {
                Ok(log_z) => {
                    latencies.push(lat);
                    log_z_sum += log_z;
                    ok += 1;
                }
                Err(e) => failures.push((i, e)),
            }
        }
        Ok(BatchReport {
            engine: cfg.engine.label().to_string(),
            wall,
            latency: LatencySummary::from_samples(&latencies),
            failures,
            mean_log_z: if ok > 0 { log_z_sum / ok as f64 } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::infer::cases::{generate, CaseSpec};
    use crate::jt::triangulate::TriangulationHeuristic;

    fn setup() -> (Arc<JunctionTree>, Vec<Evidence>) {
        let net = embedded::mixed12();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let cases = generate(&net, &CaseSpec { n_cases: 24, observed_fraction: 0.25, seed: 77 });
        (jt, cases)
    }

    #[test]
    fn single_replica_processes_all_cases() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let cfg = BatchConfig {
            engine: EngineKind::Seq,
            engine_cfg: EngineConfig::default().with_threads(1),
            replicas: 1,
        };
        let report = runner.run(&cases, &cfg).unwrap();
        assert_eq!(report.latency.count + report.failures.len(), cases.len());
        assert!(report.failures.is_empty());
        assert!(report.throughput() > 0.0);
    }

    #[test]
    fn replicas_produce_same_aggregate_as_single() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let single = runner
            .run(
                &cases,
                &BatchConfig {
                    engine: EngineKind::Seq,
                    engine_cfg: EngineConfig::default().with_threads(1),
                    replicas: 1,
                },
            )
            .unwrap();
        let multi = runner
            .run(
                &cases,
                &BatchConfig {
                    engine: EngineKind::Seq,
                    engine_cfg: EngineConfig::default().with_threads(1),
                    replicas: 4,
                },
            )
            .unwrap();
        assert_eq!(single.latency.count, multi.latency.count);
        assert!((single.mean_log_z - multi.mean_log_z).abs() < 1e-9);
    }

    #[test]
    fn engines_agree_on_mean_log_z() {
        let (jt, cases) = setup();
        let runner = BatchRunner::new(jt);
        let mut means = Vec::new();
        for kind in EngineKind::ALL {
            let report = runner
                .run(
                    &cases,
                    &BatchConfig {
                        engine: kind,
                        engine_cfg: EngineConfig { threads: 2, min_chunk: 8, ..Default::default() },
                        replicas: 2,
                    },
                )
                .unwrap();
            means.push((kind, report.mean_log_z));
        }
        for (kind, m) in &means[1..] {
            assert!((means[0].1 - m).abs() < 1e-9, "{kind} mean_log_z {m} vs {}", means[0].1);
        }
    }
}
