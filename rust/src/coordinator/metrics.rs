//! Latency/throughput metrics for batch runs.

use std::time::Duration;

/// Summary statistics over per-case latencies.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencySummary {
    /// Number of samples.
    pub count: usize,
    /// Total wall time of the samples (sum of latencies).
    pub total: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
}

impl LatencySummary {
    /// Compute from raw samples (empty input → all zeros).
    pub fn from_samples(samples: &[Duration]) -> Self {
        if samples.is_empty() {
            let z = Duration::ZERO;
            return LatencySummary { count: 0, total: z, mean: z, min: z, max: z, p50: z, p95: z, p99: z };
        }
        let mut sorted: Vec<Duration> = samples.to_vec();
        sorted.sort_unstable();
        let total: Duration = sorted.iter().sum();
        let pct = |p: f64| -> Duration {
            let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        LatencySummary {
            count: sorted.len(),
            total,
            mean: total / sorted.len() as u32,
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }

    /// Count-weighted aggregate of summaries from independent sources.
    /// Counts, totals, min, and max combine exactly; the percentiles are
    /// count-weighted means of the parts' percentiles, which is **not** a
    /// percentile of the pooled samples and is biased whenever the parts'
    /// distributions differ (one slow backend among fast ones drags every
    /// merged percentile up proportionally to its count, instead of
    /// landing in the tail where it belongs). For that reason the cluster
    /// `STATS` path no longer uses this at all: it merges the backends'
    /// latency *histograms* bucket-wise (`obs::scrape::merged_percentiles`
    /// — bucket counts add losslessly, so pooled percentiles are exact up
    /// to bucket width) and reports `stats=partial` when a backend's
    /// histograms are missing, rather than blending a biased estimate
    /// into the headline. This merge remains for same-process batch
    /// shards, where the bias caveat above still applies. Zero-count
    /// parts contribute nothing; an all-empty input merges to the zero
    /// summary.
    pub fn merge(parts: &[LatencySummary]) -> LatencySummary {
        let count: usize = parts.iter().map(|p| p.count).sum();
        if count == 0 {
            return LatencySummary::from_samples(&[]);
        }
        let weighted = |pick: fn(&LatencySummary) -> Duration| -> Duration {
            let nanos: u128 = parts.iter().map(|p| pick(p).as_nanos() * p.count as u128).sum();
            nanos_to_duration(nanos / count as u128)
        };
        let total = parts.iter().map(|p| p.total).sum::<Duration>();
        LatencySummary {
            count,
            total,
            mean: nanos_to_duration(total.as_nanos() / count as u128),
            min: parts.iter().filter(|p| p.count > 0).map(|p| p.min).min().unwrap_or(Duration::ZERO),
            max: parts.iter().filter(|p| p.count > 0).map(|p| p.max).max().unwrap_or(Duration::ZERO),
            p50: weighted(|p| p.p50),
            p95: weighted(|p| p.p95),
            p99: weighted(|p| p.p99),
        }
    }

    /// Cases per second given the *wall* duration of the whole batch
    /// (which differs from `total` when replicas run concurrently).
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.count as f64 / wall.as_secs_f64()
    }
}

/// Saturating u128-nanoseconds → `Duration` (merge arithmetic works in
/// nanos to avoid `Duration` mul/div overflow on large counts).
fn nanos_to_duration(nanos: u128) -> Duration {
    Duration::from_nanos(u64::try_from(nanos).unwrap_or(u64::MAX))
}

impl std::fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3?} p50={:.3?} p95={:.3?} p99={:.3?} max={:.3?}",
            self.count, self.mean, self.p50, self.p95, self.p99, self.max
        )
    }
}

/// Fixed-capacity ring of latency samples for long-running servers.
///
/// Batch runs summarize a complete sample vector; a serving fleet cannot
/// hold every latency forever, so this keeps the most recent `cap`
/// samples (overwriting the oldest) while counting everything ever seen.
/// Percentiles are therefore over a sliding window, counts are lifetime.
#[derive(Clone, Debug)]
pub struct Reservoir {
    samples: Vec<Duration>,
    cap: usize,
    next: usize,
    seen: u64,
}

impl Reservoir {
    /// Create with room for `cap` samples (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Reservoir { samples: Vec::with_capacity(cap.min(1024)), cap, next: 0, seen: 0 }
    }

    /// Record one sample, overwriting the oldest once full.
    pub fn record(&mut self, d: Duration) {
        if self.samples.len() < self.cap {
            self.samples.push(d);
        } else {
            self.samples[self.next] = d;
            self.next = (self.next + 1) % self.cap;
        }
        self.seen += 1;
    }

    /// Lifetime number of samples recorded (including overwritten ones).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Summary over the samples currently held in the window.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary::from_samples(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_samples_are_zeroed() {
        let s = LatencySummary::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.throughput(Duration::from_secs(1)), 0.0);
    }

    #[test]
    fn percentiles_are_ordered() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        let s = LatencySummary::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(100));
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        // nearest-rank on 100 samples: index round(99 * .5) = 50 -> 51ms
        assert_eq!(s.p50, Duration::from_millis(51));
        assert_eq!(s.p95, Duration::from_millis(95));
    }

    #[test]
    fn throughput_uses_wall_time() {
        let samples = vec![Duration::from_millis(10); 100];
        let s = LatencySummary::from_samples(&samples);
        let t = s.throughput(Duration::from_secs(1));
        assert!((t - 100.0).abs() < 1e-9);
    }

    #[test]
    fn display_contains_fields() {
        let s = LatencySummary::from_samples(&[Duration::from_millis(5)]);
        let text = format!("{s}");
        assert!(text.contains("n=1"));
    }

    #[test]
    fn merge_is_count_weighted() {
        let a = LatencySummary::from_samples(&[Duration::from_millis(10); 30]);
        let b = LatencySummary::from_samples(&[Duration::from_millis(40); 10]);
        let m = LatencySummary::merge(&[a.clone(), b.clone()]);
        assert_eq!(m.count, 40);
        assert_eq!(m.min, Duration::from_millis(10));
        assert_eq!(m.max, Duration::from_millis(40));
        // (10ms·30 + 40ms·10) / 40 = 17.5ms, exact for constant parts
        assert_eq!(m.p50, Duration::from_micros(17_500));
        assert_eq!(m.p99, Duration::from_micros(17_500));
        assert_eq!(m.mean, Duration::from_micros(17_500));
        assert_eq!(m.total, Duration::from_millis(700));
        // empty parts are inert; merging one summary is the identity
        assert_eq!(LatencySummary::merge(&[a.clone(), LatencySummary::from_samples(&[])]), a);
        assert_eq!(LatencySummary::merge(&[b.clone()]), b);
        assert_eq!(LatencySummary::merge(&[]).count, 0);
        assert_eq!(LatencySummary::merge(&[]).p99, Duration::ZERO);
    }

    #[test]
    fn reservoir_keeps_a_sliding_window_and_lifetime_count() {
        let mut r = Reservoir::new(4);
        for ms in 1..=10u64 {
            r.record(Duration::from_millis(ms));
        }
        assert_eq!(r.seen(), 10);
        let s = r.summary();
        // window holds the most recent 4 samples: 7, 8, 9, 10 ms
        assert_eq!(s.count, 4);
        assert_eq!(s.min, Duration::from_millis(7));
        assert_eq!(s.max, Duration::from_millis(10));
    }

    #[test]
    fn reservoir_zero_capacity_is_clamped() {
        let mut r = Reservoir::new(0);
        r.record(Duration::from_millis(3));
        r.record(Duration::from_millis(5));
        assert_eq!(r.seen(), 2);
        assert_eq!(r.summary().count, 1);
        assert_eq!(r.summary().max, Duration::from_millis(5));
    }
}
