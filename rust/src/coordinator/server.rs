//! Line-protocol TCP inference server (`fastbn serve`).
//!
//! One engine replica per connection thread; the compiled tree is shared.
//! Protocol (one request per line, UTF-8):
//!
//! ```text
//! QUERY <target-var> [| ev1=state1 ev2=state2 ...]
//! MPE [| ev1=state1 ev2=state2 ...]
//! STATS
//! QUIT
//! ```
//!
//! Responses are single lines: `OK <state>=<prob> ...`, `STATS ...`,
//! `ERR <message>`. This is intentionally minimal — the coordinator story
//! for this paper is the batch runner; the server exists so the system is
//! deployable interactively without Python anywhere near the request path.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::engine::{EngineConfig, EngineKind};
use crate::jt::evidence::Evidence;
use crate::jt::state::TreeState;
use crate::jt::tree::JunctionTree;
use crate::Result;

/// Server handle; dropping it stops accepting new connections.
pub struct Server {
    inner: LineServer,
    queries: Arc<AtomicU64>,
}

impl Server {
    /// Start serving on `bind` (use port 0 for an ephemeral port).
    ///
    /// Each connection builds its engine and tree state *inside* its
    /// connection thread (engines are not `Send`); the accept loop,
    /// reaping, and shutdown are the shared [`LineServer`] scaffolding.
    pub fn start(jt: Arc<JunctionTree>, engine: EngineKind, cfg: EngineConfig, bind: &str) -> Result<Server> {
        let queries = Arc::new(AtomicU64::new(0));
        let factory_queries = Arc::clone(&queries);
        let inner = LineServer::start(bind, "fastbn-accept", move || {
            let jt = Arc::clone(&jt);
            let queries = Arc::clone(&factory_queries);
            let mut engine = engine.build(Arc::clone(&jt), &cfg);
            let mut state = TreeState::fresh(&jt);
            Box::new(move |line: &str| match respond(line, &jt, engine.as_mut(), &mut state, &queries) {
                Reply::Line(reply) => Some(reply),
                Reply::Quit => None,
            })
        })?;
        Ok(Server { inner, queries })
    }

    /// Bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// Number of queries served so far.
    pub fn queries_served(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    /// Finished connection threads joined by the accept loop so far.
    pub fn reaped_connections(&self) -> u64 {
        self.inner.reaped_connections()
    }

    /// Stop accepting and wait for the accept loop to end.
    pub fn shutdown(mut self) {
        self.inner.stop_and_join();
    }
}

/// Scaffolding shared by the session servers (fleet, cluster): a bound
/// listener, the nonblocking accept loop on its own thread, one handler
/// thread per connection running [`serve_lines`] over a responder that
/// `make_responder` builds *inside* the connection thread (so responders
/// need not be `Send`), plus live/reaped connection gauges. The public
/// server types wrap this and add their domain handle (fleet, cluster).
pub(crate) struct LineServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    reaped: Arc<AtomicU64>,
}

/// Decrements the live-connection gauge however the handler exits.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

impl LineServer {
    /// Bind `bind` and serve until dropped. Each accepted connection gets
    /// its own responder (`None` from the responder ends that session).
    pub(crate) fn start<F>(bind: &str, thread_name: &str, make_responder: F) -> crate::Result<LineServer>
    where
        F: Fn() -> Box<dyn FnMut(&str) -> Option<String>> + Clone + Send + 'static,
    {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let reaped = Arc::new(AtomicU64::new(0));

        let accept_stop = Arc::clone(&stop);
        let accept_active = Arc::clone(&active);
        let accept_reaped = Arc::clone(&reaped);
        let accept_thread = std::thread::Builder::new().name(thread_name.to_string()).spawn(move || {
            run_accept_loop(&listener, &accept_stop, &accept_reaped, |stream| {
                let make_responder = make_responder.clone();
                let stop = Arc::clone(&accept_stop);
                accept_active.fetch_add(1, Ordering::Relaxed);
                let guard = ConnGuard(Arc::clone(&accept_active));
                std::thread::spawn(move || {
                    let _guard = guard;
                    let mut respond = make_responder();
                    let _ = serve_lines(stream, &stop, |line| respond(line));
                })
            });
        })?;

        Ok(LineServer { addr, stop, accept_thread: Some(accept_thread), active, reaped })
    }

    /// Bound address (useful with port 0).
    pub(crate) fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Live connection count.
    pub(crate) fn active_connections(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// Finished connection threads joined by the accept loop so far.
    pub(crate) fn reaped_connections(&self) -> u64 {
        self.reaped.load(Ordering::Relaxed)
    }

    /// The live-connection gauge itself — wrapping servers register it
    /// with an observability registry (gauges pull at render time, so
    /// they need the handle, not a snapshot).
    pub(crate) fn active_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.active)
    }

    /// The reaped-connection counter (see [`LineServer::active_handle`]).
    pub(crate) fn reaped_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.reaped)
    }

    /// Stop accepting and wait for every thread to end (idempotent).
    pub(crate) fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for LineServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Nonblocking accept loop shared by the single-tree server and
/// [`LineServer`]: `spawn_conn` starts a handler thread per connection;
/// finished handler threads are reaped (joined, counted in `reaped`) on
/// every tick so the handle list stays proportional to *live*
/// connections. Returns once `stop` is set (or the listener dies), after
/// joining every handler.
pub(crate) fn run_accept_loop(
    listener: &TcpListener,
    stop: &AtomicBool,
    reaped: &AtomicU64,
    mut spawn_conn: impl FnMut(TcpStream) -> std::thread::JoinHandle<()>,
) {
    let mut conn_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        for t in std::mem::take(&mut conn_threads) {
            if t.is_finished() {
                let _ = t.join();
                reaped.fetch_add(1, Ordering::Relaxed);
            } else {
                conn_threads.push(t);
            }
        }
        match listener.accept() {
            Ok((stream, _)) => conn_threads.push(spawn_conn(stream)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    for t in conn_threads {
        let _ = t.join();
    }
}

/// Line-serving loop shared by both servers: read one request line, hand
/// it to `respond`, write the single-line reply. `None` from `respond`
/// ends the session (QUIT). A read timeout mid-request keeps the bytes
/// received so far in the buffer — a slow client's half-sent line is
/// completed by later reads, never silently dropped. Lines are
/// accumulated as bytes (not via `read_line`) so a timeout landing
/// mid-UTF-8-character cannot truncate what was already received.
pub(crate) fn serve_lines(
    stream: TcpStream,
    stop: &AtomicBool,
    mut respond: impl FnMut(&str) -> Option<String>,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();

    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {}
            Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) => {
                continue; // partial bytes stay in `buf`; the next read appends
            }
            Err(e) => return Err(e.into()),
        }
        let response = respond(&String::from_utf8_lossy(&buf));
        buf.clear();
        let Some(response) = response else { return Ok(()) };
        writer.write_all(response.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

enum Reply {
    Line(String),
    Quit,
}

/// Split `QUERY` argument text into a target and `var=state` tokens;
/// both protocols accept `target [| var=state …]`. `Err` carries the
/// message to send after `ERR `.
pub(crate) fn parse_query_args(rest: &str) -> std::result::Result<(&str, Vec<(&str, &str)>), String> {
    let (target, ev_text) = match rest.split_once('|') {
        Some((t, e)) => (t.trim(), e.trim()),
        None => (rest, ""),
    };
    if target.is_empty() {
        return Err("usage: QUERY <var> [| ev=state ...]".to_string());
    }
    let mut pairs = Vec::new();
    for tok in ev_text.split_whitespace() {
        match tok.split_once('=') {
            Some((v, s)) => pairs.push((v, s)),
            None => return Err(format!("bad evidence token {tok:?} (want var=state)")),
        }
    }
    Ok((target, pairs))
}

/// Split `MPE` argument text into `var=state` tokens; both protocols
/// accept `[| var=state …]` — no target, the answer assigns every
/// variable. `Err` carries the message to send after `ERR `.
pub(crate) fn parse_mpe_args(rest: &str) -> std::result::Result<Vec<(&str, &str)>, String> {
    let ev_text = match rest.split_once('|') {
        Some((before, e)) if before.trim().is_empty() => e.trim(),
        None if rest.is_empty() => "",
        _ => return Err("usage: MPE [| ev=state ...]".to_string()),
    };
    let mut pairs = Vec::new();
    for tok in ev_text.split_whitespace() {
        match tok.split_once('=') {
            Some((v, s)) => pairs.push((v, s)),
            None => return Err(format!("bad evidence token {tok:?} (want var=state)")),
        }
    }
    Ok(pairs)
}

/// The `OK mpe logp=… <var>=<state> …` reply line both protocols share:
/// the joint log-probability of the completion, then one `var=state`
/// token per variable in id order (evidence variables at their observed
/// states). One place owns the wire precision, like
/// [`format_ok_posterior`].
pub(crate) fn format_ok_mpe(net: &crate::bn::network::Network, res: &crate::jt::mpe::MpeResult) -> String {
    let mut line = format!("OK mpe logp={:.6}", res.log_prob);
    for (var, &s) in net.vars.iter().zip(&res.assignment) {
        line.push_str(&format!(" {}={}", var.name, var.states[s]));
    }
    line
}

/// The `OK <state>=<prob> … logZ=…` reply line both protocols share —
/// one place owns the wire precision. Approximate-tier posteriors append
/// their accuracy contract: `tier=approx ci95=<worst half-width>
/// ess=<effective samples>` — clients can tell *which tier answered* and
/// how tight the estimate is from the reply alone.
pub(crate) fn format_ok_posterior(net: &crate::bn::network::Network, v: usize, post: &crate::infer::query::Posteriors) -> String {
    let var = &net.vars[v];
    let entries: Vec<String> = var.states.iter().zip(&post.probs[v]).map(|(s, p)| format!("{s}={p:.6}")).collect();
    let mut line = format!("OK {} logZ={:.6}", entries.join(" "), post.log_z);
    if let Some(info) = &post.approx {
        line.push_str(&format!(" tier=approx ci95={:.6} ess={:.0}", info.max_half_width(), info.effective_samples));
    }
    line
}

fn respond(
    line: &str,
    jt: &JunctionTree,
    engine: &mut dyn crate::engine::Engine,
    state: &mut TreeState,
    queries: &AtomicU64,
) -> Reply {
    let line = line.trim();
    if line.is_empty() {
        return Reply::Line("ERR empty request".into());
    }
    let mut parts = line.splitn(2, ' ');
    let verb = parts.next().unwrap_or("");
    let rest = parts.next().unwrap_or("").trim();
    match verb.to_ascii_uppercase().as_str() {
        "QUIT" => Reply::Quit,
        "STATS" => {
            let s = jt.stats();
            Reply::Line(format!(
                "STATS net={} engine={} cliques={} width={} entries={} queries={}",
                jt.net.name,
                engine.name(),
                s.cliques,
                s.width,
                s.total_clique_entries,
                queries.load(Ordering::Relaxed)
            ))
        }
        "QUERY" => {
            let (target, pairs) = match parse_query_args(rest) {
                Ok(parsed) => parsed,
                Err(msg) => return Reply::Line(format!("ERR {msg}")),
            };
            let ev = match Evidence::from_pairs(&jt.net, &pairs) {
                Ok(ev) => ev,
                Err(e) => return Reply::Line(format!("ERR {e}")),
            };
            let v = match jt.net.var_id(target) {
                Ok(v) => v,
                Err(e) => return Reply::Line(format!("ERR {e}")),
            };
            match engine.infer(state, &ev) {
                Ok(post) => {
                    queries.fetch_add(1, Ordering::Relaxed);
                    Reply::Line(format_ok_posterior(&jt.net, v, &post))
                }
                Err(e) => Reply::Line(format!("ERR {e}")),
            }
        }
        "MPE" => {
            let pairs = match parse_mpe_args(rest) {
                Ok(pairs) => pairs,
                Err(msg) => return Reply::Line(format!("ERR {msg}")),
            };
            let ev = match Evidence::from_pairs(&jt.net, &pairs) {
                Ok(ev) => ev,
                Err(e) => return Reply::Line(format!("ERR {e}")),
            };
            match engine.mpe(state, &ev) {
                Ok(res) => {
                    queries.fetch_add(1, Ordering::Relaxed);
                    Reply::Line(format_ok_mpe(&jt.net, &res))
                }
                Err(e) => Reply::Line(format!("ERR {e}")),
            }
        }
        other => Reply::Line(format!("ERR unknown verb {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::embedded;
    use crate::jt::triangulate::TriangulationHeuristic;
    use std::io::{BufRead, BufReader, Write};

    fn ask(addr: std::net::SocketAddr, requests: &[&str]) -> Vec<String> {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = Vec::new();
        for r in requests {
            stream.write_all(r.as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            out.push(line.trim().to_string());
        }
        out
    }

    #[test]
    fn serves_queries_and_stats() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let server = Server::start(
            jt,
            EngineKind::Seq,
            EngineConfig::default().with_threads(1),
            "127.0.0.1:0",
        )
        .unwrap();
        let addr = server.addr();

        let replies = ask(addr, &["QUERY lung | smoke=yes", "QUERY lung", "STATS", "BOGUS x"]);
        assert!(replies[0].starts_with("OK yes=0.1000"), "{}", replies[0]);
        assert!(replies[1].starts_with("OK yes=0.055"), "{}", replies[1]);
        assert!(replies[2].contains("cliques=6"), "{}", replies[2]);
        assert!(replies[3].starts_with("ERR"), "{}", replies[3]);
        assert_eq!(server.queries_served(), 2);
        server.shutdown();
    }

    #[test]
    fn error_paths_are_reported_not_fatal() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let server = Server::start(
            jt,
            EngineKind::Hybrid,
            EngineConfig::default().with_threads(2),
            "127.0.0.1:0",
        )
        .unwrap();
        let replies = ask(
            server.addr(),
            &[
                "QUERY nosuchvar",
                "QUERY lung | smoke=bogus",
                "QUERY lung | either=no lung=yes", // impossible
                "QUERY lung | smoke=no",           // still works after errors
            ],
        );
        assert!(replies[0].starts_with("ERR"));
        assert!(replies[1].starts_with("ERR"));
        assert!(replies[2].starts_with("ERR"));
        assert!(replies[3].starts_with("OK yes=0.01"), "{}", replies[3]);
        server.shutdown();
    }

    #[test]
    fn mpe_verb_returns_a_full_assignment_line() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let server = Server::start(
            jt,
            EngineKind::Seq,
            EngineConfig::default().with_threads(1),
            "127.0.0.1:0",
        )
        .unwrap();
        let replies = ask(
            server.addr(),
            &[
                "MPE",
                "MPE | asia=yes xray=yes",
                "MPE | either=no lung=yes", // impossible evidence
                "MPE asia=yes",             // evidence without the pipe
                "MPE | asia",               // bad token
            ],
        );
        // no evidence: one token per variable, all eight of asia's
        assert!(replies[0].starts_with("OK mpe logp=-"), "{}", replies[0]);
        assert_eq!(replies[0].split_whitespace().count(), 2 + 8, "{}", replies[0]);
        // evidence variables come back at their observed states
        assert!(replies[1].contains(" asia=yes"), "{}", replies[1]);
        assert!(replies[1].contains(" xray=yes"), "{}", replies[1]);
        assert!(replies[2].starts_with("ERR evidence is inconsistent"), "{}", replies[2]);
        assert!(replies[3].starts_with("ERR usage: MPE"), "{}", replies[3]);
        assert!(replies[4].starts_with("ERR bad evidence token"), "{}", replies[4]);
        assert_eq!(server.queries_served(), 2);
        server.shutdown();
    }

    #[test]
    fn finished_connections_are_reaped_before_shutdown() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let server = Server::start(
            jt,
            EngineKind::Seq,
            EngineConfig::default().with_threads(1),
            "127.0.0.1:0",
        )
        .unwrap();
        for _ in 0..3 {
            let replies = ask(server.addr(), &["QUERY lung", "QUIT"]);
            assert!(replies[0].starts_with("OK"), "{}", replies[0]);
        }
        // the accept loop ticks every ~5ms; finished handlers must be
        // joined while the server is still running, not at shutdown
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while server.reaped_connections() < 3 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(server.reaped_connections() >= 3, "reaped {}", server.reaped_connections());
        server.shutdown();
    }

    #[test]
    fn slow_clients_do_not_lose_partial_lines() {
        let net = embedded::asia();
        let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
        let server = Server::start(
            jt,
            EngineKind::Seq,
            EngineConfig::default().with_threads(1),
            "127.0.0.1:0",
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // half a request, a pause longer than the 200ms read timeout, the rest
        stream.write_all(b"QUERY lu").unwrap();
        stream.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(450));
        stream.write_all(b"ng | smoke=yes\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK yes=0.1000"), "{line}");
        server.shutdown();
    }
}
