//! The batch-inference coordinator — the L3 serving loop.
//!
//! The paper's evaluation protocol runs 2 000 evidence cases through one
//! engine per network. This module owns that loop as a service-shaped
//! component: a [`batch::BatchRunner`] that shards cases over engine
//! replicas (the paper's protocol is the `replicas = 1` special case,
//! intra-case parallel; `replicas > 1` adds the case-level dimension as an
//! extension benchmarked in `benches/ablation.rs`), latency/throughput
//! [`metrics`], and a line-protocol TCP [`server`] for interactive use
//! (`fastbn serve`).
//!
//! Everything here serves **one** compiled tree per process. Serving many
//! networks (and streaming evidence sessions) from a single process is the
//! [`crate::fleet`] layer, which builds on the same engines and metrics.

pub mod batch;
pub mod metrics;
pub mod server;

pub use batch::{BatchConfig, BatchReport, BatchRunner};
