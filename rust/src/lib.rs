//! # fastbn — Fast Parallel Exact Inference on Bayesian Networks
//!
//! A reproduction of *"POSTER: Fast Parallel Exact Inference on Bayesian
//! Networks"* (Jiang, Wen, Mansoor, Mian — PPoPP'23): **Fast-BNI**, a
//! junction-tree exact-inference engine for discrete Bayesian networks with
//! hybrid inter-/intra-clique parallelism on multi-core CPUs, plus the four
//! comparison implementations from the paper's Table 1.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the coordinator: Bayesian-network model and I/O
//!   ([`bn`]), junction-tree compilation ([`jt`]), the six propagation
//!   engines ([`engine`]), pool-parallel structure + parameter learning
//!   from data ([`learn`]), a batch-inference coordinator
//!   ([`coordinator`]), a multi-network serving fleet ([`fleet`]), a
//!   cross-process cluster tier routing networks over fleet processes
//!   ([`cluster`]), and a PJRT runtime that executes AOT-compiled XLA
//!   table-op kernels ([`runtime`]).
//! * **L2 (python/compile/model.py)** — JAX message-pass compute graph.
//! * **L1 (python/compile/kernels/)** — Pallas table-op kernels, lowered
//!   (interpret=True) into the same HLO artifacts the runtime loads.
//!
//! ## Quick start
//!
//! ```
//! use std::sync::Arc;
//! use fastbn::prelude::*;
//!
//! let net = fastbn::bn::embedded::asia();
//! let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
//! let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig::default());
//! let mut state = TreeState::fresh(&jt);
//! let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
//! let post = engine.infer(&mut state, &ev).unwrap();
//! let p = post.marginal(&net, "lung").unwrap();
//! assert!((p[0] - 0.1).abs() < 1e-9); // P(lung=yes | smoke=yes) = 0.1
//! ```

pub mod bench;
pub mod bn;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod infer;
pub mod jt;
pub mod learn;
pub mod obs;
pub mod prop;
pub mod rng;
pub mod runtime;

/// Crate-wide error type.
///
/// Hand-rolled `Display`/`Error` impls keep the default build free of any
/// external dependency (this offline environment has no crates.io access).
#[derive(Debug)]
pub enum Error {
    /// Parse error in a BIF / Hugin source, with a 1-based line number.
    Parse { line: usize, msg: String },
    /// Structural validation failure (CPT shapes, cycles, duplicates).
    InvalidNetwork(String),
    /// Variable name not present in the network.
    UnknownVariable(String),
    /// State name not present on a variable.
    UnknownState { var: String, state: String },
    /// The entered evidence has probability zero.
    InconsistentEvidence,
    /// Junction-tree compilation or invariant failure.
    JunctionTree(String),
    /// Accelerator-runtime (PJRT/XLA) failure.
    Runtime(String),
    /// Propagated I/O failure.
    Io(std::io::Error),
    /// Free-form error message.
    Msg(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            Error::InvalidNetwork(m) => write!(f, "invalid network: {m}"),
            Error::UnknownVariable(v) => write!(f, "unknown variable: {v}"),
            Error::UnknownState { var, state } => {
                write!(f, "unknown state {state:?} for variable {var:?}")
            }
            Error::InconsistentEvidence => write!(f, "evidence is inconsistent (P(e) = 0)"),
            Error::JunctionTree(m) => write!(f, "junction tree error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand for a free-form error message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Convenience re-exports covering the common read-eval-query flow.
pub mod prelude {
    pub use crate::bn::network::Network;
    pub use crate::engine::{Engine, EngineConfig, EngineKind};
    pub use crate::infer::query::Posteriors;
    pub use crate::jt::evidence::Evidence;
    pub use crate::jt::state::TreeState;
    pub use crate::jt::tree::JunctionTree;
    pub use crate::jt::triangulate::TriangulationHeuristic;
    pub use crate::{Error, Result};
}
