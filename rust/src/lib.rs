//! # fastbn — Fast Parallel Exact Inference on Bayesian Networks
//!
//! A reproduction of *"POSTER: Fast Parallel Exact Inference on Bayesian
//! Networks"* (Jiang, Wen, Mansoor, Mian — PPoPP'23): **Fast-BNI**, a
//! junction-tree exact-inference engine for discrete Bayesian networks with
//! hybrid inter-/intra-clique parallelism on multi-core CPUs, plus the four
//! comparison implementations from the paper's Table 1.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the coordinator: Bayesian-network model and I/O
//!   ([`bn`]), junction-tree compilation ([`jt`]), the six propagation
//!   engines ([`engine`]), a batch-inference coordinator ([`coordinator`]),
//!   and a PJRT runtime that executes AOT-compiled XLA table-op kernels
//!   ([`runtime`]).
//! * **L2 (python/compile/model.py)** — JAX message-pass compute graph.
//! * **L1 (python/compile/kernels/)** — Pallas table-op kernels, lowered
//!   (interpret=True) into the same HLO artifacts the runtime loads.
//!
//! ## Quick start
//!
//! ```
//! use std::sync::Arc;
//! use fastbn::prelude::*;
//!
//! let net = fastbn::bn::embedded::asia();
//! let jt = Arc::new(JunctionTree::compile(&net, TriangulationHeuristic::MinFill).unwrap());
//! let mut engine = EngineKind::Hybrid.build(Arc::clone(&jt), &EngineConfig::default());
//! let mut state = TreeState::fresh(&jt);
//! let ev = Evidence::from_pairs(&net, &[("smoke", "yes")]).unwrap();
//! let post = engine.infer(&mut state, &ev).unwrap();
//! let p = post.marginal(&net, "lung").unwrap();
//! assert!((p[0] - 0.1).abs() < 1e-9); // P(lung=yes | smoke=yes) = 0.1
//! ```

pub mod bench;
pub mod bn;
pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod infer;
pub mod jt;
pub mod prop;
pub mod rng;
pub mod runtime;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("invalid network: {0}")]
    InvalidNetwork(String),
    #[error("unknown variable: {0}")]
    UnknownVariable(String),
    #[error("unknown state {state:?} for variable {var:?}")]
    UnknownState { var: String, state: String },
    #[error("evidence is inconsistent (P(e) = 0)")]
    InconsistentEvidence,
    #[error("junction tree error: {0}")]
    JunctionTree(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("{0}")]
    Msg(String),
}

impl Error {
    /// Shorthand for a free-form error message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Convenience re-exports covering the common read-eval-query flow.
pub mod prelude {
    pub use crate::bn::network::Network;
    pub use crate::engine::{Engine, EngineConfig, EngineKind};
    pub use crate::infer::query::Posteriors;
    pub use crate::jt::evidence::Evidence;
    pub use crate::jt::state::TreeState;
    pub use crate::jt::tree::JunctionTree;
    pub use crate::jt::triangulate::TriangulationHeuristic;
    pub use crate::{Error, Result};
}
