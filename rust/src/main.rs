//! `fastbn` — CLI entry point. See [`fastbn::cli`] for commands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(fastbn::cli::run(argv));
}
