//! Fleet-wide metrics: per-network query counts, qps, and latency
//! percentiles.
//!
//! Each network gets a lifetime query/error counter and a bounded
//! [`Reservoir`] of recent service times (see
//! [`crate::coordinator::metrics`]); the `STATS` protocol verb renders a
//! snapshot as one line so any line-protocol client can scrape it.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{LatencySummary, Reservoir};
use crate::fleet::registry::Tier;

/// Samples kept per network (sliding window for percentiles).
const WINDOW: usize = 4096;

/// An approx query is flagged degenerate when `ESS/n` drops below this —
/// the classic likelihood-weighting failure mode on deep-tail evidence
/// (a handful of samples carry nearly all the weight).
pub const DEGENERATE_ESS_FRACTION: f64 = 0.1;

struct NetCounters {
    tier: Tier,
    queries: u64,
    errors: u64,
    reservoir: Reservoir,
    /// Running sum of per-query relative weight variance (`n/ESS − 1`) —
    /// approx-tier health; see [`FleetMetrics::record_approx`].
    wvar_sum: f64,
    /// Approx queries folded into `wvar_sum`.
    wvar_n: u64,
    /// Queries whose ESS collapsed below [`DEGENERATE_ESS_FRACTION`] of
    /// the drawn samples — evidence deep in the tail, LW degenerating.
    degen: u64,
}

/// Point-in-time view of one network's serving metrics.
#[derive(Clone, Debug)]
pub struct NetSnapshot {
    /// Network name.
    pub net: String,
    /// Which engine family answers this network's queries.
    pub tier: Tier,
    /// Successful queries served (lifetime).
    pub queries: u64,
    /// Failed queries (lifetime) — bad evidence, unknown targets, etc.
    pub errors: u64,
    /// Successful queries per second of fleet uptime.
    pub qps: f64,
    /// Latency summary over the recent-sample window.
    pub latency: LatencySummary,
    /// Mean relative weight variance (`n/ESS − 1`) over this network's
    /// approx queries; `None` until one has been recorded.
    pub weight_variance: Option<f64>,
    /// Approx queries whose ESS collapsed (see
    /// [`DEGENERATE_ESS_FRACTION`]).
    pub degenerate: u64,
}

/// Aggregates serving metrics across every network in a fleet.
pub struct FleetMetrics {
    started: Instant,
    nets: Mutex<BTreeMap<String, NetCounters>>,
}

impl Default for FleetMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl FleetMetrics {
    /// Create, stamping the fleet start time (the qps denominator).
    pub fn new() -> Self {
        FleetMetrics { started: Instant::now(), nets: Mutex::new(BTreeMap::new()) }
    }

    /// Mint a network's counters entry (idempotent). Entry lifecycle is
    /// owned by the fleet's load/evict path, so `STATS` lists preloaded
    /// but not-yet-queried networks with `queries=0`. The tier is stamped
    /// so `STATS` says which engine family answered (a re-`ensure` after a
    /// reload refreshes it).
    pub fn ensure(&self, net: &str, tier: Tier) {
        self.nets
            .lock()
            .unwrap()
            .entry(net.to_string())
            .and_modify(|c| c.tier = tier)
            .or_insert_with(|| NetCounters {
                tier,
                queries: 0,
                errors: 0,
                reservoir: Reservoir::new(WINDOW),
                wvar_sum: 0.0,
                wvar_n: 0,
                degen: 0,
            });
    }

    /// Record one query against `net`: its service time and outcome.
    ///
    /// A no-op for networks without an entry — minting here would let an
    /// in-flight query racing an eviction resurrect a removed network's
    /// counters, leaving `STATS` and `NETS` permanently disagreeing.
    pub fn record(&self, net: &str, service: Duration, ok: bool) {
        let mut nets = self.nets.lock().unwrap();
        let Some(c) = nets.get_mut(net) else { return };
        if ok {
            c.queries += 1;
            c.reservoir.record(service);
        } else {
            c.errors += 1;
        }
    }

    /// Record the sampling health of one successful approx-tier query
    /// (the [`crate::infer::query::ApproxInfo`] the posterior carried).
    /// Returns whether this query was degenerate (`ESS/n` below
    /// [`DEGENERATE_ESS_FRACTION`]) so the caller can bump its registry
    /// counter. Same anti-resurrection rule as [`FleetMetrics::record`]:
    /// a no-op (returning `false`) for networks without an entry.
    pub fn record_approx(&self, net: &str, info: &crate::infer::query::ApproxInfo) -> bool {
        let mut nets = self.nets.lock().unwrap();
        let Some(c) = nets.get_mut(net) else { return false };
        c.wvar_sum += info.relative_weight_variance();
        c.wvar_n += 1;
        let degenerate =
            info.n_samples > 0 && info.effective_samples / info.n_samples as f64 < DEGENERATE_ESS_FRACTION;
        if degenerate {
            c.degen += 1;
        }
        degenerate
    }

    /// Drop a network's counters — called on registry eviction so a fleet
    /// cycling through many networks doesn't grow `STATS` (and memory)
    /// without bound.
    pub fn remove(&self, net: &str) {
        self.nets.lock().unwrap().remove(net);
    }

    /// Fleet uptime.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Per-network snapshots, sorted by name.
    pub fn snapshot(&self) -> Vec<NetSnapshot> {
        let uptime = self.uptime().as_secs_f64().max(1e-9);
        let nets = self.nets.lock().unwrap();
        nets.iter()
            .map(|(name, c)| NetSnapshot {
                net: name.clone(),
                tier: c.tier,
                queries: c.queries,
                errors: c.errors,
                qps: c.queries as f64 / uptime,
                latency: c.reservoir.summary(),
                weight_variance: (c.wvar_n > 0).then(|| c.wvar_sum / c.wvar_n as f64),
                degenerate: c.degen,
            })
            .collect()
    }

    /// Render the single-line `STATS` reply:
    /// `STATS uptime_ms=… nets=N | <net> queries=… errors=… qps=… p50_us=… p99_us=… tier=… | …`
    ///
    /// Approx-tier networks additionally carry ` wvar=… degen=…` —
    /// appended after `tier=`, so older scrapers (which ignore unknown
    /// `key=value` fields, as `cluster::parse_backend_stats` does) keep
    /// parsing.
    pub fn render(&self) -> String {
        let snaps = self.snapshot();
        let mut out = format!("STATS uptime_ms={} nets={}", self.uptime().as_millis(), snaps.len());
        for s in &snaps {
            out.push_str(&format!(
                " | {} queries={} errors={} qps={:.2} p50_us={} p99_us={} tier={}",
                s.net,
                s.queries,
                s.errors,
                s.qps,
                s.latency.p50.as_micros(),
                s.latency.p99.as_micros(),
                s.tier
            ));
            if s.tier == Tier::Approx {
                out.push_str(&format!(" wvar={:.3} degen={}", s.weight_variance.unwrap_or(0.0), s.degenerate));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_without_ensure_is_a_noop() {
        let m = FleetMetrics::new();
        m.record("ghost", Duration::from_micros(1), true);
        assert!(m.snapshot().is_empty());
        m.ensure("asia", Tier::Exact);
        m.ensure("asia", Tier::Exact); // idempotent
        assert!(m.render().contains("| asia queries=0 errors=0"), "{}", m.render());
        m.remove("asia");
        assert!(m.snapshot().is_empty());
    }

    #[test]
    fn records_split_by_network_and_outcome() {
        let m = FleetMetrics::new();
        m.ensure("asia", Tier::Exact);
        m.ensure("cancer", Tier::Approx);
        m.record("asia", Duration::from_micros(100), true);
        m.record("asia", Duration::from_micros(300), true);
        m.record("asia", Duration::from_micros(200), false);
        m.record("cancer", Duration::from_micros(50), true);
        let snaps = m.snapshot();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].net, "asia");
        assert_eq!(snaps[0].queries, 2);
        assert_eq!(snaps[0].errors, 1);
        assert_eq!(snaps[0].tier, Tier::Exact);
        // failed queries don't pollute the latency window
        assert_eq!(snaps[0].latency.count, 2);
        assert_eq!(snaps[1].net, "cancer");
        assert_eq!(snaps[1].queries, 1);
        assert_eq!(snaps[1].tier, Tier::Approx);
        assert!(snaps[0].qps > 0.0);
    }

    #[test]
    fn render_is_one_line_with_per_net_fields() {
        let m = FleetMetrics::new();
        m.ensure("asia", Tier::Approx);
        m.record("asia", Duration::from_micros(150), true);
        let line = m.render();
        assert!(line.starts_with("STATS uptime_ms="), "{line}");
        assert!(line.contains("nets=1"), "{line}");
        assert!(line.contains("| asia queries=1 errors=0"), "{line}");
        assert!(line.contains("p50_us=150"), "{line}");
        assert!(line.contains("p99_us=150"), "{line}");
        assert!(line.contains("tier=approx"), "{line}");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn approx_health_fields_render_for_approx_nets_only() {
        use crate::infer::query::ApproxInfo;
        let m = FleetMetrics::new();
        m.ensure("exact-net", Tier::Exact);
        m.ensure("approx-net", Tier::Approx);
        // healthy query: ESS = n → wvar 0, not degenerate
        assert!(!m.record_approx("approx-net", &ApproxInfo { n_samples: 1000, effective_samples: 1000.0 }));
        // degenerate query: ESS/n = 0.05 < 0.1; wvar = 1000/50 − 1 = 19
        assert!(m.record_approx("approx-net", &ApproxInfo { n_samples: 1000, effective_samples: 50.0 }));
        // anti-resurrection: unknown nets never mint entries
        assert!(!m.record_approx("ghost", &ApproxInfo { n_samples: 10, effective_samples: 1.0 }));
        let line = m.render();
        assert!(line.contains("tier=approx wvar=9.500 degen=1"), "{line}");
        let exact = line.split(" | ").find(|s| s.starts_with("exact-net")).unwrap();
        assert!(!exact.contains("wvar="), "{exact}");
        let snaps = m.snapshot();
        let approx = snaps.iter().find(|s| s.net == "approx-net").unwrap();
        assert_eq!(approx.weight_variance, Some(9.5));
        assert_eq!(approx.degenerate, 1);
        assert_eq!(snaps.iter().find(|s| s.net == "exact-net").unwrap().weight_variance, None);
    }

    #[test]
    fn empty_fleet_renders_zero_nets() {
        let m = FleetMetrics::new();
        assert!(m.render().contains("nets=0"));
        assert!(m.snapshot().is_empty());
    }
}
