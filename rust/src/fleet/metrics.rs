//! Fleet-wide metrics: per-network query counts, qps, and latency
//! percentiles.
//!
//! Each network gets a lifetime query/error counter and a bounded
//! [`Reservoir`] of recent service times (see
//! [`crate::coordinator::metrics`]); the `STATS` protocol verb renders a
//! snapshot as one line so any line-protocol client can scrape it.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{LatencySummary, Reservoir};
use crate::fleet::registry::Tier;

/// Samples kept per network (sliding window for percentiles).
const WINDOW: usize = 4096;

struct NetCounters {
    tier: Tier,
    queries: u64,
    errors: u64,
    reservoir: Reservoir,
}

/// Point-in-time view of one network's serving metrics.
#[derive(Clone, Debug)]
pub struct NetSnapshot {
    /// Network name.
    pub net: String,
    /// Which engine family answers this network's queries.
    pub tier: Tier,
    /// Successful queries served (lifetime).
    pub queries: u64,
    /// Failed queries (lifetime) — bad evidence, unknown targets, etc.
    pub errors: u64,
    /// Successful queries per second of fleet uptime.
    pub qps: f64,
    /// Latency summary over the recent-sample window.
    pub latency: LatencySummary,
}

/// Aggregates serving metrics across every network in a fleet.
pub struct FleetMetrics {
    started: Instant,
    nets: Mutex<BTreeMap<String, NetCounters>>,
}

impl Default for FleetMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl FleetMetrics {
    /// Create, stamping the fleet start time (the qps denominator).
    pub fn new() -> Self {
        FleetMetrics { started: Instant::now(), nets: Mutex::new(BTreeMap::new()) }
    }

    /// Mint a network's counters entry (idempotent). Entry lifecycle is
    /// owned by the fleet's load/evict path, so `STATS` lists preloaded
    /// but not-yet-queried networks with `queries=0`. The tier is stamped
    /// so `STATS` says which engine family answered (a re-`ensure` after a
    /// reload refreshes it).
    pub fn ensure(&self, net: &str, tier: Tier) {
        self.nets
            .lock()
            .unwrap()
            .entry(net.to_string())
            .and_modify(|c| c.tier = tier)
            .or_insert_with(|| NetCounters { tier, queries: 0, errors: 0, reservoir: Reservoir::new(WINDOW) });
    }

    /// Record one query against `net`: its service time and outcome.
    ///
    /// A no-op for networks without an entry — minting here would let an
    /// in-flight query racing an eviction resurrect a removed network's
    /// counters, leaving `STATS` and `NETS` permanently disagreeing.
    pub fn record(&self, net: &str, service: Duration, ok: bool) {
        let mut nets = self.nets.lock().unwrap();
        let Some(c) = nets.get_mut(net) else { return };
        if ok {
            c.queries += 1;
            c.reservoir.record(service);
        } else {
            c.errors += 1;
        }
    }

    /// Drop a network's counters — called on registry eviction so a fleet
    /// cycling through many networks doesn't grow `STATS` (and memory)
    /// without bound.
    pub fn remove(&self, net: &str) {
        self.nets.lock().unwrap().remove(net);
    }

    /// Fleet uptime.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Per-network snapshots, sorted by name.
    pub fn snapshot(&self) -> Vec<NetSnapshot> {
        let uptime = self.uptime().as_secs_f64().max(1e-9);
        let nets = self.nets.lock().unwrap();
        nets.iter()
            .map(|(name, c)| NetSnapshot {
                net: name.clone(),
                tier: c.tier,
                queries: c.queries,
                errors: c.errors,
                qps: c.queries as f64 / uptime,
                latency: c.reservoir.summary(),
            })
            .collect()
    }

    /// Render the single-line `STATS` reply:
    /// `STATS uptime_ms=… nets=N | <net> queries=… errors=… qps=… p50_us=… p99_us=… tier=… | …`
    pub fn render(&self) -> String {
        let snaps = self.snapshot();
        let mut out = format!("STATS uptime_ms={} nets={}", self.uptime().as_millis(), snaps.len());
        for s in &snaps {
            out.push_str(&format!(
                " | {} queries={} errors={} qps={:.2} p50_us={} p99_us={} tier={}",
                s.net,
                s.queries,
                s.errors,
                s.qps,
                s.latency.p50.as_micros(),
                s.latency.p99.as_micros(),
                s.tier
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_without_ensure_is_a_noop() {
        let m = FleetMetrics::new();
        m.record("ghost", Duration::from_micros(1), true);
        assert!(m.snapshot().is_empty());
        m.ensure("asia", Tier::Exact);
        m.ensure("asia", Tier::Exact); // idempotent
        assert!(m.render().contains("| asia queries=0 errors=0"), "{}", m.render());
        m.remove("asia");
        assert!(m.snapshot().is_empty());
    }

    #[test]
    fn records_split_by_network_and_outcome() {
        let m = FleetMetrics::new();
        m.ensure("asia", Tier::Exact);
        m.ensure("cancer", Tier::Approx);
        m.record("asia", Duration::from_micros(100), true);
        m.record("asia", Duration::from_micros(300), true);
        m.record("asia", Duration::from_micros(200), false);
        m.record("cancer", Duration::from_micros(50), true);
        let snaps = m.snapshot();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].net, "asia");
        assert_eq!(snaps[0].queries, 2);
        assert_eq!(snaps[0].errors, 1);
        assert_eq!(snaps[0].tier, Tier::Exact);
        // failed queries don't pollute the latency window
        assert_eq!(snaps[0].latency.count, 2);
        assert_eq!(snaps[1].net, "cancer");
        assert_eq!(snaps[1].queries, 1);
        assert_eq!(snaps[1].tier, Tier::Approx);
        assert!(snaps[0].qps > 0.0);
    }

    #[test]
    fn render_is_one_line_with_per_net_fields() {
        let m = FleetMetrics::new();
        m.ensure("asia", Tier::Approx);
        m.record("asia", Duration::from_micros(150), true);
        let line = m.render();
        assert!(line.starts_with("STATS uptime_ms="), "{line}");
        assert!(line.contains("nets=1"), "{line}");
        assert!(line.contains("| asia queries=1 errors=0"), "{line}");
        assert!(line.contains("p50_us=150"), "{line}");
        assert!(line.contains("p99_us=150"), "{line}");
        assert!(line.contains("tier=approx"), "{line}");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn empty_fleet_renders_zero_nets() {
        let m = FleetMetrics::new();
        assert!(m.render().contains("nets=0"));
        assert!(m.snapshot().is_empty());
    }
}
